"""Shard-worker runtime: each replica shard runs its embed/artifact/propose
round on its own supervised worker lane.

This is the promotion of the distributed scaffolding into the serving
path.  ``ShardWorkerPool`` duck-types the ``executor.map`` protocol that
``core.selection.replica_map`` (and every ``select_sharded`` strategy)
already fans out on, so the existing local-propose / global-dedup merge is
the cross-worker protocol unchanged — but each map now runs under
supervision:

  * one LANE per shard — a dedicated single-thread executor (``thread``
    backend, the default) optionally paired with a real OS process
    (``process`` backend) that executes registered picklable jobs such as
    the canonical embed batch;
  * every task is timed and fed to a ``StragglerMonitor``
    (distributed.fault_tolerance) — straggler events surface in
    ``stats()``;
  * a ``PhaseFailureInjector`` can deterministically kill a worker at the
    Nth task of a named phase (``embed`` / ``propose`` / ``ingest``), and
    ``kill()`` hard-kills a lane (SIGKILL for process lanes) for
    non-deterministic tests;
  * a dead worker — injected kill, hard kill, hung task past ``timeout_s``,
    or a broken process pipe — is detected by the supervising caller, the
    lane is RESTARTED (generation bump; fresh thread/process), the
    caller-supplied ``on_death(shard)`` recovery hook runs (the AL service
    resets the shard's artifact columns there, forcing a re-embed from raw
    + content keys), and the task retries with bounded backoff.  Selections
    stay bit-identical to the no-failure run because every retried task is
    a pure function of pinned inputs and the rebuilt columns reproduce the
    exact feature bytes (canonical-batch embedding).

Device pinning: with more than one jax device, lanes are pinned round-robin
onto the data axis of an elastic mesh (``elastic.largest_mesh_shape`` over
``jax.devices()``) and each task runs under ``jax.default_device(lane
device)`` — the same mesh builders ``launch.mesh`` uses, so a multi-chip
host spreads shard rounds across chips with no code change above this
module.
"""
from __future__ import annotations

import concurrent.futures as cf
import multiprocessing as mp
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.distributed.fault_tolerance import (SimulatedFailure,
                                               StragglerMonitor)


class WorkerDeath(RuntimeError):
    """A shard worker died (injected, killed, hung, or broken pipe)."""


class PhaseFailureInjector:
    """Deterministic worker-kill schedule keyed by PHASE of the shard path.

    ``fail_at`` maps a phase name (``embed`` / ``propose`` / ``ingest`` /
    ``job``) to the 0-based task indices *within that phase* at which the
    worker executing the task dies (raises ``SimulatedFailure``, which the
    pool treats exactly like a hard kill: restart + recover + retry).
    Each scheduled index fires once, so the retried task survives —
    mirroring ``fault_tolerance.FailureInjector``'s once-per-step contract.
    """

    def __init__(self, fail_at: Dict[str, Sequence[int]]):
        self.fail_at = {ph: set(idx) for ph, idx in fail_at.items()}
        self.counts: Dict[str, int] = {}
        self.fired: List[tuple] = []
        self._lock = threading.Lock()

    def maybe_fail(self, phase: str) -> None:
        with self._lock:
            i = self.counts.get(phase, 0)
            self.counts[phase] = i + 1
            sched = self.fail_at.get(phase)
            if sched and i in sched:
                sched.discard(i)
                self.fired.append((phase, i))
                raise SimulatedFailure(
                    f"injected worker death at {phase}[{i}]")


# --------------------------------------------------------------------------
# Registered process jobs: the only work shipped across the process
# boundary. Jobs are pure functions of their (picklable) payload plus a
# per-process cache dict for expensive lazy state (e.g. the backend).
# --------------------------------------------------------------------------
_JOBS: Dict[str, Callable[[Any, dict], Any]] = {}


def register_job(name: str):
    def deco(fn):
        _JOBS[name] = fn
        return fn
    return deco


@register_job("echo")
def _job_echo(payload, cache):
    return payload


@register_job("embed_batch")
def _job_embed_batch(payload, cache):
    """The canonical embed chunk (service layer's ``_feats_for`` contract):
    preprocess the raw rows, zero-pad to the one canonical ``batch_size``
    shape, run the feature forward, return the valid rows. Pure in
    (config, raw bytes) — the worker process rebuilds the backend from the
    config once and caches it, so the feature bytes match the in-process
    path bit for bit (backend construction is deterministic from config).
    """
    import numpy as np

    from repro.service.backends import make_backend
    from repro.service.config import ALServiceConfig

    cfg_d = payload["config"]
    key = tuple(sorted(cfg_d.items()))
    backend = cache.get(key)
    if backend is None:
        cfg = ALServiceConfig(**cfg_d)
        backend = make_backend(cfg.model_name, config=cfg)
        cache[key] = backend
    raw = np.asarray(payload["raw"])
    bs = max(int(payload["bs"]), 1)
    x = np.asarray(backend.preprocess(raw))
    n = x.shape[0]
    if n < bs:
        x = np.concatenate([x, np.zeros((bs - n,) + x.shape[1:], x.dtype)])
    return np.asarray(backend.features(x))[:n]


def _process_main(conn):
    """Worker-process loop: execute registered jobs until EOF/None."""
    cache: dict = {}
    while True:
        try:
            msg = conn.recv()
        except EOFError:
            return
        if msg is None:
            return
        name, payload = msg
        try:
            conn.send(("ok", _JOBS[name](payload, cache)))
        except BaseException as e:  # ship the failure, keep serving
            conn.send(("err", f"{type(e).__name__}: {e}"))


class _Lane:
    """One shard's worker lane: a dedicated single-thread executor, plus a
    paired OS process under the ``process`` backend. ``generation`` bumps
    on every restart."""

    def __init__(self, index: int, kind: str, device=None):
        self.index = index
        self.kind = kind
        self.device = device
        self.generation = 0
        self.dead = False
        self._proc = None
        self._conn = None
        self._ex = cf.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"shard{index}-g0")

    # -- liveness ---------------------------------------------------------
    def alive(self) -> bool:
        if self.dead:
            return False
        if self._proc is not None and not self._proc.is_alive():
            return False
        return True

    def kill(self) -> None:
        """Hard-kill the lane: SIGKILL the paired process (if any) and mark
        the lane dead so its next task raises ``WorkerDeath`` — thread
        lanes cannot be preempted mid-task, so an in-flight task is caught
        by the supervisor's timeout instead."""
        self.dead = True
        if self._proc is not None and self._proc.is_alive():
            self._proc.kill()

    def restart(self) -> None:
        self.generation += 1
        self.dead = False
        old = self._ex
        self._ex = cf.ThreadPoolExecutor(
            max_workers=1,
            thread_name_prefix=f"shard{self.index}-g{self.generation}")
        old.shutdown(wait=False)   # a hung task finishes into the void
        self._stop_process()

    # -- thread tasks -----------------------------------------------------
    def submit(self, fn, *args) -> cf.Future:
        return self._ex.submit(fn, *args)

    # -- process jobs -----------------------------------------------------
    def _ensure_process(self):
        if self._proc is None or not self._proc.is_alive():
            ctx = mp.get_context("spawn")
            self._conn, child = ctx.Pipe()
            self._proc = ctx.Process(target=_process_main, args=(child,),
                                     daemon=True,
                                     name=f"shard{self.index}-proc")
            self._proc.start()
            child.close()
        return self._conn

    def run_job(self, name: str, payload, timeout_s: float):
        """One registered job on the paired process; raises ``WorkerDeath``
        on a dead/hung process, ``RuntimeError`` on a job error."""
        if self.dead:
            raise WorkerDeath(f"lane {self.index} was killed")
        try:
            conn = self._ensure_process()
            conn.send((name, payload))
            if not conn.poll(timeout_s):
                raise WorkerDeath(
                    f"shard {self.index} job {name!r} hung past "
                    f"{timeout_s}s")
            status, value = conn.recv()
        except (EOFError, BrokenPipeError, OSError) as e:
            raise WorkerDeath(
                f"shard {self.index} worker process died during "
                f"{name!r}: {e!r}") from e
        if status != "ok":
            raise RuntimeError(f"job {name!r} failed on shard "
                               f"{self.index}: {value}")
        return value

    def _stop_process(self):
        if self._proc is not None:
            if self._proc.is_alive():
                try:
                    self._conn.send(None)
                except (BrokenPipeError, OSError):
                    pass
                self._proc.join(timeout=1.0)
                if self._proc.is_alive():
                    self._proc.kill()
            self._proc = None
            self._conn = None

    def shutdown(self):
        self._ex.shutdown(wait=False)
        self._stop_process()


def _lane_devices(n_lanes: int, devices=None) -> List[Any]:
    """Round-robin lane -> device pinning over the elastic mesh's data
    axis; all-None on a single-device host (no pinning needed)."""
    import jax

    devs = list(devices) if devices is not None else list(jax.devices())
    if len(devs) <= 1:
        return [None] * n_lanes
    from repro.distributed.elastic import largest_mesh_shape
    data, _model = largest_mesh_shape(len(devs), 1)
    row = devs[:data]
    return [row[i % len(row)] for i in range(n_lanes)]


class ShardWorkerPool:
    """Supervised per-shard worker lanes behind the ``executor.map``
    protocol (a drop-in for the old shared ThreadPoolExecutor).

    ``map`` runs under the default phase; ``scoped(phase, on_death,
    shard_of)`` returns a facade whose ``map`` tags tasks with that phase,
    maps each item to its shard via ``shard_of(position, item)``
    (positional by default), and calls ``on_death(shard)`` after a worker
    death before the retry — the service layer's shard-recovery hook.
    """

    def __init__(self, n_shards: int, *, kind: str = "thread",
                 timeout_s: float = 30.0, max_retries: int = 2,
                 backoff_s: float = 0.05,
                 injector: Optional[PhaseFailureInjector] = None,
                 monitor: Optional[StragglerMonitor] = None,
                 devices=None):
        if kind not in ("thread", "process"):
            raise ValueError(f"worker backend must be 'thread' or "
                             f"'process', got {kind!r}")
        self.n_shards = max(int(n_shards), 1)
        self.kind = kind
        self.timeout_s = float(timeout_s)
        self.max_retries = max(int(max_retries), 0)
        self.backoff_s = float(backoff_s)
        self.injector = injector
        self.monitor = monitor if monitor is not None else StragglerMonitor()
        self._devices = _lane_devices(self.n_shards, devices)
        self._lanes = [_Lane(i, kind, self._devices[i])
                       for i in range(self.n_shards)]
        self._lock = threading.Lock()
        self.restarts = 0          # lane restarts after a worker death
        self.tasks = 0             # supervised tasks completed
        self.deaths: List[str] = []   # human-readable death log

    # -- executor protocol -------------------------------------------------
    def map(self, fn: Callable, items) -> list:
        return self._map(fn, items, phase="shard", on_death=None,
                         shard_of=None)

    def scoped(self, phase: str, on_death: Optional[Callable] = None,
               shard_of: Optional[Callable] = None) -> "_ScopedExecutor":
        return _ScopedExecutor(self, phase, on_death, shard_of)

    # -- supervision core --------------------------------------------------
    def _map(self, fn, items, *, phase, on_death, shard_of) -> list:
        items = list(items)
        if not items:
            return []
        shards = [(shard_of(i, it) if shard_of is not None else i)
                  % self.n_shards for i, it in enumerate(items)]
        futs = [self._lanes[s].submit(self._wrap, phase, fn, it,
                                      self._lanes[s])
                for s, it in zip(shards, items)]
        return [self._gather(futs[i], shards[i], phase, fn, items[i],
                             on_death)
                for i in range(len(items))]

    def _wrap(self, phase, fn, item, lane):
        if lane.dead:
            raise WorkerDeath(f"lane {lane.index} was killed")
        if self.injector is not None:
            self.injector.maybe_fail(phase)
        t0 = time.perf_counter()
        if lane.device is not None:
            import jax
            with jax.default_device(lane.device):
                out = fn(item)
        else:
            out = fn(item)
        return time.perf_counter() - t0, out

    def _gather(self, fut, shard, phase, fn, item, on_death):
        lane = self._lanes[shard]
        attempt = 0
        while True:
            death = None
            try:
                dur, out = fut.result(timeout=self.timeout_s)
                with self._lock:
                    self.tasks += 1
                    self.monitor.observe(self.tasks, dur)
                return out
            except (SimulatedFailure, WorkerDeath) as e:
                death = e
            except cf.TimeoutError:
                # on >=3.11 cf.TimeoutError IS TimeoutError: one raised BY
                # the task itself must propagate, not read as a hang
                if fut.done():
                    raise
                death = WorkerDeath(
                    f"shard {shard} {phase} task hung past "
                    f"{self.timeout_s}s (worker presumed dead)")
            # -- death path: restart lane, recover shard, bounded retry --
            with self._lock:
                self.restarts += 1
                self.deaths.append(f"{phase}/shard{shard}: {death}")
            lane.restart()
            if on_death is not None:
                on_death(shard)
            attempt += 1
            if attempt > self.max_retries:
                raise WorkerDeath(
                    f"shard {shard} {phase} task failed after "
                    f"{attempt} attempts: {death}") from death
            time.sleep(self.backoff_s * attempt)
            fut = lane.submit(self._wrap, phase, fn, item, lane)

    # -- process jobs ------------------------------------------------------
    def run_job(self, shard: int, name: str, payload,
                on_death: Optional[Callable] = None):
        """A registered job on the shard's paired worker process, under
        the same supervision (injection, straggler timing, restart +
        bounded retry). Only meaningful on the ``process`` backend —
        thread pools run jobs inline for parity."""
        shard = shard % self.n_shards
        lane = self._lanes[shard]
        attempt = 0
        while True:
            death = None
            try:
                if self.injector is not None:
                    self.injector.maybe_fail("job")
                t0 = time.perf_counter()
                if self.kind == "process":
                    out = lane.run_job(name, payload, self.timeout_s)
                else:
                    out = _JOBS[name](payload, {})
                with self._lock:
                    self.tasks += 1
                    self.monitor.observe(self.tasks,
                                         time.perf_counter() - t0)
                return out
            except (SimulatedFailure, WorkerDeath) as e:
                death = e
            with self._lock:
                self.restarts += 1
                self.deaths.append(f"job/{name}/shard{shard}: {death}")
            lane.restart()
            if on_death is not None:
                on_death(shard)
            attempt += 1
            if attempt > self.max_retries:
                raise WorkerDeath(
                    f"shard {shard} job {name!r} failed after "
                    f"{attempt} attempts: {death}") from death
            time.sleep(self.backoff_s * attempt)

    # -- probes / chaos ----------------------------------------------------
    def kill(self, shard: int) -> None:
        self._lanes[shard % self.n_shards].kill()

    def probe(self) -> List[bool]:
        """Per-lane liveness (the detection half of kill-recovery)."""
        return [lane.alive() for lane in self._lanes]

    def stats(self) -> dict:
        with self._lock:
            return {
                "backend": self.kind,
                "lanes": self.n_shards,
                "generations": [ln.generation for ln in self._lanes],
                "alive": [ln.alive() for ln in self._lanes],
                "pinned_devices": sum(d is not None for d in self._devices),
                "tasks": self.tasks,
                "restarts": self.restarts,
                "straggler_events": len(self.monitor.events),
                "deaths": list(self.deaths),
            }

    def shutdown(self) -> None:
        for lane in self._lanes:
            lane.shutdown()


class _ScopedExecutor:
    """Phase-tagged view of a pool: what the service layer hands to
    ``replica_map`` / ``select_sharded`` so deaths in that phase run the
    right recovery hook."""

    def __init__(self, pool: ShardWorkerPool, phase: str,
                 on_death: Optional[Callable],
                 shard_of: Optional[Callable]):
        self.pool = pool
        self.phase = phase
        self.on_death = on_death
        self.shard_of = shard_of

    def map(self, fn: Callable, items) -> list:
        return self.pool._map(fn, items, phase=self.phase,
                              on_death=self.on_death,
                              shard_of=self.shard_of)
