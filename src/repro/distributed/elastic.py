"""Elastic scaling: rebuild the mesh from whatever devices exist and reshard
state onto it.

With atomic+elastic checkpoints (checkpoint/manager.py), scale-up/down is:
detect device change -> make_elastic_mesh() -> re-derive shardings from the
same logical rules -> restore(..., shardings=new) -> continue. Tests verify
a checkpoint written under a 4-device mesh restores bit-exact under 8 (and
1) devices.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

from repro.distributed import partition


def largest_mesh_shape(n_devices: int, model_parallel: int = 1
                       ) -> Tuple[int, int]:
    """(data, model) using as many devices as divisibility allows.

    ``model_parallel`` is clamped down to the largest divisor of
    ``n_devices``; both arguments must be >= 1 (0 would divide by zero,
    negatives would walk the divisor search forever)."""
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if model_parallel < 1:
        raise ValueError(
            f"model_parallel must be >= 1, got {model_parallel}")
    model = min(model_parallel, n_devices)
    while n_devices % model != 0:
        model -= 1
    return n_devices // model, model


def make_elastic_mesh(model_parallel: int = 1,
                      devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    data, model = largest_mesh_shape(len(devices), model_parallel)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devices[: data * model])


def reshard_plan(decls, mesh: Mesh, overrides=None):
    """(shardings tree, rules) for a given mesh from the shared rules."""
    rules = partition.make_rules(mesh, overrides)
    return partition.tree_shardings(decls, mesh, rules), rules
