"""Logical-axis -> mesh-axis resolution (MaxText-style sharding rules).

Every parameter / activation dim carries a *logical* axis name. Rules map a
logical name to a mesh axis (or tuple of axes). Resolution is
divisibility-aware: if a dim is not divisible by the product of the mapped
mesh-axis sizes, the rule is dropped for that dim (replicate) rather than
erroring — this is what lets one fixed production mesh serve 10 architectures
with head counts like 40 or 56 that a 16-way TP axis does not divide.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import param as param_lib

AxisVal = Union[None, str, Tuple[str, ...]]

# Logical axis vocabulary used across the codebase:
#   batch      activation batch                 -> (pod, data)
#   fsdp/embed parameter d_model dim            -> (pod, data)
#   tp         fused heads*head_dim / d_ff dims -> model
#   vocab      vocab dim of embed / lm_head     -> model
#   expert     MoE expert dim                   -> model
#   seq        sequence dim (SP, opt-in)        -> None by default
#   layer, norm, head_dim, window, ...          -> None
DEFAULT_RULES: Dict[str, AxisVal] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "embed": ("pod", "data"),
    "tp": "model",
    "ff": "model",
    "qkv": "model",
    "heads": "model",
    "kv": "model",
    "vocab": "model",
    "expert": "model",
    "seq": None,
    "kv_seq": None,
    "layer": None,
    "norm": None,
    "head_dim": None,
    "lora": None,
    "stack": None,
}


@dataclasses.dataclass
class AxisRules:
    rules: Dict[str, AxisVal]
    mesh_axes: Tuple[str, ...]
    mesh_shape: Dict[str, int]

    def _axes_for(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        val = self.rules.get(logical, None)
        if val is None:
            return ()
        if isinstance(val, str):
            val = (val,)
        # keep only axes present in this mesh (e.g. "pod" absent single-pod)
        return tuple(a for a in val if a in self.mesh_axes)

    def pspec(
        self,
        logical: Sequence[Optional[str]],
        dim_sizes: Optional[Sequence[int]] = None,
    ) -> P:
        """Resolve a logical-axis tuple to a PartitionSpec.

        Drops (a) axes already used by an earlier dim, (b) axes whose size
        does not divide the dim.
        """
        used = set()
        out = []
        for i, name in enumerate(logical):
            axes = self._axes_for(name)
            axes = tuple(a for a in axes if a not in used)
            if dim_sizes is not None and axes:
                prod = 1
                for a in axes:
                    prod *= self.mesh_shape[a]
                if prod == 0 or dim_sizes[i] % prod != 0:
                    axes = ()
            if not axes:
                out.append(None)
            else:
                used.update(axes)
                out.append(axes if len(axes) > 1 else axes[0])
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def batch_axes(self) -> Tuple[str, ...]:
        return self._axes_for("batch")

    def batch_size(self) -> int:
        n = 1
        for a in self._axes_for("batch"):
            n *= self.mesh_shape[a]
        return n


def make_rules(mesh: Mesh, overrides: Optional[Dict[str, AxisVal]] = None) -> AxisRules:
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return AxisRules(rules=rules, mesh_axes=tuple(mesh.axis_names), mesh_shape=shape)


def tree_pspecs(decls, rules: AxisRules):
    """ParamDecl tree -> PartitionSpec tree (divisibility-aware)."""

    def one(d: param_lib.ParamDecl) -> P:
        return rules.pspec(d.logical, d.shape)

    return jax.tree.map(one, decls, is_leaf=param_lib.is_decl)


def tree_shardings(decls, mesh: Mesh, rules: AxisRules):
    specs = tree_pspecs(decls, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def constrain(x, rules: AxisRules, *logical: Optional[str]):
    """with_sharding_constraint by logical names (no-op outside mesh ctx)."""
    try:
        spec = rules.pspec(logical, x.shape)
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# --- activation-constraint context -----------------------------------------
# Model code calls ``ac(x, *logical)``; the step builder installs the active
# rules while lowering. Outside any context this is a no-op, so smoke tests
# and CPU examples run unchanged (same pattern as flax's axis-rules context).
_ACTIVE: list = []


class activation_rules:
    def __init__(self, rules: AxisRules):
        self.rules = rules

    def __enter__(self):
        _ACTIVE.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE.pop()
        return False


def ac(x, *logical: Optional[str]):
    """Constrain an activation by logical axis names (no-op w/o context)."""
    if not _ACTIVE:
        return x
    rules = _ACTIVE[-1]
    spec = rules.pspec(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, spec)
