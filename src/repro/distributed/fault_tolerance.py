"""Fault-tolerance runtime: straggler detection, failure injection, and a
checkpoint/restart supervisor for the train loop.

At 1000+ nodes the dominant failure modes are (a) whole-node loss (preempted
pod, dead host) and (b) stragglers (thermal throttling, flaky ICI link). The
supervisor treats (a) as restore-from-last-checkpoint — checkpoints are
atomic + elastic, so resume works even on a *different* device count — and
(b) as a detection + mitigation hook (swap data shard / flag for eviction),
since single-controller JAX can't preempt a lagging chip mid-step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    ema_s: float
    ratio: float


class StragglerMonitor:
    """EMA step-time watchdog. ``observe`` returns an event if step time
    exceeds ``threshold`` x EMA (after warmup)."""

    def __init__(self, threshold: float = 2.5, alpha: float = 0.2,
                 warmup: int = 5):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, duration_s: float) -> Optional[StragglerEvent]:
        self.n += 1
        if self.ema is None:
            self.ema = duration_s
            return None
        ratio = duration_s / max(self.ema, 1e-9)
        event = None
        is_outlier = ratio > self.threshold
        if is_outlier and self.n > self.warmup:
            event = StragglerEvent(step, duration_s, self.ema, ratio)
            self.events.append(event)
        if not is_outlier:
            # outlier samples never fold into the EMA — during warmup they
            # are merely unreported, not accepted as the new baseline
            self.ema = (1 - self.alpha) * self.ema + self.alpha * duration_s
        return event


class FailureInjector:
    """Deterministic failure schedule for resilience tests: raises
    SimulatedFailure at the given steps (once each)."""

    def __init__(self, fail_at_steps: List[int]):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class SupervisorReport:
    steps_done: int
    restarts: int
    straggler_events: int
    final_step: int


def supervise(train_round: Callable[[int], int], *, total_steps: int,
              latest_step: Callable[[], Optional[int]],
              max_restarts: int = 10,
              monitor: Optional[StragglerMonitor] = None) -> SupervisorReport:
    """Run ``train_round(start_step) -> steps_completed`` until
    ``total_steps``, restarting from the last checkpoint on failure.

    ``train_round`` must itself restore state from ``latest_step()``.
    Pass the ``StragglerMonitor`` the rounds feed their step times to and
    the report's ``straggler_events`` reflects it (0 without one)."""
    restarts = 0

    def report(final: int) -> SupervisorReport:
        events = len(monitor.events) if monitor is not None else 0
        return SupervisorReport(total_steps, restarts, events, final)

    while True:
        start = latest_step() or 0
        if start >= total_steps:
            return report(start)
        try:
            reached = train_round(start)
            if reached >= total_steps:
                return report(reached)
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
