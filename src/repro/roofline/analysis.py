"""Three-term roofline from a compiled dry-run artifact.

  compute    = HLO_FLOPs_per_chip / peak_FLOPs
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_operand_bytes_per_chip / link_bw

cost_analysis() on an SPMD-partitioned executable reports the *per-device*
program, so terms are per-chip directly. Collective bytes are not in
cost_analysis: we parse the post-optimization HLO, build a symbol table of
instruction result sizes, and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (counting
-start, skipping -done so async pairs are not double counted).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\]{},:#\s]+?))\s+"
    r"([\w\-]+)\(([^)]*)")

COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start", "reduce-scatter-start",
    "ragged-all-to-all",
}
_SKIP = {"all-gather-done", "all-reduce-done", "collective-permute-done"}


def shape_bytes(type_str: str) -> int:
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return int(total)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective op kind from post-opt HLO text."""
    sizes: Dict[str, int] = {}
    pending = []  # (op, operand_names) resolved after full pass
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op, args = m.groups()
        sizes[name] = shape_bytes(type_str)
        if op in COLLECTIVES and op not in _SKIP:
            opnames = []
            depth = 0
            cur = ""
            for ch in args:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                if ch == "," and depth == 0:
                    opnames.append(cur.strip())
                    cur = ""
                else:
                    cur += ch
            if cur.strip():
                opnames.append(cur.strip())
            pending.append((op, [o.lstrip("%").split(" ")[0] for o in opnames
                                 if o.strip().startswith(("%",)) or
                                 re.match(r"^[\w.\-]+$", o.strip())]))
    out: Dict[str, int] = {}
    for op, opnames in pending:
        key = op.replace("-start", "")
        b = 0
        for nm in opnames:
            b += sizes.get(nm, 0)
        out[key] = out.get(key, 0) + b
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, int]
    chips: int
    model_flops_global: float
    raw_cost_flops: float = 0.0
    raw_cost_bytes: float = 0.0
    n_hlo_warnings: int = 0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Max-of-terms lower bound (perfect overlap assumption)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_chip * self.chips
        return self.model_flops_global / max(hlo_global, 1.0)

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization achievable at the roofline bound."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops_global / (self.chips * PEAK_FLOPS * t)

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
            "chips": self.chips,
            "model_flops_global": self.model_flops_global,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_bound": self.step_time,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
            "raw_cost_flops": self.raw_cost_flops,
            "raw_cost_bytes": self.raw_cost_bytes,
            "n_hlo_warnings": self.n_hlo_warnings,
        }


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D train, 2*N_active*D inference,
    plus exact-attention cache reads for decode."""
    n = cfg.active_params()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * B * S
    if shape.kind == "prefill":
        return 2.0 * n * B * S
    att = 4.0 * B * S * cfg.n_heads * cfg.hd if cfg.rwkv is None else 0.0
    return 2.0 * n * B + att


def analyze(compiled, cfg, shape, chips: int) -> Roofline:
    """Trip-count-scaled HLO walk (see hlo_analyzer); raw cost_analysis()
    numbers are recorded alongside for reference (they count while bodies
    once — verified in tests/test_roofline.py)."""
    from repro.roofline import hlo_analyzer

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    hc = hlo_analyzer.HloCost(compiled.as_text())
    c = hc.entry_cost()
    return Roofline(
        flops_per_chip=c.flops,
        bytes_per_chip=c.bytes,
        coll_bytes_per_chip=float(sum(c.coll.values())),
        coll_breakdown={k: int(v) for k, v in c.coll.items()},
        chips=chips,
        model_flops_global=model_flops(cfg, shape),
        raw_cost_flops=raw_flops,
        raw_cost_bytes=raw_bytes,
        n_hlo_warnings=len(hc.warnings),
    )
