"""Render the §Roofline markdown table from runs/dryrun_*.json."""
from __future__ import annotations

import json
import sys


def render(paths):
    rows = []
    for path in paths:
        with open(path) as f:
            recs = json.load(f)
        for key, r in sorted(recs.items()):
            if r["status"] == "skipped":
                rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                            f"skip | — | — | — | — | — | — |")
                continue
            if r["status"] != "ok":
                rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                            f"ERROR | — | — | — | — | — | — |")
                continue
            rf = r["roofline"]
            mem = r.get("memory", {})
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{rf['bottleneck'][:4]} | {rf['t_compute']:.2e} | "
                f"{rf['t_memory']:.2e} | {rf['t_collective']:.2e} | "
                f"{rf['useful_flops_ratio']:.2f} | {rf['mfu_bound']:.4f} | "
                f"{mem.get('temp_size_in_bytes', 0)/1e9:.0f} |")
    hdr = ("| arch | shape | mesh | bneck | t_comp (s) | t_mem (s) | "
           "t_coll (s) | useful | mfu_bound | temp GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


if __name__ == "__main__":
    print(render(sys.argv[1:]))
