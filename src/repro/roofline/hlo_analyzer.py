"""Trip-count-aware cost analysis of post-optimization HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified in
tests/test_roofline.py), which silently undercounts any scan-over-layers
program by ~n_layers x. This analyzer parses the compiled module text,
builds the computation call graph, and scales per-computation FLOPs / HBM
bytes / collective-operand bytes by ``known_trip_count`` from each while's
backend_config (fallback: the loop-bound constant in the condition).

Cost model (documented deviations in EXPERIMENTS.md §Roofline):
  * FLOPs: dots = 2 * result_elems * contracted_elems; elementwise = 1/elem;
    reduces = operand elems. Matches XLA conventions for the dominant terms.
  * HBM bytes: sum of (result + operand) bytes over *materializing* top-level
    instructions; fusion internals are excluded (a fusion touches HBM only at
    its parameters and its result), which is exactly the TPU mental model.
  * Collectives: operand bytes per op kind (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute), start/done pairs counted
    once.

All numbers are per-chip: an SPMD-partitioned executable's module is the
per-device program.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1,
    "f8e4m3": 1, "f8e8m0fnu": 1, "f4e2m1fn": 0.5,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVE_OPS = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
}
_START_SUFFIX = "-start"
_DONE_SUFFIX = "-done"

_NON_MATERIAL = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "token", "partition-id", "replica-id", "iota",
    "reshape",  # layout-preserving view on the TPU target
}

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "power", "exponential", "log",
    "tanh", "sqrt", "rsqrt", "negate", "maximum", "minimum", "compare",
    "select", "and", "or", "xor", "not", "exponential-minus-one",
    "log-plus-one", "cbrt", "sine", "cosine", "clamp", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "abs", "sign", "atan2",
    "remainder", "erf", "logistic", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "is-finite",
}


def _shape_elems_and_bytes(type_str: str) -> Tuple[int, float]:
    elems, byts = 0, 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    elems: int
    bytes_: float
    is_root: bool = False


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {}

    def __iadd__(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n,
                    {k: v * n for k, v in self.coll.items()})


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\]{},]+)\s+"
    r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r"known_trip_count[^\d]*(\d+)")
_CALL_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _split_operands(operand_str: str) -> List[str]:
    """Operand names from an HLO operand list.

    Depending on the XLA version, operands print bare (``%a, %b``) or with
    their full types (``f32[256,256]{1,0} %a, ...``); shapes contain commas,
    so splitting must track bracket/brace depth, and the name is the LAST
    token of each top-level part (stripped of ``%``)."""
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in operand_str:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    out = []
    for p in parts:
        toks = p.split()
        if not toks:
            continue
        name = next((t for t in reversed(toks) if t.startswith("%")),
                    toks[-1])
        out.append(name.lstrip("%"))
    return out


def parse_computations(hlo: str) -> Tuple[Dict[str, List[Instr]], Optional[str]]:
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        is_root = line.lstrip().startswith("ROOT ")
        name, type_str, opcode, rest = m.groups()
        depth = 1
        i = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str, attrs = rest[:i], rest[i + 1:]
        operands = _split_operands(operand_str)
        elems, byts = _shape_elems_and_bytes(type_str)
        comps[cur].append(Instr(name, type_str, opcode, operands, attrs,
                                elems, byts, is_root))
    return comps, entry


def _instr_flops(inst: Instr, table: Dict[str, Instr]) -> float:
    op = inst.opcode
    if op == "dot":
        contracted = 1
        m = _LHS_C_RE.search(inst.attrs)
        if m and inst.operands:
            lhs = table.get(inst.operands[0])
            if lhs is not None:
                dims_str = _SHAPE_RE.search(lhs.type_str)
                if dims_str and dims_str.group(2):
                    lhs_dims = [int(d) for d in dims_str.group(2).split(",")]
                    for d in (m.group(1).split(",") if m.group(1) else []):
                        contracted *= lhs_dims[int(d)]
        return 2.0 * inst.elems * contracted
    if op == "convolution":
        kern = 1
        if len(inst.operands) > 1:
            rhs = table.get(inst.operands[1])
            if rhs is not None:
                kern = max(rhs.elems, 1)
        return 2.0 * inst.elems * kern
    if op in _ELEMWISE:
        return float(inst.elems)
    if op in ("reduce", "reduce-window"):
        opnd = table.get(inst.operands[0]) if inst.operands else None
        return float(opnd.elems if opnd else inst.elems)
    if op == "all-reduce" or op == "all-reduce-start":
        return float(inst.elems)
    return 0.0


def _base_opcode(op: str) -> str:
    if op.endswith(_START_SUFFIX):
        return op[: -len(_START_SUFFIX)]
    return op


# ops that exist in CPU HLO as bf16->f32 legalization / layout plumbing but
# are free on the TPU target (the MXU consumes bf16 operands natively and
# converts fuse into consumers). Treated as *transparent*: zero HBM charge,
# operand sizes resolved through them to the source buffer.
_TRANSPARENT = {"convert", "bitcast", "copy", "reshape", "parameter",
                "tuple", "get-tuple-element", "constant"}


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_computations(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self.warnings: List[str] = []
        # per-computation alias maps: instr name -> (source name or None)
        self._alias: Dict[str, Dict[str, Optional[str]]] = {}
        for cname, instrs in self.comps.items():
            self._alias[cname] = self._build_aliases(cname, instrs)

    def _conv_only_fusion(self, called: Optional[str]) -> bool:
        if not called or called not in self.comps:
            return False
        return all(ci.opcode in _TRANSPARENT
                   for ci in self.comps[called])

    def _build_aliases(self, cname, instrs):
        """instr -> source operand for transparent (no-HBM) instructions.

        Only dtype-changing ops alias (convert + convert-only fusions):
        the point is to charge consumers at the *storage* dtype size. GTE /
        copy keep their own recorded (element) sizes — resolving a GTE to
        its tuple operand would charge the whole loop carry.
        """
        alias: Dict[str, Optional[str]] = {}
        for inst in instrs:
            if inst.opcode in ("convert", "bitcast"):
                alias[inst.name] = inst.operands[0] if inst.operands else None
            elif inst.opcode == "copy" and "(" not in inst.type_str:
                # non-tuple copy: resolve to source for dtype purposes
                alias[inst.name] = inst.operands[0] if inst.operands else None
            elif inst.opcode == "fusion":
                m = _CALL_RE.search(inst.attrs)
                if m and self._conv_only_fusion(m.group(1)):
                    alias[inst.name] = (inst.operands[0]
                                        if inst.operands else None)
        return alias

    def _operand_bytes(self, name: str, table: Dict[str, Instr],
                       cname: str) -> float:
        """Bytes of an operand, resolved through transparent aliases to the
        real buffer; charged at the smallest (storage) dtype on the chain."""
        alias = self._alias.get(cname, {})
        seen = set()
        best = table[name].bytes_ if name in table else 0.0
        while name in alias and name not in seen:
            seen.add(name)
            nxt = alias[name]
            if nxt is None or nxt not in table:
                break
            name = nxt
            best = min(best, table[name].bytes_) if best else \
                table[name].bytes_
        return best

    def _trip_count(self, inst: Instr, cond_name: Optional[str]) -> float:
        m = _TRIP_RE.search(inst.attrs)
        if m:
            return float(m.group(1))
        # fallback: loop bound = max integer constant in the condition
        best = 0
        if cond_name and cond_name in self.comps:
            for ci in self.comps[cond_name]:
                if ci.opcode == "constant":
                    for o in ci.operands:
                        if re.fullmatch(r"\d+", o):
                            best = max(best, int(o))
        if best:
            return float(best)
        self.warnings.append(f"while {inst.name}: no known_trip_count")
        return 1.0

    def comp_cost(self, name: str, *, material: bool = True) -> Cost:
        key = f"{name}|{material}"
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        instrs = self.comps.get(name, [])
        table = {i.name: i for i in instrs}
        for inst in instrs:
            op = inst.opcode
            if op == "while":
                body = _BODY_RE.search(inst.attrs)
                cond = _COND_RE.search(inst.attrs)
                trip = self._trip_count(inst, cond.group(1) if cond else None)
                if body:
                    total += self.comp_cost(body.group(1)).scaled(trip)
                continue
            if op in ("fusion", "call", "custom-call", "async-start"):
                m = _CALL_RE.search(inst.attrs)
                called = m.group(1) if m else None
                if called:
                    inner = self.comp_cost(called, material=False)
                    total += Cost(inner.flops, 0.0, dict(inner.coll))
                if (material and op != "custom-call"
                        and not self._conv_only_fusion(called)):
                    dus = self._inplace_dus_fusion(called)
                    if dus is not None:
                        tidx, ub = dus
                        other = sum(
                            self._operand_bytes(o, table, name)
                            for i, o in enumerate(inst.operands)
                            if i != tidx and o in table)
                        total += Cost(0.0, 2.0 * ub + min(other, ub * 4
                                                          + 1e6), {})
                    else:
                        ob = self._fusion_operand_bytes(inst, table, called,
                                                        cname=name)
                        total += Cost(0.0, inst.bytes_ + ob, {})
                continue
            if op == "convert":
                continue  # fused into consumers on the TPU target
            if op in ("dynamic-slice", "slice", "gather"):
                # reads + writes only the slice, never the source buffer
                total += Cost(_instr_flops(inst, table),
                              2.0 * inst.bytes_ if material else 0.0, {})
                continue
            if op == "dynamic-update-slice":
                ub = (self._operand_bytes(inst.operands[1], table, name)
                      if len(inst.operands) > 1 else inst.bytes_)
                total += Cost(0.0, 2.0 * ub if material else 0.0, {})
                continue
            if op == "conditional":
                # take max branch cost (upper bound)
                branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                      inst.attrs)
                names = []
                if branches:
                    names = [b.strip().lstrip("%") for b in
                             branches[0].split(",")]
                else:
                    names = [m.group(1) for m in
                             re.finditer(r"(?:true|false)_computation=%?"
                                         r"([\w.\-]+)", inst.attrs)]
                if names:
                    costs = [self.comp_cost(n) for n in names]
                    best = max(costs, key=lambda c: c.flops + c.bytes)
                    total += best
                continue
            base = _base_opcode(op)
            if base in COLLECTIVE_OPS and not op.endswith(_DONE_SUFFIX):
                ob = sum(self._operand_bytes(o, table, name)
                         for o in inst.operands if o in table)
                if ob == 0:
                    ob = inst.bytes_
                total += Cost(_instr_flops(inst, table),
                              inst.bytes_ + ob if material else 0.0,
                              {base: ob})
                continue
            fl = _instr_flops(inst, table)
            by = 0.0
            if material and op not in _NON_MATERIAL:
                ob = sum(self._operand_bytes(o, table, name)
                         for o in inst.operands if o in table)
                by = inst.bytes_ + ob
            total += Cost(fl, by, {})
        self._memo[key] = total
        return total

    def _inplace_dus_fusion(self, called: Optional[str]
                            ) -> Optional[Tuple[int, float]]:
        """If the fused computation's ROOT is (converts of) a
        dynamic-update-slice whose target traces back to a parameter, this
        models the TPU in-place cache update: returns (target_param_index,
        update_bytes). The fusion's full-buffer result then aliases its
        input instead of being written to HBM."""
        if not called or called not in self.comps:
            return None
        cinstrs = self.comps[called]
        ctable = {c.name: c for c in cinstrs}

        def resolve(nm, depth=0):
            ci = ctable.get(nm)
            while (ci is not None and depth < 8
                   and ci.opcode in ("convert", "bitcast", "copy", "reshape")
                   and ci.operands):
                ci = ctable.get(ci.operands[0])
                depth += 1
            return ci

        root = next((c for c in cinstrs if c.is_root), None)
        dus = resolve(root.name) if root is not None else None
        if dus is None or dus.opcode != "dynamic-update-slice":
            return None
        target = resolve(dus.operands[0]) if dus.operands else None
        if target is None or target.opcode != "parameter":
            return None
        try:
            tidx = int(target.operands[0])
        except (ValueError, IndexError):
            return None
        upd = resolve(dus.operands[1]) if len(dus.operands) > 1 else None
        # charge at storage (min) dtype size of the update
        ub = min(upd.bytes_, ctable[dus.operands[1]].bytes_) if (
            upd is not None and dus.operands[1] in ctable) else (
            upd.bytes_ if upd is not None else dus.bytes_)
        return tidx, ub

    def _fusion_operand_bytes(self, inst: Instr, table: Dict[str, Instr],
                              called: Optional[str], *,
                              cname: str = "") -> float:
        """Operand bytes of a fusion, charging sliced params at slice size.

        A fused dynamic-slice reads only the slice from HBM; charging the
        full operand would overcount KV-cache and scan-slice traffic badly.
        convert/bitcast/copy inside the fused body are treated as
        transparent when tracing a parameter's uses (TPU target model).
        """
        full = [self._operand_bytes(o, table, cname) if o in table else 0.0
                for o in inst.operands]
        if not called or called not in self.comps:
            return float(sum(full))
        cinstrs = self.comps[called]
        ctable = {c.name: c for c in cinstrs}
        uses_of: Dict[str, List[Instr]] = {}
        for ci in cinstrs:
            for o in ci.operands:
                uses_of.setdefault(o, []).append(ci)

        def terminal_uses(nm: str, depth=0) -> List[Instr]:
            outs = []
            for u in uses_of.get(nm, []):
                if u.opcode in ("convert", "bitcast", "copy", "reshape") \
                        and depth < 6:
                    outs.extend(terminal_uses(u.name, depth + 1))
                else:
                    outs.append(u)
            return outs

        pname_to_idx: Dict[str, int] = {}
        for ci in cinstrs:
            if ci.opcode == "parameter" and ci.operands:
                try:
                    pname_to_idx[ci.name] = int(ci.operands[0])
                except ValueError:
                    pass
        idx_to_pname = {v: k for k, v in pname_to_idx.items()}

        out = 0.0
        for i, fb in enumerate(full):
            pname = idx_to_pname.get(i)
            uses = terminal_uses(pname) if pname else None
            if uses and all(u.opcode in ("dynamic-slice", "slice", "gather",
                                         "dynamic-update-slice")
                            for u in uses):
                out += sum(
                    u.bytes_ if u.opcode != "dynamic-update-slice"
                    else (self._operand_bytes(u.operands[1], ctable, called)
                          if len(u.operands) > 1 else u.bytes_)
                    for u in uses)
            else:
                out += fb
        return out

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.comp_cost(self.entry)


def analyze_text(hlo_text: str) -> Cost:
    return HloCost(hlo_text).entry_cost()
