"""Cost attribution: where do the roofline bytes/FLOPs/collectives come from?

Walks the same computation graph as hlo_analyzer but keeps per-instruction
records scaled by the enclosing while trip-counts, attributed to the jax
``op_name`` metadata (which carries model source names like
``jit(train_step)/.../dot_general``). This is the dry-run 'profiler' the
§Perf hillclimb iterates against — no wall clock on CPU, but exact
compiled-artifact accounting.
"""
from __future__ import annotations

import collections
import re
from typing import Dict, List, Optional, Tuple

from repro.roofline.hlo_analyzer import (COLLECTIVE_OPS, HloCost, Instr,
                                         _DONE_SUFFIX, _NON_MATERIAL,
                                         _BODY_RE, _CALL_RE, _COND_RE,
                                         _base_opcode, _instr_flops)

_META_RE = re.compile(r'op_name="([^"]*)"')


def _opname(inst: Instr) -> str:
    m = _META_RE.search(inst.attrs)
    if not m:
        return f"<{inst.opcode}>"
    name = m.group(1)
    # strip unique suffixes to aggregate: keep the semantic path tail
    parts = name.split("/")
    return "/".join(parts[-3:]) if len(parts) > 3 else name


class Attribution(HloCost):
    def attribute(self) -> Dict[str, Dict[str, float]]:
        """op_name -> {flops, bytes, coll} (trip-scaled)."""
        agg: Dict[str, Dict[str, float]] = collections.defaultdict(
            lambda: {"flops": 0.0, "bytes": 0.0, "coll": 0.0})
        if self.entry is not None:
            self._walk(self.entry, 1.0, agg, material=True)
        return dict(agg)

    def _walk(self, comp: str, mult: float, agg, *, material: bool):
        instrs = self.comps.get(comp, [])
        table = {i.name: i for i in instrs}
        for inst in instrs:
            op = inst.opcode
            key = _opname(inst)
            if op == "while":
                body = _BODY_RE.search(inst.attrs)
                cond = _COND_RE.search(inst.attrs)
                trip = self._trip_count(inst, cond.group(1) if cond else None)
                if body:
                    self._walk(body.group(1), mult * trip, agg,
                               material=material)
                continue
            if op in ("fusion", "call", "custom-call", "async-start"):
                m = _CALL_RE.search(inst.attrs)
                called = m.group(1) if m else None
                if called:
                    self._walk(called, mult, agg, material=False)
                if (material and op != "custom-call"
                        and not self._conv_only_fusion(called)):
                    dus = self._inplace_dus_fusion(called)
                    if dus is not None:
                        tidx, ub = dus
                        other = sum(self._operand_bytes(o, table, comp)
                                    for i, o in enumerate(inst.operands)
                                    if i != tidx and o in table)
                        agg[key]["bytes"] += (2.0 * ub + min(
                            other, ub * 4 + 1e6)) * mult
                    else:
                        ob = self._fusion_operand_bytes(inst, table, called,
                                                        cname=comp)
                        agg[key]["bytes"] += (inst.bytes_ + ob) * mult
                continue
            if op == "convert":
                continue
            if op in ("dynamic-slice", "slice", "gather"):
                agg[key]["flops"] += _instr_flops(inst, table) * mult
                if material:
                    agg[key]["bytes"] += 2.0 * inst.bytes_ * mult
                continue
            if op == "dynamic-update-slice":
                ub = (self._operand_bytes(inst.operands[1], table, comp)
                      if len(inst.operands) > 1 else inst.bytes_)
                if material:
                    agg[key]["bytes"] += 2.0 * ub * mult
                continue
            base = _base_opcode(op)
            if base in COLLECTIVE_OPS and not op.endswith(_DONE_SUFFIX):
                ob = sum(self._operand_bytes(o, table, comp)
                         for o in inst.operands if o in table)
                if ob == 0:
                    ob = inst.bytes_
                agg[key]["coll"] += ob * mult
                if material:
                    agg[key]["bytes"] += (inst.bytes_ + ob) * mult
                continue
            agg[key]["flops"] += _instr_flops(inst, table) * mult
            if material and op not in _NON_MATERIAL:
                ob = sum(self._operand_bytes(o, table, comp)
                         for o in inst.operands if o in table)
                agg[key]["bytes"] += (inst.bytes_ + ob) * mult


def top_costs(hlo_text: str, k: int = 25) -> str:
    """Human-readable top-k contributors per resource."""
    att = Attribution(hlo_text).attribute()
    lines = []
    for res in ("bytes", "coll", "flops"):
        total = sum(v[res] for v in att.values())
        lines.append(f"== top {res} (total {total:.3e}) ==")
        top = sorted(att.items(), key=lambda kv: -kv[1][res])[:k]
        for name, v in top:
            if v[res] <= 0:
                continue
            lines.append(f"  {v[res]:.3e} ({v[res]/max(total,1e-30):6.1%}) "
                         f"{name}")
    return "\n".join(lines)
