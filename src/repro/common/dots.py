"""Mixed-precision matmul helper.

TPU target: bf16 operands feed the MXU directly with an f32 accumulator
(``preferred_element_type``) — no materialized converts, which matters
because XLA hoists a whole-cache ``convert`` out of the layer scan when the
model casts explicitly (EXPERIMENTS.md §Perf A1).

CPU runtime (smoke tests, examples): the XLA:CPU DotThunk cannot execute
BF16xBF16=F32, so operands are cast to f32. The roofline analyzer treats
those converts as transparent (they do not exist in the TPU lowering), so
the accounting stays target-faithful either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _cpu() -> bool:
    return jax.default_backend() == "cpu"


def einsum_f32(spec: str, lhs, rhs):
    """einsum with f32 accumulation; operands stay in storage dtype on TPU."""
    if _cpu():
        return jnp.einsum(spec, lhs.astype(jnp.float32),
                          rhs.astype(jnp.float32))
    return jnp.einsum(spec, lhs, rhs, preferred_element_type=jnp.float32)
