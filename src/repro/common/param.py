"""Parameter declaration system.

Models declare parameters as ``ParamDecl`` pytrees (shape + logical axes +
init recipe). The same declaration tree is used three ways:

  * ``init_params``      -> materialized arrays (smoke tests, examples)
  * ``param_shapes``     -> ShapeDtypeStruct tree (dry-run lowering, no alloc)
  * ``partition.tree_pspecs`` -> PartitionSpec tree (pjit shardings)

Logical axis names are resolved to mesh axes by ``repro.distributed.partition``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    """Declaration of a single parameter tensor."""

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"          # normal | zeros | ones | embed | uniform
    scale: Optional[float] = None  # stddev override; default 1/sqrt(fan_in)
    fan_in_axes: Optional[Tuple[int, ...]] = None  # axes counted as fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)

    @property
    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def _fan_in(decl: ParamDecl) -> float:
    if decl.fan_in_axes is not None:
        axes = decl.fan_in_axes
    elif len(decl.shape) <= 1:
        axes = ()
    else:
        # By convention the last axis is the output axis; "layer"-stacked
        # leading axes are excluded from fan-in.
        axes = tuple(
            i for i, name in enumerate(decl.logical[:-1]) if name != "layer"
        )
    fan = 1.0
    for a in axes:
        fan *= decl.shape[a]
    return max(fan, 1.0)


def init_one(decl: ParamDecl, key: jax.Array) -> jax.Array:
    if decl.init == "zeros":
        return jnp.zeros(decl.shape, decl.dtype)
    if decl.init == "ones":
        return jnp.ones(decl.shape, decl.dtype)
    scale = decl.scale
    if decl.init == "embed":
        scale = scale if scale is not None else 0.02
        x = jax.random.normal(key, decl.shape, jnp.float32) * scale
        return x.astype(decl.dtype)
    if decl.init == "uniform":
        lim = scale if scale is not None else float(np.sqrt(1.0 / _fan_in(decl)))
        x = jax.random.uniform(key, decl.shape, jnp.float32, -lim, lim)
        return x.astype(decl.dtype)
    # default: truncated-normal-ish scaled normal
    std = scale if scale is not None else float(1.0 / np.sqrt(_fan_in(decl)))
    x = jax.random.normal(key, decl.shape, jnp.float32) * std
    return x.astype(decl.dtype)


def init_params(decls, rng: jax.Array):
    """Materialize a ParamDecl pytree into arrays (deterministic in rng)."""
    leaves, treedef = jax.tree.flatten(decls, is_leaf=is_decl)
    keys = jax.random.split(rng, max(len(leaves), 1))
    arrs = [init_one(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def param_shapes(decls):
    """ShapeDtypeStruct tree for lowering without allocation."""
    return jax.tree.map(lambda d: d.sds, decls, is_leaf=is_decl)


def logical_tree(decls):
    """Tree of logical-axis tuples (same structure as params)."""
    return jax.tree.map(lambda d: d.logical, decls, is_leaf=is_decl)


def count_params(decls) -> int:
    leaves = jax.tree.leaves(decls, is_leaf=is_decl)
    return int(sum(int(np.prod(d.shape)) for d in leaves))


def param_bytes(decls) -> int:
    leaves = jax.tree.leaves(decls, is_leaf=is_decl)
    return int(
        sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize for d in leaves)
    )
