"""Optimizers built from scratch (no optax): AdamW and a factored
Adafactor-style optimizer (bf16 first moment + rank-1 factored second moment)
for the 671B-class archs where full fp32 Adam state would not fit 16 GB/chip.

Both expose *declaration* trees so the dry-run can lower ``train_step`` with
ShapeDtypeStructs and derive optimizer-state shardings from the same logical
axes as the parameters (ZeRO-3 falls out of pjit param sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.param import ParamDecl, init_params, is_decl


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable = cosine_schedule(3e-4, 100, 10000)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32

    def state_decls(self, param_decls):
        def one(d: ParamDecl):
            return {
                "m": ParamDecl(d.shape, d.logical, dtype=self.state_dtype,
                               init="zeros"),
                "v": ParamDecl(d.shape, d.logical, dtype=self.state_dtype,
                               init="zeros"),
            }
        return {
            "per_param": jax.tree.map(one, param_decls, is_leaf=is_decl),
            "step": ParamDecl((), (), dtype=jnp.int32, init="zeros"),
        }

    def init(self, params):
        return {
            "per_param": jax.tree.map(
                lambda p: {"m": jnp.zeros(p.shape, self.state_dtype),
                           "v": jnp.zeros(p.shape, self.state_dtype)}, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        lr = self.lr(step)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, s):
            g32 = g.astype(jnp.float32)
            m = self.b1 * s["m"].astype(jnp.float32) + (1 - self.b1) * g32
            v = self.b2 * s["v"].astype(jnp.float32) + (1 - self.b2) * g32 ** 2
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay, no decay on norms/bias
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, {"m": m.astype(self.state_dtype),
                           "v": v.astype(self.state_dtype)}

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["per_param"])
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_state = {"per_param": tdef.unflatten([o[1] for o in outs]),
                     "step": step}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def _factor_axes(shape) -> Optional[Tuple[int, int]]:
    """Pick the two largest trailing axes to factor over (None if ndim<2)."""
    if len(shape) < 2:
        return None
    return (len(shape) - 2, len(shape) - 1)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    """Factored second moment (row/col) + bf16 first moment.

    State cost: ~2 bytes/param (m in bf16) + O(rows+cols) for v — ~7x smaller
    than fp32 AdamW state; the difference between deepseek-v3-671b fitting a
    16 GB v5e chip or not (see EXPERIMENTS.md §Dry-run).
    """
    lr: Callable = cosine_schedule(1e-4, 100, 10000)
    b1: float = 0.9
    decay: float = 0.99
    eps: float = 1e-30
    clip_norm: float = 1.0
    weight_decay: float = 0.0

    def state_decls(self, param_decls):
        def one(d: ParamDecl):
            ax = _factor_axes(d.shape)
            st = {"m": ParamDecl(d.shape, d.logical, dtype=jnp.bfloat16,
                                 init="zeros")}
            if ax is None:
                st["v"] = ParamDecl(d.shape, d.logical, dtype=jnp.float32,
                                    init="zeros")
            else:
                r, c = ax
                row_shape = tuple(s for i, s in enumerate(d.shape) if i != c)
                col_shape = tuple(s for i, s in enumerate(d.shape) if i != r)
                row_log = tuple(l for i, l in enumerate(d.logical) if i != c)
                col_log = tuple(l for i, l in enumerate(d.logical) if i != r)
                st["vr"] = ParamDecl(row_shape, row_log, dtype=jnp.float32,
                                     init="zeros")
                st["vc"] = ParamDecl(col_shape, col_log, dtype=jnp.float32,
                                     init="zeros")
            return st
        return {
            "per_param": jax.tree.map(one, param_decls, is_leaf=is_decl),
            "step": ParamDecl((), (), dtype=jnp.int32, init="zeros"),
        }

    def init(self, params):
        decls = jax.tree.map(
            lambda p: ParamDecl(p.shape, (None,) * p.ndim, dtype=p.dtype),
            params)
        return init_params(self.state_decls(decls), jax.random.PRNGKey(0))

    def update(self, grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        lr = self.lr(step)

        def upd(p, g, s):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + self.eps
            if "v" in s:
                v = self.decay * s["v"] + (1 - self.decay) * g2
                precond = g32 * jax.lax.rsqrt(v + self.eps)
                new_v = {"v": v}
            else:
                r, c = _factor_axes(p.shape)
                vr = self.decay * s["vr"] + (1 - self.decay) * jnp.mean(g2, axis=c)
                vc = self.decay * s["vc"] + (1 - self.decay) * jnp.mean(g2, axis=r)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                vr_e = jnp.expand_dims(vr, c)
                vc_e = jnp.expand_dims(vc, r)
                v = vr_e * vc_e / jnp.maximum(
                    jnp.expand_dims(denom, c), self.eps)
                precond = g32 * jax.lax.rsqrt(v + self.eps)
                new_v = {"vr": vr, "vc": vc}
            m = self.b1 * s["m"].astype(jnp.float32) + (1 - self.b1) * precond
            delta = m
            if p.ndim >= 2 and self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, dict(new_v, m=m.astype(jnp.bfloat16))

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["per_param"])
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_state = {"per_param": tdef.unflatten([o[1] for o in outs]),
                     "step": step}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


OPTIMIZERS = {"adamw": AdamW, "adafactor": Adafactor}


def make_optimizer(name: str, **kw):
    return OPTIMIZERS[name](**kw)
