"""Gradient compression for cross-pod reduction.

Two composable schemes (both with exactness-preserving state):

  * top-k sparsification with error feedback (DGC-style): only the k largest
    |g| entries are reduced; the residual accumulates locally and is added
    back next step, so the optimizer sees an unbiased long-run gradient.
  * int8 quantization (per-tensor absmax scaling) around a psum — 4x fewer
    bytes on the slow inter-pod links.

At the (2,16,16) mesh the inter-pod axis has exactly these semantics: DP
gradient reduction over "pod" is the long-haul traffic; compress there,
keep in-pod reductions exact (see launch/train.py --compress).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def topk_sparsify(g: jax.Array, frac: float,
                  err: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Keep the top ``frac`` fraction of |g| (+ carried error); returns
    (sparse_g, new_error). Shapes preserved (zeros elsewhere)."""
    if err is not None:
        g = g + err
    flat = g.reshape(-1)
    k = max(int(flat.shape[0] * frac), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(g) >= thresh
    sparse = jnp.where(mask, g, 0.0)
    return sparse, g - sparse


def int8_quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, axis_name: str, *,
                    quantize: bool = True) -> jax.Array:
    """psum with int8 payload: quantize -> psum(int32) -> dequant by the
    gathered scales' max (conservative, deterministic)."""
    if not quantize:
        return jax.lax.psum(g, axis_name)
    q, scale = int8_quantize(g)
    scale = jax.lax.pmax(scale, axis_name)       # shared scale across peers
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def make_compressed_grad_fn(frac: float = 0.05):
    """tree-level top-k + error feedback; returns (fn, init_state_fn)."""

    def init_state(grads):
        return jax.tree.map(jnp.zeros_like, grads)

    def compress(grads, err_state):
        outs = jax.tree.map(
            lambda g, e: topk_sparsify(g.astype(jnp.float32), frac,
                                       e.astype(jnp.float32)),
            grads, err_state, is_leaf=lambda x: isinstance(x, jax.Array))
        sparse = jax.tree.map(lambda t: t[0], outs,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], outs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return sparse, new_err

    return compress, init_state
