"""Sharded, atomic, async checkpointing with elastic restore.

Layout per step:  <dir>/step_<n>/
    manifest.msgpack   tree structure, shapes, dtypes, step, metadata
    arr_<i>.npy[.zst]  one file per leaf (real multi-host would write one
                       file per shard; single-process writes the full leaf)

Guarantees:
  * atomic — written to a tmpdir, fsynced, then renamed; a crash mid-save
    never corrupts the latest checkpoint (restore scans for complete dirs).
  * async — ``save_async`` snapshots to host memory synchronously and
    writes on a background thread, so the train loop only blocks for the
    device->host copy.
  * elastic — ``restore`` takes target shardings; leaves are device_put
    against the *new* mesh, so restoring onto a different device count
    (scale up/down) or different sharding rules just works.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Optional

import jax
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover
    zstd = None

try:
    import ml_dtypes
    _EXTRA_DTYPES = {"bfloat16": np.dtype(ml_dtypes.bfloat16)}
    for _n in ("float8_e4m3fn", "float8_e5m2"):
        if hasattr(ml_dtypes, _n):
            _EXTRA_DTYPES[_n] = np.dtype(getattr(ml_dtypes, _n))
except ImportError:  # pragma: no cover
    _EXTRA_DTYPES = {}


def _np_dtype(name: str) -> np.dtype:
    if name in _EXTRA_DTYPES:
        return _EXTRA_DTYPES[name]
    return np.dtype(name)


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, compress: bool = False):
        self.dir = directory
        self.keep = keep
        self.compress = compress and zstd is not None
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ----------------------------------------------------------- save ----
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._write(step, host_tree, metadata or {})

    def save_async(self, step: int, tree: Any,
                   metadata: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # sync copy

        def work():
            try:
                self._write(step, host_tree, metadata or {})
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree, metadata: dict):
        paths, leaves, _ = _leaf_paths(host_tree)
        final = os.path.join(self.dir, f"step_{step:012d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        entries = []
        for i, (p, leaf) in enumerate(zip(paths, leaves)):
            arr = np.asarray(leaf)
            if arr.ndim and not arr.flags["C_CONTIGUOUS"]:
                arr = np.ascontiguousarray(arr)
            fname = f"arr_{i}.bin" + (".zst" if self.compress else "")
            blob = arr.tobytes()
            if self.compress:
                blob = zstd.ZstdCompressor(level=3).compress(blob)
            with open(os.path.join(tmp, fname), "wb") as f:
                f.write(blob)
            entries.append({"path": p, "file": fname,
                            "dtype": str(arr.dtype),
                            "shape": list(arr.shape)})
        manifest = {"step": step, "entries": entries, "metadata": metadata,
                    "complete": True}
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:012d}"),
                          ignore_errors=True)

    # -------------------------------------------------------- restore ----
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            if not os.path.exists(os.path.join(self.dir, name,
                                               "manifest.msgpack")):
                continue
            out.append(int(name[5:]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple:
        """Returns (tree, step, metadata). ``template`` fixes the pytree
        structure; ``shardings`` (optional matching tree) resharding onto
        the current mesh (elastic restore)."""
        step = step if step is not None else self.latest_step()
        assert step is not None, f"no checkpoints in {self.dir}"
        d = os.path.join(self.dir, f"step_{step:012d}")
        with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read(), raw=False)
        by_path = {e["path"]: e for e in manifest["entries"]}
        paths, leaves, treedef = _leaf_paths(template)
        shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                        else [None] * len(leaves))
        out = []
        for p, tmpl, sh in zip(paths, leaves, shard_leaves):
            e = by_path[p]
            fpath = os.path.join(d, e["file"])
            with open(fpath, "rb") as f:
                blob = f.read()
            if e["file"].endswith(".zst"):
                blob = zstd.ZstdDecompressor().decompress(blob)
            arr = np.frombuffer(blob, dtype=_np_dtype(e["dtype"])).reshape(
                e["shape"]).copy()
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, manifest["step"], manifest["metadata"]
