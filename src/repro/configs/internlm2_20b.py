"""internlm2-20b — dense, GQA. [arXiv:2403.17297; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92544,
    rope_theta=1000000.0,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="internlm2-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, q_chunk=16, kv_chunk=16,
    )
