"""recurrentgemma-2b — Griffin: RG-LRU + local attention, 1 attn : 2 rec.
[arXiv:2402.19427; hf]

Sub-quadratic: RG-LRU state + 2048-token local window => runs long_500k.
"""
from repro.configs.base import ArchConfig, GriffinConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    griffin=GriffinConfig(
        lru_width=2560,
        conv_width=4,
        pattern=("rec", "rec", "attn"),
        window=2048,
    ),
    subquadratic=True,
    logits_soft_cap=30.0,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="rgemma-smoke", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=128, vocab=256, head_dim=16, q_chunk=16, kv_chunk=16,
        griffin=GriffinConfig(lru_width=64, conv_width=4,
                              pattern=("rec", "rec", "attn"), window=16),
    )
