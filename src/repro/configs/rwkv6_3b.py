"""rwkv6-3b (Finch) — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]

Pure recurrent state => O(1) decode, runs long_500k.
"""
from repro.configs.base import ArchConfig, RWKVConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,               # d_model / rwkv.head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    norm="ln",
    norm_eps=1e-5,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32, chunk=64),
    subquadratic=True,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="rwkv6-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256,
        rwkv=RWKVConfig(head_dim=16, decay_lora=16, mix_lora=8, chunk=16),
    )
