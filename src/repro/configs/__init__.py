"""Arch config registry: ``get_config(name)`` / ``get_smoke_config(name)``."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    GriffinConfig,
    MLAConfig,
    MoEConfig,
    RWKVConfig,
    ShapeConfig,
    SHAPES,
    shape_applicable,
)

ARCH_IDS = [
    "phi3_medium_14b",
    "qwen15_4b",
    "qwen3_8b",
    "internlm2_20b",
    "whisper_medium",
    "deepseek_moe_16b",
    "deepseek_v3_671b",
    "recurrentgemma_2b",
    "rwkv6_3b",
    "llava_next_34b",
]

# canonical ids from the assignment -> module names
ALIASES = {
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen1.5-4b": "qwen15_4b",
    "qwen3-8b": "qwen3_8b",
    "internlm2-20b": "internlm2_20b",
    "whisper-medium": "whisper_medium",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "rwkv6-3b": "rwkv6_3b",
    "llava-next-34b": "llava_next_34b",
}


def _module(name: str):
    name = ALIASES.get(name, name).replace("-", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    return _module(name).smoke_config()
