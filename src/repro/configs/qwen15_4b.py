"""qwen1.5-4b — dense, QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="qwen15-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, q_chunk=16, kv_chunk=16,
    )
