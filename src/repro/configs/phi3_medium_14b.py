"""phi3-medium-14b — dense, RoPE+SwiGLU+GQA. [arXiv:2404.14219; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab=100352,
    rope_theta=10000.0,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="phi3-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, q_chunk=16, kv_chunk=16,
    )
