"""deepseek-v3-671b — MLA + 1 shared + 256 routed top-8 + MTP.
[arXiv:2412.19437; hf]
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,               # dense-layer FFN (first 3 layers)
    vocab=129280,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_routed=256,
        top_k=8,
        d_ff_expert=2048,
        n_shared=1,
        first_dense=3,
        capacity_factor=1.25,
    ),
    mtp=True,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="dsv3-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, q_chunk=16, kv_chunk=16,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=dataclasses.replace(CONFIG.moe, n_routed=8, top_k=2, d_ff_expert=32,
                                n_shared=1, first_dense=1, group_size=64),
    )
