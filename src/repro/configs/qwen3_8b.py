"""qwen3-8b — dense, GQA + qk_norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1000000.0,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="qwen3-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, head_dim=16, q_chunk=16, kv_chunk=16,
    )
