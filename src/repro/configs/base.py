"""Architecture + shape configuration.

One ``ArchConfig`` describes any of the 10 assigned backbones; each arch file
under ``repro/configs`` exports ``CONFIG`` (full-size, dry-run only) and
``smoke_config()`` (reduced, runs a real step on CPU).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    first_dense: int = 0          # leading dense layers (deepseek-v3: 3)
    capacity_factor: float = 1.25
    group_size: int = 2048        # tokens per dispatch group
    aux_loss_alpha: float = 0.001
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class GriffinConfig:
    """RecurrentGemma block pattern: (rec, rec, attn) repeating."""
    lru_width: int = 2560
    conv_width: int = 4
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    window: int = 2048            # local attention window
    c_const: float = 8.0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    norm: str = "rms"             # rms | ln
    mlp: str = "swiglu"           # swiglu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rwkv: Optional[RWKVConfig] = None
    griffin: Optional[GriffinConfig] = None
    # enc-dec (whisper): n_layers == decoder layers
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_enc_frames: int = 1500      # stub audio frontend sequence length
    # vlm stub frontend
    n_patches: int = 0            # patch embeddings spliced into prefix
    mtp: bool = False             # deepseek-v3 multi-token prediction head
    logits_soft_cap: Optional[float] = None
    # runtime knobs (hillclimb levers)
    q_chunk: int = 512
    kv_chunk: int = 1024
    remat: bool = True
    attention_impl: str = "chunked"   # chunked | naive | pallas
    # note for DESIGN.md §shape-skips
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab, 256)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline."""
        d, L, V = self.d_model, self.n_layers, self.padded_vocab
        total = V * d * (1 if self.tie_embeddings else 2)
        if self.rwkv is not None:
            per = d * d * 5 + 2 * d * self.d_ff + d * self.d_ff  # approx
            return total + L * per
        for i in range(L):
            # attention
            if self.mla is not None:
                m = self.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                per_attn = (
                    d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d
                )
            else:
                per_attn = d * self.hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * self.hd * d
            # mlp
            if self.moe is not None and i >= self.moe.first_dense:
                mo = self.moe
                per_mlp = (mo.n_routed + mo.n_shared) * 3 * d * mo.d_ff_expert + d * mo.n_routed
            else:
                mult = 3 if self.mlp == "swiglu" else 2
                per_mlp = mult * d * self.d_ff
            total += per_attn + per_mlp
        if self.enc_dec:
            # encoder layers + cross attention in decoder
            per_enc = 4 * d * d + 2 * d * self.d_ff
            total += self.n_enc_layers * per_enc + L * 4 * d * d
        return total

    def tp_friendly(self, tp: int = 16) -> "ArchConfig":
        """Output-preserving TP transform: pad query heads up to a multiple
        of ``tp`` (zero weights) and replicate KV heads up to ``tp`` (tiled
        checkpoint), so attention is fully local per model shard — the
        vLLM-style fix for head counts a 16-way axis does not divide.
        Measured wins: EXPERIMENTS.md §Perf B1/C1. No-op where already
        divisible or for attention-free archs."""
        import dataclasses as _dc
        if self.rwkv is not None or self.mla is not None:
            return self
        hd = self.hd
        nh = -(-self.n_heads // tp) * tp
        kv = self.n_kv_heads
        if kv < tp and self.n_kv_heads != self.n_heads:
            kv = tp
        elif self.n_kv_heads == self.n_heads:
            kv = nh                      # MHA: pad together
        if (nh, kv) == (self.n_heads, self.n_kv_heads):
            return self
        return _dc.replace(self, n_heads=nh, n_kv_heads=min(kv, nh),
                           head_dim=hd)

    def active_params(self) -> int:
        """Active params per token (for MoE MODEL_FLOPS = 6*N_active*D)."""
        if self.moe is None:
            return self.n_params()
        mo = self.moe
        d, L = self.d_model, self.n_layers
        n_moe_layers = L - mo.first_dense
        inactive = (mo.n_routed - mo.top_k) * 3 * d * mo.d_ff_expert * n_moe_layers
        return self.n_params() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason). long_500k only for sub-quadratic archs (see DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k exact-softmax decode cache is out of scope (DESIGN.md §shape-skips)"
    return True, ""
