"""llava-next-34b — VLM backbone; anyres patch frontend stubbed.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

``input_specs`` supplies precomputed patch embeddings (B, n_patches, d)
spliced over the sequence prefix (anyres tiling stub).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    rope_theta=5000000.0,
    n_patches=2880,           # anyres: base + 4 tiles x 576
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="llava-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, n_patches=8, q_chunk=16, kv_chunk=16,
    )
