"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6.
[arXiv:2401.06066; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,               # dense-layer FFN (layer 0)
    vocab=102400,
    moe=MoEConfig(
        n_routed=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared=2,
        first_dense=1,
        capacity_factor=1.25,
    ),
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="dsmoe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, q_chunk=16, kv_chunk=16,
        moe=dataclasses.replace(CONFIG.moe, n_routed=8, top_k=2, d_ff_expert=32,
                                n_shared=1, first_dense=1, group_size=64),
    )
