"""whisper-medium — enc-dec audio; conv frontend stubbed. [arXiv:2212.04356]

``input_specs`` supplies precomputed frame embeddings (B, 1500, d); the
transformer backbone (24 enc + 24 dec layers, d=1024, 16H, LN+GELU) is what
this repo exercises.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    norm="ln",
    mlp="gelu",
    norm_eps=1e-5,
    enc_dec=True,
    n_enc_layers=24,
    n_enc_frames=1500,
    rope_theta=10000.0,   # backbone uses RoPE in this repro (see DESIGN.md)
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="whisper-smoke", n_layers=2, n_enc_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, n_enc_frames=24,
        q_chunk=16, kv_chunk=16,
    )
