"""Composable CausalLM covering all assigned architectures.

A model is a list of *segments*; each segment is ``count`` repetitions of a
*unit* (a short list of LayerSpecs). Uniform stacks (phi3, qwen, internlm,
rwkv, llava) are one segment scanned ``count`` times; deepseek-v3 is
[3 x dense-MLA, 58 x MoE-MLA]; recurrentgemma is [8 x (rec,rec,attn),
1 x (rec,rec)]; whisper adds an encoder stack. Scanning over stacked layer
params keeps XLA compile time flat in depth (critical for the 512-device
dry-run) and remat-wraps each unit.

Three execution modes share one layer implementation:
  train    full sequence, no cache, returns CE loss (+aux)
  prefill  full sequence, emits per-layer caches (ring-buffer for local attn,
           compressed latents for MLA, fp32 state for RG-LRU/RWKV)
  decode   single token against the cache (the `serve_step` of the dry-run)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.param import ParamDecl, init_params, param_shapes
from repro.configs.base import ArchConfig
from repro.distributed.partition import ac
from repro.models.layers import attention as attn_lib
from repro.models.layers import mla as mla_lib
from repro.models.layers import moe as moe_lib
from repro.models.layers import rglru as rglru_lib
from repro.models.layers import rwkv as rwkv_lib
from repro.models.layers.mlp import mlp_apply, mlp_decls
from repro.models.layers.norms import apply_norm, norm_decls
from repro.models.layers.rope import apply_rope


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str          # attn | attn_local | mla | rec | rwkv_att
    mlp: str            # dense | moe | rwkv_ffn
    cross_attn: bool = False   # whisper decoder


@dataclasses.dataclass(frozen=True)
class Segment:
    count: int
    unit: Tuple[LayerSpec, ...]


def build_segments(cfg: ArchConfig) -> List[Segment]:
    if cfg.rwkv is not None:
        return [Segment(cfg.n_layers, (LayerSpec("rwkv_att", "rwkv_ffn"),))]
    if cfg.griffin is not None:
        pat = cfg.griffin.pattern
        unit = tuple(
            LayerSpec("rec" if p == "rec" else "attn_local", "dense")
            for p in pat)
        full, rem = divmod(cfg.n_layers, len(pat))
        segs = [Segment(full, unit)] if full else []
        if rem:
            segs.append(Segment(1, unit[:rem]))
        return segs
    mixer = "mla" if cfg.mla is not None else "attn"
    if cfg.moe is not None:
        fd = cfg.moe.first_dense
        segs = []
        if fd:
            segs.append(Segment(fd, (LayerSpec(mixer, "dense"),)))
        segs.append(Segment(cfg.n_layers - fd, (LayerSpec(mixer, "moe"),)))
        return segs
    return [Segment(cfg.n_layers, (LayerSpec(mixer, "dense",
                                             cross_attn=cfg.enc_dec),))]


# ---------------------------------------------------------------- decls ----

def _mixer_decls(cfg: ArchConfig, spec: LayerSpec):
    if spec.mixer in ("attn", "attn_local"):
        return attn_lib.attn_decls(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.hd, cfg.qkv_bias, cfg.qk_norm,
                                   out_bias=(cfg.norm == "ln"))
    if spec.mixer == "mla":
        return mla_lib.mla_decls(cfg)
    if spec.mixer == "rec":
        return rglru_lib.rglru_decls(cfg)
    if spec.mixer == "rwkv_att":
        return rwkv_lib.timemix_decls(cfg)
    raise ValueError(spec.mixer)


def _mlp_decls(cfg: ArchConfig, spec: LayerSpec):
    if spec.mlp == "dense":
        return mlp_decls(cfg.d_model, cfg.d_ff, cfg.mlp, bias=(cfg.norm == "ln"))
    if spec.mlp == "moe":
        return moe_lib.moe_decls(cfg.d_model, cfg.moe)
    if spec.mlp == "rwkv_ffn":
        return rwkv_lib.chanmix_decls(cfg)
    raise ValueError(spec.mlp)


def _layer_decls(cfg: ArchConfig, spec: LayerSpec):
    d = {
        "norm1": norm_decls(cfg.norm, cfg.d_model),
        "norm2": norm_decls(cfg.norm, cfg.d_model),
        "mixer": _mixer_decls(cfg, spec),
        "mlp": _mlp_decls(cfg, spec),
    }
    if spec.cross_attn:
        d["norm_x"] = norm_decls(cfg.norm, cfg.d_model)
        d["cross"] = attn_lib.attn_decls(
            cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            out_bias=(cfg.norm == "ln"))
    return d


def _stack_decl(d: ParamDecl, count: int) -> ParamDecl:
    return ParamDecl((count,) + d.shape, ("layer",) + d.logical,
                     dtype=d.dtype, init=d.init, scale=d.scale)


def _segment_decls(cfg: ArchConfig, seg: Segment):
    unit = {str(i): _layer_decls(cfg, s) for i, s in enumerate(seg.unit)}
    if seg.count == 1:
        return unit
    return jax.tree.map(lambda d: _stack_decl(d, seg.count), unit,
                        is_leaf=lambda x: isinstance(x, ParamDecl))


def model_decls(cfg: ArchConfig):
    V, d = cfg.padded_vocab, cfg.d_model
    decls: Dict[str, Any] = {
        "embed": ParamDecl((V, d), ("vocab", "embed"), init="embed"),
        "final_norm": norm_decls(cfg.norm, d),
        "segments": [_segment_decls(cfg, s) for s in build_segments(cfg)],
    }
    if not cfg.tie_embeddings:
        decls["lm_head"] = ParamDecl((d, V), ("embed", "vocab"))
    if cfg.enc_dec:
        enc_spec = LayerSpec("attn", "dense")
        enc_seg = Segment(cfg.n_enc_layers, (enc_spec,))
        decls["encoder"] = {
            "segment": _segment_decls(cfg, enc_seg),
            "final_norm": norm_decls(cfg.norm, d),
        }
    if cfg.mtp:
        decls["mtp"] = {
            "proj": ParamDecl((2 * d, d), ("embed", None)),
            "norm_h": norm_decls(cfg.norm, d),
            "norm_e": norm_decls(cfg.norm, d),
            "layer": _layer_decls(cfg, LayerSpec(
                "mla" if cfg.mla is not None else "attn", "dense")),
            "final_norm": norm_decls(cfg.norm, d),
        }
    return decls


# ---------------------------------------------------------------- cache ----

def _layer_cache_decls(cfg: ArchConfig, spec: LayerSpec, B: int, S: int):
    hd, KH = cfg.hd, cfg.n_kv_heads
    if spec.mixer == "attn":
        c = {"k": ParamDecl((B, S, KH * hd), ("batch", "kv_seq", "qkv"), init="zeros"),
             "v": ParamDecl((B, S, KH * hd), ("batch", "kv_seq", "qkv"), init="zeros")}
        if spec.cross_attn:
            Se = cfg.n_enc_frames
            c["xk"] = ParamDecl((B, Se, KH * hd), ("batch", None, "qkv"), init="zeros")
            c["xv"] = ParamDecl((B, Se, KH * hd), ("batch", None, "qkv"), init="zeros")
        return c
    if spec.mixer == "attn_local":
        W = min(cfg.griffin.window, S)
        return {
            "k": ParamDecl((B, W, KH * hd), ("batch", None, "qkv"), init="zeros"),
            "v": ParamDecl((B, W, KH * hd), ("batch", None, "qkv"), init="zeros"),
            "pos": ParamDecl((W,), (None,), dtype=jnp.int32, init="zeros"),
        }
    if spec.mixer == "mla":
        m = cfg.mla
        return {
            "ckv": ParamDecl((B, S, m.kv_lora_rank), ("batch", "kv_seq", None), init="zeros"),
            "kr": ParamDecl((B, S, m.qk_rope_head_dim), ("batch", "kv_seq", None), init="zeros"),
        }
    if spec.mixer == "rec":
        return rglru_lib.rglru_state_decls(cfg, B)
    if spec.mixer == "rwkv_att":
        return rwkv_lib.rwkv_state_decls(cfg, B)
    raise ValueError(spec.mixer)


def cache_decls(cfg: ArchConfig, B: int, S: int):
    segs = build_segments(cfg)
    out = []
    for seg in segs:
        unit = {str(i): _layer_cache_decls(cfg, s, B, S)
                for i, s in enumerate(seg.unit)}
        if seg.count > 1:
            unit = jax.tree.map(lambda d: _stack_decl(d, seg.count), unit,
                                is_leaf=lambda x: isinstance(x, ParamDecl))
        out.append(unit)
    return {"len": ParamDecl((), (), dtype=jnp.int32, init="zeros"),
            "segments": out}


# --------------------------------------------------------------- layers ----

def _apply_attn(cfg: ArchConfig, params, x, positions, mode, cache, cur_len,
                *, local: bool):
    B, S, _ = x.shape
    window = cfg.griffin.window if local else None
    q, k, v = attn_lib.project_qkv(params, x, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.hd, cfg.qk_norm, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    KH, hd = cfg.n_kv_heads, cfg.hd
    new_cache = cache
    if mode == "decode":
        Sc = cache["k"].shape[1]
        kf = k.reshape(B, 1, KH * hd)
        vf = v.reshape(B, 1, KH * hd)
        if local:
            idx = jax.lax.rem(cur_len, Sc)
            kc = jax.lax.dynamic_update_slice(cache["k"], kf, (0, idx, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], vf, (0, idx, 0))
            pos = jax.lax.dynamic_update_slice(
                cache["pos"], cur_len[None].astype(jnp.int32) + 1, (idx,))
            # pos buffer stores (position + 1); 0 means empty
            o = attn_lib.decode_attention_pos(
                q, kc.reshape(B, Sc, KH, hd), vc.reshape(B, Sc, KH, hd),
                pos - 1, cur_len, window)
            new_cache = {"k": kc, "v": vc, "pos": pos}
        else:
            kc = jax.lax.dynamic_update_slice(cache["k"], kf, (0, cur_len, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], vf, (0, cur_len, 0))
            o = attn_lib.decode_attention(
                q, kc.reshape(B, Sc, KH, hd), vc.reshape(B, Sc, KH, hd),
                cur_len + 1)
            new_cache = dict(cache, k=kc, v=vc)
    else:
        impl = cfg.attention_impl if mode != "oracle" else "naive"
        o = attn_lib.attention(
            q, k, v, impl=impl, causal=True, window=window,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        if mode == "prefill":
            if local:
                Sc = cache["k"].shape[1]
                ring, ringpos = _ring_from_seq(
                    k.reshape(B, S, KH * hd), v.reshape(B, S, KH * hd), Sc)
                new_cache = {"k": ring[0], "v": ring[1], "pos": ringpos}
            else:
                Sc = cache["k"].shape[1]
                kf = jnp.zeros_like(cache["k"]).at[:, :S].set(
                    k.reshape(B, S, KH * hd))
                vf = jnp.zeros_like(cache["v"]).at[:, :S].set(
                    v.reshape(B, S, KH * hd))
                new_cache = dict(cache, k=kf, v=vf)
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), params["w_o"])
    if "b_o" in params:
        out = out + params["b_o"]
    return out, new_cache


def _ring_from_seq(kf, vf, W: int):
    """Fold the last W positions of (B,S,F) k/v into ring-buffer layout."""
    B, S, F = kf.shape
    i = jnp.arange(W)
    # largest position p <= S-1 with p ≡ i (mod W); may be negative if S < W
    p = i + ((S - 1 - i) // W) * W
    valid = p >= 0
    pc = jnp.clip(p, 0, S - 1)
    kr = jnp.where(valid[None, :, None], kf[:, pc], 0)
    vr = jnp.where(valid[None, :, None], vf[:, pc], 0)
    pos = jnp.where(valid, p + 1, 0).astype(jnp.int32)   # store pos+1; 0=empty
    return (kr, vr), pos


def _apply_cross_attn(cfg: ArchConfig, params, x, enc_out, mode, cache):
    """Whisper decoder cross-attention (no rope, bidirectional over frames)."""
    B, S, _ = x.shape
    KH, hd = cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,de->bse", x, params["w_q"]).reshape(B, S, cfg.n_heads, hd)
    if mode == "decode":
        k = cache["xk"].reshape(B, -1, KH, hd)
        v = cache["xv"].reshape(B, -1, KH, hd)
        new_cache = cache
        o = attn_lib.naive_attention(q, k, v, causal=False)
    else:
        k = jnp.einsum("bsd,de->bse", enc_out, params["w_k"]).reshape(
            B, -1, KH, hd)
        v = jnp.einsum("bsd,de->bse", enc_out, params["w_v"]).reshape(
            B, -1, KH, hd)
        o = attn_lib.attention(q, k, v, impl="chunked", causal=False,
                               q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        new_cache = None
        if mode == "prefill" and cache is not None:
            new_cache = dict(cache,
                             xk=k.reshape(B, -1, KH * hd),
                             xv=v.reshape(B, -1, KH * hd))
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), params["w_o"])
    if "b_o" in params:
        out = out + params["b_o"]
    return out, new_cache


def _apply_mixer(cfg, spec, params, x, positions, mode, cache, cur_len):
    if spec.mixer in ("attn", "attn_local"):
        return _apply_attn(cfg, params, x, positions, mode, cache, cur_len,
                           local=spec.mixer == "attn_local")
    if spec.mixer == "mla":
        if mode == "decode":
            Sc = cache["ckv"].shape[1]
            # write latents for current token, then absorbed attention
            _, _, c_kv, k_rope = mla_lib._latents(params, x, cfg, positions)
            ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_kv, (0, cur_len, 0))
            kr = jax.lax.dynamic_update_slice(cache["kr"], k_rope, (0, cur_len, 0))
            out = mla_lib.mla_decode(params, x, cfg, ckv, kr, cur_len + 1,
                                     positions)
            return out, {"ckv": ckv, "kr": kr}
        out, (c_kv, k_rope) = mla_lib.mla_prefill(
            params, x, cfg, positions,
            impl="chunked" if cfg.attention_impl != "naive" else "naive")
        if mode == "prefill":
            S = x.shape[1]
            ckv = jnp.zeros_like(cache["ckv"]).at[:, :S].set(c_kv)
            kr = jnp.zeros_like(cache["kr"]).at[:, :S].set(k_rope)
            return out, {"ckv": ckv, "kr": kr}
        return out, cache
    if spec.mixer == "rec":
        state = cache if mode == "decode" else None
        out, new_state = rglru_lib.rglru_block_apply(params, x, cfg, state)
        return out, (new_state if mode in ("decode", "prefill") else cache)
    if spec.mixer == "rwkv_att":
        state = cache if mode == "decode" else None
        out, new_state = rwkv_lib.timemix_apply(params, x, cfg, state)
        return out, (new_state if mode in ("decode", "prefill") else cache)
    raise ValueError(spec.mixer)


def _apply_mlp(cfg, spec, params, x, mode, cache):
    if spec.mlp == "dense":
        return mlp_apply(params, x, cfg.mlp), cache, 0.0
    if spec.mlp == "moe":
        out, aux = moe_lib.moe_apply(params, x, cfg.moe, cfg.norm_eps)
        return out, cache, aux
    if spec.mlp == "rwkv_ffn":
        state = cache if mode == "decode" else None
        out, new_state = rwkv_lib.chanmix_apply(params, x, state)
        return out, (new_state if mode in ("decode", "prefill") else cache), 0.0
    raise ValueError(spec.mlp)


def _apply_layer(cfg, spec: LayerSpec, params, x, positions, mode,
                 cache, cur_len, enc_out):
    mixer_cache = None if cache is None else cache.get("mixer")
    mlp_cache = None if cache is None else cache.get("mlp")
    x = ac(x, "batch", None, None)
    h = apply_norm(cfg.norm, params["norm1"], x, cfg.norm_eps)
    mo, new_mixer_cache = _apply_mixer(cfg, spec, params["mixer"], h,
                                       positions, mode, mixer_cache, cur_len)
    x = ac(x + mo, "batch", None, None)
    if spec.cross_attn:
        hx = apply_norm(cfg.norm, params["norm_x"], x, cfg.norm_eps)
        xo, new_mixer_cache2 = _apply_cross_attn(
            cfg, params["cross"], hx, enc_out, mode,
            new_mixer_cache if mode in ("prefill", "decode") else None)
        x = x + xo
        if mode in ("prefill", "decode") and new_mixer_cache2 is not None:
            new_mixer_cache = new_mixer_cache2
    h2 = apply_norm(cfg.norm, params["norm2"], x, cfg.norm_eps)
    fo, new_mlp_cache, aux = _apply_mlp(cfg, spec, params["mlp"], h2, mode,
                                        mlp_cache)
    x = ac(x + fo, "batch", None, None)
    new_cache = None
    if cache is not None:
        new_cache = {"mixer": new_mixer_cache, "mlp": new_mlp_cache}
    return x, new_cache, aux


# ------------------------------------------------- unrolled decode path ----
# Decode does NOT scan over layers: scanning makes the per-layer cache a
# scan ys, and stacking ys rewrites a full layer cache (e.g. 268 MB/chip at
# deepseek-v3 decode_32k) per layer for a one-token update — and defeats
# input/output aliasing, adding a full zero-init of the stacked buffer.
# Unrolling lets every layer issue one tiny dynamic-update-slice into the
# *donated* stacked cache, which XLA aliases in place.
# (EXPERIMENTS.md §Perf iteration A2: t_mem 1.94s -> ~0.03s.)

def _dus(buf, update, idxs):
    return jax.lax.dynamic_update_slice(buf, update.astype(buf.dtype), idxs)


def _decode_layer_inplace(cfg: ArchConfig, spec: LayerSpec, params, x,
                          positions, lc, li, cur_len, enc_out):
    """One unrolled decode layer; lc maps names -> stacked (L, ...) arrays.
    Returns (x, lc) with in-place-style updates at layer index ``li``."""
    B = x.shape[0]
    KH, hd = cfg.n_kv_heads, cfg.hd
    zero = jnp.int32(0)
    h = apply_norm(cfg.norm, params["norm1"], x, cfg.norm_eps)

    if spec.mixer in ("attn", "attn_local"):
        ap = params["mixer"]
        q, k, v = attn_lib.project_qkv(ap, h, cfg.n_heads, KH, hd,
                                       cfg.qk_norm, cfg.norm_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kf = k.reshape(B, 1, KH * hd)[None]
        vf = v.reshape(B, 1, KH * hd)[None]
        if spec.mixer == "attn_local":
            W = lc["k"].shape[2]
            idx = jax.lax.rem(cur_len, W)
            lc = dict(lc,
                      k=_dus(lc["k"], kf, (li, zero, idx, zero)),
                      v=_dus(lc["v"], vf, (li, zero, idx, zero)),
                      pos=_dus(lc["pos"], cur_len[None, None] + 1,
                               (li, idx)))
            o = attn_lib.decode_attention_pos(
                q, lc["k"][li].reshape(B, W, KH, hd),
                lc["v"][li].reshape(B, W, KH, hd),
                lc["pos"][li] - 1, cur_len, cfg.griffin.window)
        else:
            Sc = lc["k"].shape[2]
            lc = dict(lc,
                      k=_dus(lc["k"], kf, (li, zero, cur_len, zero)),
                      v=_dus(lc["v"], vf, (li, zero, cur_len, zero)))
            o = attn_lib.decode_attention(
                q, lc["k"][li].reshape(B, Sc, KH, hd),
                lc["v"][li].reshape(B, Sc, KH, hd), cur_len + 1)
        mo = jnp.einsum("bse,ed->bsd", o.reshape(B, 1, -1), ap["w_o"])
        if "b_o" in ap:
            mo = mo + ap["b_o"]
    elif spec.mixer == "mla":
        ap = params["mixer"]
        _, _, c_kv, k_rope = mla_lib._latents(ap, h, cfg, positions)
        lc = dict(lc,
                  ckv=_dus(lc["ckv"], c_kv[None], (li, zero, cur_len, zero)),
                  kr=_dus(lc["kr"], k_rope[None], (li, zero, cur_len, zero)))
        mo = mla_lib.mla_decode(ap, h, cfg, lc["ckv"][li], lc["kr"][li],
                                cur_len + 1, positions)
    elif spec.mixer == "rec":
        state = {"h": lc["h"][li], "conv": lc["conv"][li]}
        mo, ns = rglru_lib.rglru_block_apply(params["mixer"], h, cfg, state)
        lc = dict(lc,
                  h=_dus(lc["h"], ns["h"][None], (li, zero, zero)),
                  conv=_dus(lc["conv"], ns["conv"][None],
                            (li, zero, zero, zero)))
    elif spec.mixer == "rwkv_att":
        state = {"x_prev": lc["att"]["x_prev"][li], "S": lc["att"]["S"][li]}
        mo, ns = rwkv_lib.timemix_apply(params["mixer"], h, cfg, state)
        lc = dict(lc, att={
            "x_prev": _dus(lc["att"]["x_prev"], ns["x_prev"][None],
                           (li, zero, zero)),
            "S": _dus(lc["att"]["S"], ns["S"][None],
                      (li, zero, zero, zero, zero))})
    else:
        raise ValueError(spec.mixer)
    x = x + mo

    if spec.cross_attn:
        hx = apply_norm(cfg.norm, params["norm_x"], x, cfg.norm_eps)
        cp = params["cross"]
        q = jnp.einsum("bsd,de->bse", hx, cp["w_q"]).reshape(
            B, 1, cfg.n_heads, hd)
        o = attn_lib.decode_attention(
            q, lc["xk"][li].reshape(B, -1, KH, hd),
            lc["xv"][li].reshape(B, -1, KH, hd),
            jnp.asarray(lc["xk"].shape[2], jnp.int32))
        xo = jnp.einsum("bse,ed->bsd", o.reshape(B, 1, -1), cp["w_o"])
        if "b_o" in cp:
            xo = xo + cp["b_o"]
        x = x + xo

    h2 = apply_norm(cfg.norm, params["norm2"], x, cfg.norm_eps)
    if spec.mlp == "rwkv_ffn":
        state = {"x_prev": lc["ffn"]["x_prev"][li]}
        fo, ns = rwkv_lib.chanmix_apply(params["mlp"], h2, state)
        lc = dict(lc, ffn={"x_prev": _dus(lc["ffn"]["x_prev"],
                                          ns["x_prev"][None],
                                          (li, zero, zero))})
    elif spec.mlp == "moe":
        fo, _ = moe_lib.moe_apply(params["mlp"], h2, cfg.moe, cfg.norm_eps)
    else:
        fo = mlp_apply(params["mlp"], h2, cfg.mlp)
    return x + fo, lc


def _decode_segment_unrolled(cfg, seg: Segment, seg_params, seg_cache, x,
                             positions, cur_len, enc_out):
    cache = {str(i): seg_cache[str(i)] for i in range(len(seg.unit))}
    for li in range(seg.count):
        up = jax.tree.map(lambda a: a[li], seg_params)
        for i, spec in enumerate(seg.unit):
            x, cache[str(i)] = _decode_layer_inplace(
                cfg, spec, up[str(i)], x, positions, cache[str(i)], li,
                cur_len, enc_out)
    return x, cache


# -------------------------------------------------------------- backbone ---

def _restructure_cache(cfg: ArchConfig, seg_cache, unit):
    """Insert the {"mixer","mlp"} split used by _apply_layer."""
    out = {}
    for i, spec in enumerate(unit):
        lc = seg_cache[str(i)]
        if spec.mlp == "rwkv_ffn":
            out[str(i)] = {"mixer": lc["att"], "mlp": lc["ffn"]}
        else:
            out[str(i)] = {"mixer": lc, "mlp": None}
    return out


def _flatten_cache(unit, cache):
    out = {}
    for i, spec in enumerate(unit):
        lc = cache[str(i)]
        if spec.mlp == "rwkv_ffn":
            out[str(i)] = {"att": lc["mixer"], "ffn": lc["mlp"]}
        else:
            out[str(i)] = lc["mixer"]
    return out


def apply_backbone(cfg: ArchConfig, params, x, positions, mode,
                   cache=None, cur_len=None, enc_out=None):
    """x: (B,S,d) embedded inputs. Returns (h, new_cache, aux_sum)."""
    segs = build_segments(cfg)
    new_seg_caches = []
    aux_total = 0.0

    for si, seg in enumerate(segs):
        seg_params = params["segments"][si]
        seg_cache = None if cache is None else cache["segments"][si]

        def unit_fn(xa, unit_params, unit_cache, seg=seg):
            xx, aux_sum = xa
            ncache = {} if unit_cache is not None else None
            for i, spec in enumerate(seg.unit):
                lc = None if unit_cache is None else unit_cache[str(i)]
                xx, nc, aux = _apply_layer(cfg, spec, unit_params[str(i)], xx,
                                           positions, mode, lc, cur_len,
                                           enc_out)
                if ncache is not None:
                    ncache[str(i)] = nc
            return (xx, aux_sum + aux), ncache

        if seg.count == 1:
            uc = (None if seg_cache is None
                  else _restructure_cache(cfg, seg_cache, seg.unit))
            (x, aux_total), nc = unit_fn((x, aux_total), seg_params, uc)
            new_seg_caches.append(
                None if nc is None else _flatten_cache(seg.unit, nc))
        else:
            if mode == "train" or cache is None:
                def body(carry, up):
                    return (jax.checkpoint(unit_fn)(carry, up, None)[0]
                            if cfg.remat else unit_fn(carry, up, None)[0]), None
                (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                                 seg_params)
                new_seg_caches.append(None)
            elif mode == "decode":
                # unrolled in-place path (see _decode_segment_unrolled)
                x, nc = _decode_segment_unrolled(
                    cfg, seg, seg_params, seg_cache, x, positions, cur_len,
                    enc_out)
                new_seg_caches.append(nc)
            else:
                rc = _restructure_cache(cfg, seg_cache, seg.unit)

                def body(carry, xs):
                    up, uc = xs
                    fn = jax.checkpoint(unit_fn) if (
                        cfg.remat and mode != "decode") else unit_fn
                    carry, nc = fn(carry, up, uc)
                    return carry, nc

                (x, aux_total), ncs = jax.lax.scan(body, (x, aux_total),
                                                   (seg_params, rc))
                new_seg_caches.append(_flatten_cache(seg.unit, ncs))

    h = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    new_cache = None
    if cache is not None:
        new_cache = {"len": cache["len"], "segments": new_seg_caches}
    return h, new_cache, aux_total


def apply_encoder(cfg: ArchConfig, params, frames):
    """Whisper encoder over stub frame embeddings (B,T,d), bidirectional."""
    enc_spec = LayerSpec("attn", "dense")
    x = frames
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def unit_fn(xx, up):
        h = apply_norm(cfg.norm, up["0"]["norm1"], xx, cfg.norm_eps)
        q, k, v = attn_lib.project_qkv(up["0"]["mixer"], h, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.hd, cfg.qk_norm,
                                       cfg.norm_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attn_lib.attention(q, k, v, impl="chunked", causal=False,
                               q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        o = jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1),
                       up["0"]["mixer"]["w_o"])
        if "b_o" in up["0"]["mixer"]:
            o = o + up["0"]["mixer"]["b_o"]
        xx = xx + o
        h2 = apply_norm(cfg.norm, up["0"]["norm2"], xx, cfg.norm_eps)
        xx = xx + mlp_apply(up["0"]["mlp"], h2, cfg.mlp)
        return xx, None

    def body(xx, up):
        fn = jax.checkpoint(unit_fn) if cfg.remat else unit_fn
        return fn(xx, up)

    x, _ = jax.lax.scan(body, x, params["encoder"]["segment"])
    return apply_norm(cfg.norm, params["encoder"]["final_norm"], x,
                      cfg.norm_eps)


# ----------------------------------------------------------------- model ---

def _soft_cap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


class Model:
    """Functional model facade. All methods are pure (jit-able)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- declarations --------------------------------------------------
    def param_decls(self):
        return model_decls(self.cfg)

    def init(self, rng):
        return init_params(self.param_decls(), rng)

    def param_sds(self):
        return param_shapes(self.param_decls())

    def cache_decls(self, batch: int, max_len: int):
        return cache_decls(self.cfg, batch, max_len)

    # -- embedding / frontends ------------------------------------------
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = ac(jnp.take(params["embed"], tokens, axis=0), "batch", None, None)
        if cfg.n_patches and "patch_embeds" in batch:
            P = min(cfg.n_patches, x.shape[1])
            x = x.at[:, :P].set(batch["patch_embeds"][:, :P].astype(x.dtype))
        return x

    def _head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    # -- training --------------------------------------------------------
    def loss(self, params, batch, *, loss_chunk: int = 512):
        """batch: tokens (B,S) int32, labels (B,S) int32 (-1 = pad),
        optional frames / patch_embeds. Returns (loss, metrics)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        enc_out = None
        if cfg.enc_dec:
            enc_out = apply_encoder(cfg, params, batch["frames"])
        h, _, aux = apply_backbone(cfg, params, x, positions, "train",
                                   enc_out=enc_out)
        ce, z = self._chunked_ce(params, h, batch["labels"], loss_chunk)
        loss = ce + z + aux
        metrics = {"ce": ce, "z_loss": z, "aux_loss": aux}
        if cfg.mtp:
            mtp_loss = self._mtp_loss(params, h, batch, positions)
            loss = loss + 0.3 * mtp_loss
            metrics["mtp"] = mtp_loss
        return loss, metrics

    def _chunked_ce(self, params, h, labels, chunk: int):
        """Seq-chunked CE: never materializes (B,S,V) logits."""
        cfg = self.cfg
        head = self._head(params)
        B, S, d = h.shape
        c = min(chunk, S)
        n = S // c if S % c == 0 else -(-S // c)
        Sp = n * c
        if Sp != S:
            h = jnp.pad(h, ((0, 0), (0, Sp - S), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, Sp - S)),
                             constant_values=-1)
        hc = h.reshape(B, n, c, d).swapaxes(0, 1)
        lc = labels.reshape(B, n, c).swapaxes(0, 1)

        def step(carry, xs):
            hh, ll = xs
            logits = ac(jnp.einsum("bcd,dv->bcv", hh, head),
                        "batch", None, "vocab").astype(jnp.float32)
            logits = _soft_cap(logits, cfg.logits_soft_cap)
            lse = jax.nn.logsumexp(logits, axis=-1)
            lbl = jnp.clip(ll, 0)
            lbl_logit = jnp.take_along_axis(
                logits, lbl[..., None], axis=-1)[..., 0]
            w = (ll >= 0).astype(jnp.float32)
            ce_sum = jnp.sum((lse - lbl_logit) * w)
            z_sum = jnp.sum(jnp.square(lse) * w)
            n_tok = jnp.sum(w)
            a, b, cnt = carry
            return (a + ce_sum, b + z_sum, cnt + n_tok), None

        fn = jax.checkpoint(step) if cfg.remat else step
        (ce_sum, z_sum, n_tok), _ = jax.lax.scan(
            fn, (0.0, 0.0, 0.0), (hc, lc))
        n_tok = jnp.maximum(n_tok, 1.0)
        return ce_sum / n_tok, 1e-4 * z_sum / n_tok

    def _mtp_loss(self, params, h, batch, positions):
        """deepseek-v3 MTP (depth 1): predict token t+2 from [h_t; emb_{t+1}]."""
        cfg = self.cfg
        mp = params["mtp"]
        tokens, labels = batch["tokens"], batch["labels"]
        emb_next = jnp.take(params["embed"], jnp.roll(tokens, -1, axis=1),
                            axis=0)
        hh = apply_norm(cfg.norm, mp["norm_h"], h, cfg.norm_eps)
        ee = apply_norm(cfg.norm, mp["norm_e"], emb_next, cfg.norm_eps)
        z = jnp.einsum("bsd,dk->bsk", jnp.concatenate([hh, ee], -1),
                       mp["proj"])
        spec = LayerSpec("mla" if cfg.mla is not None else "attn", "dense")
        z, _, _ = _apply_layer(cfg, spec, mp["layer"], z, positions, "train",
                               None, None, None)
        z = apply_norm(cfg.norm, mp["final_norm"], z, cfg.norm_eps)
        labels2 = jnp.roll(labels, -1, axis=1).at[:, -2:].set(-1)
        ce, _ = self._chunked_ce(params, z, labels2, 512)
        return ce

    # -- serving ----------------------------------------------------------
    def prefill(self, params, batch, cache):
        """Fill the cache from a prompt; returns (cache, last_logits)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        enc_out = None
        if cfg.enc_dec:
            enc_out = apply_encoder(cfg, params, batch["frames"])
        h, new_cache, _ = apply_backbone(cfg, params, x, positions, "prefill",
                                         cache=cache, cur_len=jnp.int32(0),
                                         enc_out=enc_out)
        new_cache["len"] = jnp.asarray(S, jnp.int32)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], self._head(params))
        return new_cache, _soft_cap(logits.astype(jnp.float32),
                                    cfg.logits_soft_cap)

    def decode_step(self, params, cache, token):
        """One serving step. token: (B,1) int32. Returns (logits, cache)."""
        cfg = self.cfg
        cur_len = cache["len"]
        x = jnp.take(params["embed"], token, axis=0)
        B = x.shape[0]
        positions = jnp.broadcast_to(cur_len[None, None], (B, 1))
        h, new_cache, _ = apply_backbone(cfg, params, x, positions, "decode",
                                         cache=cache, cur_len=cur_len)
        new_cache["len"] = cur_len + 1
        logits = jnp.einsum("bd,dv->bv", h[:, 0], self._head(params))
        return _soft_cap(logits.astype(jnp.float32),
                         cfg.logits_soft_cap), new_cache

    # -- AL hooks ----------------------------------------------------------
    def embed_pool(self, params, batch):
        """Mean-pooled final hidden state (B,d) — diversity strategies."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        enc_out = None
        if cfg.enc_dec:
            enc_out = apply_encoder(cfg, params, batch["frames"])
        h, _, _ = apply_backbone(cfg, params, x, positions, "train",
                                 enc_out=enc_out)
        mask = (batch["tokens"] >= 0).astype(h.dtype)[..., None]
        return jnp.sum(h * mask, axis=1) / jnp.maximum(jnp.sum(mask, 1), 1)

    def last_logits(self, params, batch):
        """Last-position logits (B,V) — uncertainty strategies."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        enc_out = None
        if cfg.enc_dec:
            enc_out = apply_encoder(cfg, params, batch["frames"])
        h, _, _ = apply_backbone(cfg, params, x, positions, "train",
                                 enc_out=enc_out)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], self._head(params))
        return _soft_cap(logits.astype(jnp.float32), cfg.logits_soft_cap)
