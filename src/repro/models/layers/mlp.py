"""Dense MLP blocks: SwiGLU / GELU. Weights kept 2-D for clean TP sharding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.param import ParamDecl
from repro.distributed.partition import ac


def mlp_decls(d_model: int, d_ff: int, kind: str, bias: bool = False):
    decls = {
        "w_in": ParamDecl((d_model, d_ff), ("embed", "ff")),
        "w_out": ParamDecl((d_ff, d_model), ("ff", "embed")),
    }
    if kind == "swiglu":
        decls["w_gate"] = ParamDecl((d_model, d_ff), ("embed", "ff"))
    if bias:
        decls["b_in"] = ParamDecl((d_ff,), ("ff",), init="zeros")
        decls["b_out"] = ParamDecl((d_model,), ("norm",), init="zeros")
    return decls


def mlp_apply(params, x, kind: str):
    lg = ("batch",) + (None,) * (x.ndim - 2) + ("ff",)
    h = ac(jnp.einsum("...d,df->...f", x, params["w_in"]), *lg)
    if "b_in" in params:
        h = h + params["b_in"]
    if kind == "swiglu":
        g = ac(jnp.einsum("...d,df->...f", x, params["w_gate"]), *lg)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("...f,fd->...d", h, params["w_out"])
    if "b_out" in params:
        out = out + params["b_out"]
    return out
