"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Temporal mixing: y = W_out( GeLU(W_gate x) * RGLRU(conv1d_4(W_x x)) ).
The linear recurrence h_t = a_t*h_{t-1} + sqrt(1-a_t^2)*(i_t*u_t) is run with
``jax.lax.associative_scan`` (parallel, O(S log S)) for train/prefill and a
single fused step for decode — this O(1)-state path is why the arch runs the
long_500k shape.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.param import ParamDecl
from repro.configs.base import ArchConfig

_C_CONST = 8.0


def rglru_decls(cfg: ArchConfig):
    g = cfg.griffin
    d, W = cfg.d_model, g.lru_width
    H = cfg.n_heads
    bw = W // H                      # block width for block-diagonal gates
    return {
        "w_x": ParamDecl((d, W), ("embed", "tp")),
        "w_gate": ParamDecl((d, W), ("embed", "tp")),
        "w_out": ParamDecl((W, d), ("tp", "embed")),
        "conv_w": ParamDecl((g.conv_width, W), ("stack", "tp"), scale=0.1),
        "conv_b": ParamDecl((W,), ("tp",), init="zeros"),
        # block-diagonal input/recurrence gates (H blocks)
        "gate_a_w": ParamDecl((H, bw, bw), ("heads", None, None)),
        "gate_a_b": ParamDecl((H, bw), ("heads", None), init="zeros"),
        "gate_x_w": ParamDecl((H, bw, bw), ("heads", None, None)),
        "gate_x_b": ParamDecl((H, bw), ("heads", None), init="zeros"),
        # Lambda: initialized so a = sigmoid(L) in (0.9, 0.999)
        "lam": ParamDecl((W,), ("norm",), init="uniform", scale=1.0),
    }


def _gates(params, u, H: int):
    """u: (B,S,W) -> (log_a, gated_in) both (B,S,W) fp32."""
    B, S, W = u.shape
    bw = W // H
    ub = u.reshape(B, S, H, bw).astype(jnp.float32)
    r = jax.nn.sigmoid(
        jnp.einsum("bshw,hwv->bshv", ub, params["gate_a_w"].astype(jnp.float32))
        + params["gate_a_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(
        jnp.einsum("bshw,hwv->bshv", ub, params["gate_x_w"].astype(jnp.float32))
        + params["gate_x_b"].astype(jnp.float32))
    r = r.reshape(B, S, W)
    i = i.reshape(B, S, W)
    lam = params["lam"].astype(jnp.float32)
    # log a_t = c * r_t * log sigmoid(Lambda)   (<= 0)
    log_a = -_C_CONST * r * jax.nn.softplus(-lam)
    gated = i * u.astype(jnp.float32)
    return log_a, gated


def conv1d_causal(params, u, state=None):
    """Depthwise causal conv, width K. u: (B,S,W). state: (B,K-1,W) or None.

    Returns (out, new_state) where new_state holds the last K-1 inputs.
    """
    K = params["conv_w"].shape[0]
    B, S, W = u.shape
    if state is None:
        state = jnp.zeros((B, K - 1, W), u.dtype)
    xs = jnp.concatenate([state, u], axis=1)          # (B, S+K-1, W)
    out = jnp.zeros((B, S, W), jnp.float32)
    for i in range(K):
        w_i = params["conv_w"][K - 1 - i].astype(jnp.float32)
        out = out + xs[:, i : i + S].astype(jnp.float32) * w_i
    out = out + params["conv_b"].astype(jnp.float32)
    new_state = xs[:, S:]
    return out.astype(u.dtype), new_state


def rglru_scan(log_a, gated, h0=None):
    """Associative linear recurrence. All (B,S,W) fp32; h0: (B,W) or None."""
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 0.0)) * gated
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block_apply(params, x, cfg: ArchConfig, state=None
                      ) -> Tuple[jax.Array, dict]:
    """Temporal-mix forward. x: (B,S,d). state: None or
    {"h": (B,W), "conv": (B,K-1,W)}. Returns (y, new_state)."""
    u = jnp.einsum("bsd,dw->bsw", x, params["w_x"])
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, params["w_gate"]).astype(jnp.float32))
    u, conv_state = conv1d_causal(
        params, u, None if state is None else state["conv"])
    log_a, gated = _gates(params, u, cfg.n_heads)
    h0 = None if state is None else state["h"]
    h = rglru_scan(log_a, gated, h0)
    y = (gate * h).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, params["w_out"])
    new_state = {"h": h[:, -1].astype(jnp.float32), "conv": conv_state}
    return out, new_state


def rglru_state_decls(cfg: ArchConfig, batch: int):
    g = cfg.griffin
    return {
        "h": ParamDecl((batch, g.lru_width), ("batch", "tp"),
                       dtype=jnp.float32, init="zeros"),
        "conv": ParamDecl((batch, g.conv_width - 1, g.lru_width),
                          ("batch", None, "tp"), init="zeros"),
    }
