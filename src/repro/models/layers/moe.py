"""Token-choice MoE with capacity-based dispatch/combine einsums (t5x-style).

Expert weights carry the "expert" logical axis -> sharded over the `model`
mesh axis (EP); the dispatch/combine einsums against expert-sharded weights
are what induce the all-to-all / reduce-scatter collectives in SPMD.

Shared experts (deepseek fine-grained MoE) run as a plain dense SwiGLU with
d_ff = n_shared * d_ff_expert.

Capacity math: tokens are reshaped to (G, group_size); per group each expert
accepts C = ceil(group_size * top_k / n_routed * capacity_factor) tokens;
overflow tokens are dropped (standard token-choice behaviour; the router
aux-loss keeps the drop rate low).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.param import ParamDecl
from repro.configs.base import MoEConfig
from repro.distributed.partition import ac


def moe_decls(d_model: int, mo: MoEConfig):
    E, F = mo.n_routed, mo.d_ff_expert
    decls = {
        "router": ParamDecl((d_model, E), ("embed", "expert"), dtype=jnp.float32),
        "w_in": ParamDecl((E, d_model, F), ("expert", "embed", "ff")),
        "w_gate": ParamDecl((E, d_model, F), ("expert", "embed", "ff")),
        "w_out": ParamDecl((E, F, d_model), ("expert", "ff", "embed")),
    }
    if mo.n_shared:
        Fs = mo.n_shared * F
        decls["shared"] = {
            "w_in": ParamDecl((d_model, Fs), ("embed", "ff")),
            "w_gate": ParamDecl((d_model, Fs), ("embed", "ff")),
            "w_out": ParamDecl((Fs, d_model), ("ff", "embed")),
        }
    return decls


def capacity(mo: MoEConfig, group_size: int) -> int:
    c = math.ceil(group_size * mo.top_k / mo.n_routed * mo.capacity_factor)
    return max(int(c), mo.top_k)


def _dispatch_combine(router_probs, mo: MoEConfig, C: int):
    """router_probs: (G,S,E) fp32 -> dispatch (G,S,E,C) bool-ish, combine fp32.

    Priority = top-k rank then sequence position (t5x convention). Built by
    iterating over the K choices so no (G,S,K,E,C) tensor is materialized.
    """
    G, S, E = router_probs.shape
    topv, topi = jax.lax.top_k(router_probs, mo.top_k)      # (G,S,K)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    fill = jnp.zeros((G, E), jnp.int32)
    dispatch = jnp.zeros((G, S, E, C), jnp.bool_)
    combine = jnp.zeros((G, S, E, C), jnp.float32)
    for k in range(mo.top_k):
        idx = topi[:, :, k]                                  # (G,S)
        mask = jax.nn.one_hot(idx, E, dtype=jnp.int32)       # (G,S,E)
        pos = fill[:, None, :] + jnp.cumsum(mask, axis=1) - mask  # pos within expert
        ok = (pos < C) & (mask > 0)
        oh = jax.nn.one_hot(jnp.where(ok, pos, C), C + 1, dtype=jnp.float32)[..., :C]
        d_k = oh * ok[..., None]
        dispatch |= d_k.astype(bool)
        combine = combine + d_k * topv[:, :, k][..., None, None]
        fill = fill + jnp.sum(mask * ok.astype(jnp.int32), axis=1)
    return dispatch, combine, topi, topv


def moe_apply(params, x, mo: MoEConfig, norm_eps: float = 1e-6
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (out, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    gs = min(mo.group_size, T)
    G = T // gs
    assert G * gs == T, f"tokens {T} not divisible by group {gs}"
    xt = x.reshape(G, gs, d)

    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    C = capacity(mo, gs)
    dispatch, combine, topi, _ = _dispatch_combine(probs, mo, C)

    # load-balance aux loss (Switch-style): E * sum(frac_tokens * frac_probs)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    route_mask = jnp.sum(
        jax.nn.one_hot(topi, mo.n_routed, dtype=jnp.float32), axis=2)  # (G,S,E)
    frac_tokens = jnp.mean(route_mask, axis=(0, 1)) / mo.top_k
    aux = mo.n_routed * jnp.sum(frac_probs * frac_tokens) * mo.aux_loss_alpha

    disp = ac(dispatch.astype(x.dtype), "batch", None, "expert", None)
    ein = ac(jnp.einsum("gsec,gsd->egcd", disp, xt),
             "expert", "batch", None, None)                  # (E,G,C,d) - a2a
    h = ac(jnp.einsum("egcd,edf->egcf", ein, params["w_in"]),
           "expert", "batch", None, None)
    g = jnp.einsum("egcd,edf->egcf", ein, params["w_gate"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    eout = ac(jnp.einsum("egcf,efd->egcd", h, params["w_out"]),
              "expert", "batch", None, None)                 # (E,G,C,d)
    out = ac(jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), eout),
             "batch", None, None)

    if "shared" in params:
        sp = params["shared"]
        hs = jnp.einsum("gsd,df->gsf", xt, sp["w_in"])
        gsh = jnp.einsum("gsd,df->gsf", xt, sp["w_gate"])
        hs = jax.nn.silu(gsh.astype(jnp.float32)).astype(hs.dtype) * hs
        out = out + jnp.einsum("gsf,fd->gsd", hs, sp["w_out"])

    return out.reshape(B, S, d), aux


def router_entropy(params, x, mo: MoEConfig):
    """Mean router entropy — exposed as a beyond-paper AL uncertainty signal."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    p = jax.nn.softmax(logits, axis=-1)
    return -jnp.sum(p * jnp.log(p + 1e-9), axis=-1)
