"""Multi-head Latent Attention (deepseek-v3).

Train/prefill expand the compressed latents into full per-head K/V and reuse
the generic chunked attention. Decode uses the *absorbed* formulation: scores
and outputs are computed directly against the (B, S, kv_lora_rank) latent
cache — this is the KV-cache compression that makes MLA serving cheap
(cache/token = kv_lora_rank + qk_rope_head_dim instead of 2*H*head_dim).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.dots import einsum_f32
from repro.common.param import ParamDecl
from repro.configs.base import ArchConfig
from repro.models.layers.attention import chunked_attention, naive_attention, NEG_INF
from repro.models.layers.norms import rms_decls, rmsnorm
from repro.models.layers.rope import apply_rope


def mla_decls(cfg: ArchConfig):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": ParamDecl((d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": rms_decls(m.q_lora_rank),
        "w_uq": ParamDecl((m.q_lora_rank, H * qk), ("lora", "qkv")),
        "w_dkv": ParamDecl((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "lora")),
        "kv_norm": rms_decls(m.kv_lora_rank),
        "w_uk": ParamDecl((m.kv_lora_rank, H * m.qk_nope_head_dim), ("lora", "qkv")),
        "w_uv": ParamDecl((m.kv_lora_rank, H * m.v_head_dim), ("lora", "qkv")),
        "w_o": ParamDecl((H * m.v_head_dim, d), ("qkv", "embed")),
    }


def _latents(params, x, cfg: ArchConfig, positions):
    """Shared Q/KV-latent computation. x: (B,S,d)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = rmsnorm(params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["w_dq"]),
                 cfg.norm_eps)
    q = jnp.einsum("bsr,re->bse", cq, params["w_uq"]).reshape(B, S, H, qk)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    dkv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_kv = rmsnorm(params["kv_norm"], dkv[..., : m.kv_lora_rank], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., m.kv_lora_rank:], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_prefill(params, x, cfg: ArchConfig, positions, impl: str = "chunked"):
    """Returns (out, (c_kv, k_rope)) — the latter is the (compressed) cache."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope, c_kv, k_rope = _latents(params, x, cfg, positions)
    k_nope = jnp.einsum("bsr,re->bse", c_kv, params["w_uk"]).reshape(
        B, S, H, m.qk_nope_head_dim)
    v = jnp.einsum("bsr,re->bse", c_kv, params["w_uv"]).reshape(
        B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = qk ** -0.5
    # pad V head_dim up to the QK head_dim so generic attention applies
    attn_fn = chunked_attention if impl == "chunked" else naive_attention
    if m.v_head_dim != qk:
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk - m.v_head_dim)))
    else:
        v_p = v
    kw = dict(causal=True, scale=scale)
    if impl == "chunked":
        kw.update(q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    o = attn_fn(q, k, v_p, **kw)[..., : m.v_head_dim]
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), params["w_o"])
    return out, (c_kv, k_rope)


def mla_decode(params, x, cfg: ArchConfig, c_kv_cache, k_rope_cache, cur_len,
               positions):
    """Absorbed decode: attention in latent space against the compressed cache.

    x: (B,1,d); c_kv_cache: (B,Smax,R); k_rope_cache: (B,Smax,Dr).
    Caches already contain the current token at position cur_len-1.
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    f32 = jnp.float32
    q_nope, q_rope, _, _ = _latents(params, x, cfg, positions)
    # absorb W_UK into the query:  q_lat = q_nope @ W_UK^T  (B,1,H,R)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = einsum_f32("bqhd,rhd->bqhr", q_nope, w_uk)
    # NOTE: caches stay bf16; f32 only in the MXU accumulator. Materializing
    # .astype(f32) here gets hoisted over the whole stacked cache by XLA
    # (= +47 GB HBM traffic/step/chip at deepseek-v3 decode_32k; see
    # EXPERIMENTS.md §Perf iteration A1).
    s = einsum_f32("bqhr,bsr->bhqs", q_lat.astype(c_kv_cache.dtype),
                   c_kv_cache)
    s = s + einsum_f32("bqhd,bsd->bhqs", q_rope, k_rope_cache)
    s = s * ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5)
    ok = jnp.arange(c_kv_cache.shape[1]) < cur_len
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = einsum_f32("bhqs,bsr->bqhr", p.astype(c_kv_cache.dtype),
                       c_kv_cache)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_uv.astype(f32))
    o = o.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", o, params["w_o"])
