"""RMSNorm / LayerNorm (computed in fp32, cast back)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.common.param import ParamDecl


def rms_decls(dim: int):
    return {"scale": ParamDecl((dim,), ("norm",), init="ones")}


def ln_decls(dim: int):
    return {
        "scale": ParamDecl((dim,), ("norm",), init="ones"),
        "bias": ParamDecl((dim,), ("norm",), init="zeros"),
    }


def norm_decls(kind: str, dim: int):
    return rms_decls(dim) if kind == "rms" else ln_decls(dim)


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * (var + eps) ** -0.5
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * (var + eps) ** -0.5
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dtype)


def apply_norm(kind: str, params, x, eps: float):
    return rmsnorm(params, x, eps) if kind == "rms" else layernorm(params, x, eps)
