"""RWKV6 ("Finch") time-mix + channel-mix blocks with data-dependent decay.

Train/prefill use an exact *chunked* formulation (GLA-style): the sequence is
split into chunks of length C; the matrix state S (per head, Dk x Dv) is
carried across chunks with per-channel decay, and the intra-chunk part is an
einsum over a (C, C, Dk) exp-of-log-decay-difference tensor. All exponent
arguments are differences of a non-increasing cumulative log-decay, hence
<= 0 — numerically safe without clamping (see tests vs. the sequential
oracle). Decode is the exact one-step recurrence on the carried state:
O(1) in context length, which is why rwkv6 runs long_500k.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.param import ParamDecl
from repro.configs.base import ArchConfig

MIX = ("w", "k", "v", "r", "g")


def timemix_decls(cfg: ArchConfig):
    r = cfg.rwkv
    d = cfg.d_model
    H = d // r.head_dim
    return {
        "mu_x": ParamDecl((d,), ("norm",), init="zeros"),
        "mu": ParamDecl((5, d), (None, "norm"), init="zeros"),
        "mix_w1": ParamDecl((d, 5 * r.mix_lora), ("embed", None), scale=0.01),
        "mix_w2": ParamDecl((5, r.mix_lora, d), (None, None, "embed"), scale=0.01),
        "decay_base": ParamDecl((d,), ("norm",), init="uniform", scale=1.0),
        "decay_w1": ParamDecl((d, r.decay_lora), ("embed", "lora"), scale=0.01),
        "decay_w2": ParamDecl((r.decay_lora, d), ("lora", "embed"), scale=0.01),
        "bonus": ParamDecl((H, r.head_dim), ("heads", None), scale=0.1),
        "w_r": ParamDecl((d, d), ("embed", "qkv")),
        "w_k": ParamDecl((d, d), ("embed", "qkv")),
        "w_v": ParamDecl((d, d), ("embed", "qkv")),
        "w_g": ParamDecl((d, d), ("embed", "qkv")),
        "w_o": ParamDecl((d, d), ("qkv", "embed")),
        "gn_scale": ParamDecl((d,), ("norm",), init="ones"),
        "gn_bias": ParamDecl((d,), ("norm",), init="zeros"),
    }


def chanmix_decls(cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamDecl((d,), ("norm",), init="zeros"),
        "mu_r": ParamDecl((d,), ("norm",), init="zeros"),
        "w_k": ParamDecl((d, f), ("embed", "ff")),
        "w_v": ParamDecl((f, d), ("ff", "embed")),
        "w_r": ParamDecl((d, d), ("embed", "qkv")),
    }


def _token_shift(x, prev):
    """x: (B,S,d); prev: (B,d) last token of previous segment (zeros at t=0)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1)


def _ddlerp(x, sx, mu_x, mu, w1, w2):
    """RWKV6 data-dependent mixing -> the 5 mixed inputs (w,k,v,r,g)."""
    xx = x + sx * mu_x                                     # (B,S,d)
    lo = jnp.tanh(jnp.einsum("bsd,dl->bsl", xx, w1))
    lo = lo.reshape(*lo.shape[:-1], 5, w2.shape[1])
    off = jnp.einsum("bsml,mld->bsmd", lo, w2)             # (B,S,5,d)
    mixed = x[..., None, :] + sx[..., None, :] * (mu + off)
    return [mixed[..., i, :] for i in range(5)]


def _group_norm(o, scale, bias, H: int, eps: float = 64e-5):
    B, S, d = o.shape
    x = o.reshape(B, S, H, d // H).astype(jnp.float32)
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x.reshape(B, S, d)
    return x * scale.astype(jnp.float32) + bias.astype(jnp.float32)


def _rkvw(params, x, x_prev, cfg: ArchConfig):
    """Projections + per-step log decay. Returns (r,k,v,g,log_w,(B,d) last x)."""
    sx = _token_shift(x, x_prev) - x
    xw, xk, xv, xr, xg = _ddlerp(x, sx, params["mu_x"], params["mu"],
                                 params["mix_w1"], params["mix_w2"])
    r = jnp.einsum("bsd,de->bse", xr, params["w_r"])
    k = jnp.einsum("bsd,de->bse", xk, params["w_k"])
    v = jnp.einsum("bsd,de->bse", xv, params["w_v"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, params["w_g"])
                    .astype(jnp.float32))
    dec = params["decay_base"].astype(jnp.float32) + jnp.einsum(
        "bsl,ld->bsd",
        jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, params["decay_w1"])),
        params["decay_w2"]).astype(jnp.float32)
    log_w = -jnp.exp(dec)                                  # <= 0, per channel
    return r, k, v, g, log_w, x[:, -1]


def wkv_sequential(r, k, v, log_w, bonus, state0):
    """Oracle: exact per-step scan. r/k/v: (B,S,H,D); state0: (B,H,D,D)."""
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                           # (B,H,D)...
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t, S + bonus[..., None] * kv)
        S = jnp.exp(w_t)[..., None] * S + kv
        return S, o_t

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, log_w))
    state, o = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(o, 0, 1), state


def wkv_chunked(r, k, v, log_w, bonus, state0, chunk: int):
    """Exact chunked WKV. r/k/v/log_w: (B,S,H,D) fp32; state0: (B,H,D,D)."""
    B, S, H, D = r.shape
    C = min(chunk, S)
    n = -(-S // C)
    pad = n * C - S
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0), (0, 0)))
    rc = r.reshape(B, n, C, H, D)
    kc = k.reshape(B, n, C, H, D)
    vc = v.reshape(B, n, C, H, D)
    wc = log_w.reshape(B, n, C, H, D)

    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)           # strictly lower

    def chunk_step(Sst, inp):
        rr, kk, vv, ww = inp                               # (B,C,H,D)
        b = jnp.cumsum(ww, axis=1)                         # inclusive cumsum
        b_end = b[:, -1]                                   # (B,H,D)
        # inter-chunk: o_t += (r_t * exp(b_{t-1})) @ S_prev
        b_prev = b - ww                                    # exclusive cumsum
        q_int = rr * jnp.exp(b_prev)
        o = jnp.einsum("bthk,bhkv->bthv", q_int, Sst)
        # intra-chunk: s_tj = sum_d r_td k_jd exp(b_{t-1,d} - b_{j,d}), j<t
        diff = b_prev[:, :, None] - b[:, None, :]          # (B,C,C,H,D)
        diff = jnp.where(tri[None, :, :, None, None], diff, -jnp.inf)
        s = jnp.einsum("bthd,bjhd,btjhd->btjh", rr, kk, jnp.exp(diff))
        o = o + jnp.einsum("btjh,bjhv->bthv", s, vv)
        # diagonal bonus term
        diag = jnp.einsum("bthd,hd,bthd->bth", rr, bonus, kk)
        o = o + diag[..., None] * vv
        # state update: S = exp(b_end) * S_prev + sum_j exp(b_end - b_j) k_j v_j
        k_dec = kk * jnp.exp(b_end[:, None] - b)
        Sst = jnp.exp(b_end)[..., None] * Sst + jnp.einsum(
            "bjhk,bjhv->bhkv", k_dec, vv)
        return Sst, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, wc))
    state, o = jax.lax.scan(chunk_step, state0, xs)
    o = jnp.moveaxis(o, 0, 1).reshape(B, n * C, H, D)[:, :S]
    return o, state


def timemix_apply(params, x, cfg: ArchConfig, state=None
                  ) -> Tuple[jax.Array, dict]:
    """x: (B,S,d). state: None or {"x_prev": (B,d), "S": (B,H,D,D) fp32}."""
    r_cfg = cfg.rwkv
    B, S, d = x.shape
    H, D = d // r_cfg.head_dim, r_cfg.head_dim
    x_prev = (jnp.zeros((B, d), x.dtype) if state is None else
              state["x_prev"].astype(x.dtype))
    r, k, v, g, log_w, last_x = _rkvw(params, x, x_prev, cfg)
    shp = (B, S, H, D)
    r4 = r.reshape(shp).astype(jnp.float32)
    k4 = k.reshape(shp).astype(jnp.float32)
    v4 = v.reshape(shp).astype(jnp.float32)
    w4 = log_w.reshape(shp)
    S0 = (jnp.zeros((B, H, D, D), jnp.float32) if state is None
          else state["S"])
    bonus = params["bonus"].astype(jnp.float32)
    if S == 1:
        o, S1 = wkv_sequential(r4, k4, v4, w4, bonus, S0)
    else:
        o, S1 = wkv_chunked(r4, k4, v4, w4, bonus, S0, r_cfg.chunk)
    o = _group_norm(o.reshape(B, S, d), params["gn_scale"], params["gn_bias"], H)
    o = (o * g).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", o, params["w_o"])
    return out, {"x_prev": last_x.astype(jnp.float32), "S": S1}


def chanmix_apply(params, x, state=None) -> Tuple[jax.Array, dict]:
    """x: (B,S,d). state: None or {"x_prev": (B,d)}."""
    B, S, d = x.shape
    x_prev = (jnp.zeros((B, d), x.dtype) if state is None else
              state["x_prev"].astype(x.dtype))
    sx = _token_shift(x, x_prev) - x
    xk = x + sx * params["mu_k"]
    xr = x + sx * params["mu_r"]
    kk = jnp.einsum("bsd,df->bsf", xk, params["w_k"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    kv = jnp.einsum("bsf,fd->bsd", kk, params["w_v"])
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, params["w_r"]).astype(jnp.float32))
    return (rr * kv.astype(jnp.float32)).astype(x.dtype), {
        "x_prev": x[:, -1].astype(jnp.float32)}


def rwkv_state_decls(cfg: ArchConfig, batch: int):
    r = cfg.rwkv
    d = cfg.d_model
    H, D = d // r.head_dim, r.head_dim
    return {
        "att": {
            "x_prev": ParamDecl((batch, d), ("batch", None),
                                dtype=jnp.float32, init="zeros"),
            "S": ParamDecl((batch, H, D, D), ("batch", "heads", None, None),
                           dtype=jnp.float32, init="zeros"),
        },
        "ffn": {
            "x_prev": ParamDecl((batch, d), ("batch", None),
                                dtype=jnp.float32, init="zeros"),
        },
    }
