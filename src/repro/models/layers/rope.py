"""Rotary position embeddings (rotate-half convention, fp32 phases)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) or (..., S, D); positions: broadcastable to (..., S)."""
    dtype = x.dtype
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    if x.ndim == ang.ndim + 1:                         # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)
