"""GQA attention: naive, chunked (flash-style online softmax in pure JAX),
and single-token decode over a KV cache.

Weights are kept 2-D ``(d_model, n*head_dim)`` so the fused output dim always
TP-shards cleanly even when the head count (40, 56, 10...) does not divide the
model axis — see DESIGN.md §5 and distributed/partition.py.

The chunked implementation is the one used by prefill/train in the dry-run:
it never materializes an (Sq, Skv) score matrix, scanning KV blocks with an
online-softmax carry (m, l, acc). A Pallas flash kernel with identical
semantics lives in repro/kernels/flash_attention for the TPU target.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.dots import einsum_f32
from repro.common.param import ParamDecl
from repro.distributed.partition import ac
from repro.models.layers.norms import rms_decls, rmsnorm
from repro.models.layers.rope import apply_rope

NEG_INF = -1e30


def attn_decls(d_model: int, n_heads: int, n_kv: int, head_dim: int,
               qkv_bias: bool = False, qk_norm: bool = False,
               out_bias: bool = False):
    decls = {
        "w_q": ParamDecl((d_model, n_heads * head_dim), ("embed", "qkv")),
        "w_k": ParamDecl((d_model, n_kv * head_dim), ("embed", "qkv")),
        "w_v": ParamDecl((d_model, n_kv * head_dim), ("embed", "qkv")),
        "w_o": ParamDecl((n_heads * head_dim, d_model), ("qkv", "embed")),
    }
    if qkv_bias:
        decls["b_q"] = ParamDecl((n_heads * head_dim,), ("qkv",), init="zeros")
        decls["b_k"] = ParamDecl((n_kv * head_dim,), ("qkv",), init="zeros")
        decls["b_v"] = ParamDecl((n_kv * head_dim,), ("qkv",), init="zeros")
    if out_bias:
        decls["b_o"] = ParamDecl((d_model,), ("norm",), init="zeros")
    if qk_norm:
        decls["q_norm"] = rms_decls(head_dim)
        decls["k_norm"] = rms_decls(head_dim)
    return decls


def project_qkv(params, x, n_heads: int, n_kv: int, head_dim: int,
                qk_norm: bool, norm_eps: float = 1e-6):
    """x: (B,S,d) -> q (B,S,H,D), k,v (B,S,KH,D). No rope here."""
    B, S, _ = x.shape
    q = ac(jnp.einsum("bsd,de->bse", x, params["w_q"]), "batch", None, "qkv")
    k = ac(jnp.einsum("bsd,de->bse", x, params["w_k"]), "batch", None, "qkv")
    v = ac(jnp.einsum("bsd,de->bse", x, params["w_v"]), "batch", None, "qkv")
    if "b_q" in params:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    if qk_norm:
        q = rmsnorm(params["q_norm"], q, norm_eps)
        k = rmsnorm(params["k_norm"], k, norm_eps)
    return q, k, v


def _mask(q_pos, k_pos, *, causal: bool, window: Optional[int],
          kv_valid: Optional[jax.Array]):
    """(..., qc, kc) boolean mask of *allowed* positions."""
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    if kv_valid is not None:
        m &= kp < kv_valid
    return m


def naive_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, kv_valid: Optional[jax.Array] = None,
                    scale: Optional[float] = None):
    """Oracle path. q: (B,Sq,H,D); k,v: (B,Skv,KH,D)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, KH, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    m = _mask(q_pos, k_pos, causal=causal, window=window, kv_valid=kv_valid)
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None, q_offset: int = 0,
                      kv_valid: Optional[jax.Array] = None,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      scale: Optional[float] = None):
    """Flash-style attention in pure JAX (compiles on any backend).

    Outer scan over Q chunks, inner scan over KV chunks with online-softmax
    carry. Peak memory per step: (B,KH,G,qc,kc) fp32 scores.
    """
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else D ** -0.5
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    # pad to multiples
    Sq_p = -(-Sq // qc) * qc
    Skv_p = -(-Skv // kc) * kc
    qg = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0))).reshape(
        B, Sq_p // qc, qc, KH, G, D)
    kp = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    kb = kp.reshape(B, Skv_p // kc, kc, KH, D)
    vb = vp.reshape(B, Skv_p // kc, kc, KH, D)
    n_kb = Skv_p // kc
    kv_valid_arr = (jnp.asarray(Skv, jnp.int32) if kv_valid is None
                    else jnp.asarray(kv_valid, jnp.int32))

    def q_step(_, qi_and_chunk):
        qi, qch = qi_and_chunk                     # qch: (qc,KH,G,D) per batch later
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def kv_step(carry, ki_and_blk):
            m_prev, l_prev, acc = carry
            ki, kblk, vblk = ki_and_blk
            k_pos = ki * kc + jnp.arange(kc)
            # keep K/V blocks in storage dtype, f32 accumulate on the MXU
            # (an .astype(f32) here hoists a whole-K convert out of the scan)
            s = einsum_f32("bqkgd,bskd->bkgqs", qch, kblk) * scale
            mask = _mask(q_pos, k_pos, causal=causal, window=window,
                         kv_valid=kv_valid_arr)
            s = jnp.where(mask, s, NEG_INF)
            m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_cur[..., None])
            corr = jnp.exp(m_prev - m_cur)
            l_cur = l_prev * corr + jnp.sum(p, axis=-1)
            pv = einsum_f32("bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk)
            acc = acc * corr[..., None] + pv
            return (m_cur, l_cur, acc), None

        m0 = jnp.full((B, KH, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KH, G, qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(n_kb), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        l = jnp.maximum(l, 1e-30)
        out = (acc / l[..., None])                 # (B,KH,G,qc,D)
        return None, jnp.transpose(out, (0, 3, 1, 2, 4))  # (B,qc,KH,G,D)

    _, outs = jax.lax.scan(q_step, None,
                           (jnp.arange(Sq_p // qc), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq_p, H, D)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, *,
                     window: Optional[int] = None, scale: Optional[float] = None):
    """Single-step decode. q: (B,1,H,D); caches: (B,Smax,KH,D).

    cur_len: int32 scalar — number of valid cache entries *including* the
    current token (already written into the cache).
    """
    B, _, H, D = q.shape
    KH = k_cache.shape[2]
    G = H // KH
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, KH, G, D).astype(k_cache.dtype)
    # caches stay in their storage dtype; accumulate f32 on the MXU —
    # an .astype(f32) on the cache hoists a full-cache convert out of the
    # layer scan (EXPERIMENTS.md §Perf iteration A1)
    s = einsum_f32("bkgd,bskd->bkgs", qg, k_cache) * scale
    k_pos = jnp.arange(k_cache.shape[1])
    ok = k_pos < cur_len
    if window is not None:
        ok &= k_pos > cur_len - 1 - window
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = einsum_f32("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, D).astype(q.dtype)


def decode_attention_pos(q, k_cache, v_cache, k_pos, cur_pos, window=None,
                         scale: Optional[float] = None):
    """Decode over a ring buffer with explicit key positions.

    q: (B,1,H,D); caches: (B,W,KH,D); k_pos: (W,) int32, -1 = empty slot;
    cur_pos: int32 scalar (position of the current token).
    """
    B, _, H, D = q.shape
    KH = k_cache.shape[2]
    G = H // KH
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, KH, G, D).astype(k_cache.dtype)
    s = einsum_f32("bkgd,bskd->bkgs", qg, k_cache) * scale
    ok = (k_pos >= 0) & (k_pos <= cur_pos)
    if window is not None:
        ok &= k_pos > cur_pos - window
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = einsum_f32("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, D).astype(q.dtype)


def attention(q, k, v, *, impl: str = "chunked", **kw):
    if impl == "naive":
        kw.pop("q_chunk", None)
        kw.pop("kv_chunk", None)
        return naive_attention(q, k, v, **kw)
    if impl == "pallas":
        # TPU target path; falls back to chunked off-TPU. Wired in ops.py.
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention_auto(q, k, v, **kw)
    kw.setdefault("q_chunk", 512)
    kw.setdefault("kv_chunk", 1024)
    return chunked_attention(q, k, v, **kw)
