"""Blockwise-chunked transformer encoder for ingest embedding.

The blockwise-parallel-transformer pattern (BPT; SNIPPETS.md Snippet 2):
per layer, attention runs block-by-block over the query axis — the flash
kernel on TPU (`kernels/flash_attention`), the chunked online-softmax scan
elsewhere — and the feed-forward runs over the same fixed-size row blocks
under ``jax.checkpoint``. At a fixed block size the per-block *activation*
working set (the (block, kv_chunk) score tile, the (block, d_ff) MLP
intermediate) is flat in sequence length; only the residual stream and the
per-layer K/V projections remain O(S) state. ``activation_accounting``
states that split analytically, in the same machine-independent spirit as
``kernels.pairwise.ops``.

Bitwise chunking contract (the PR-7 batch-insensitivity contract extended
to the sequence axis): the block size is invisible in the output bytes.
Every op outside attention is row-local; inside attention a query row's
online-softmax trajectory depends only on the KV *chunk grid* — which is
pinned by ``kv_chunk`` independently of the block size — never on how
query rows are grouped into blocks. Trailing pad rows/keys introduced by
block-multiple padding are exact no-ops for real rows (causal masking
zeroes them before any reduction that could regroup). Hence
``blockwise_encode(block=b)`` == ``blockwise_encode(block=b')`` bit-for-bit
for any b, b', including b >= S (the unchunked forward). Asserted by
tests/test_transformer_backend.py and benchmarks/table2_pipeline.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.param import ParamDecl, init_params
from repro.configs.base import ArchConfig
from repro.models import transformer as tf_lib
from repro.models.layers import attention as attn_lib
from repro.models.layers.mlp import mlp_apply
from repro.models.layers.norms import apply_norm, norm_decls
from repro.models.layers.rope import apply_rope


def tiny_encoder_config(vocab: int = 512) -> ArchConfig:
    """CPU-sized GQA encoder used by the service's transformer backend."""
    return ArchConfig(
        name="tiny_blockwise_encoder", family="dense",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=vocab, norm="rms", mlp="swiglu", remat=True,
        attention_impl="pallas")   # pallas on TPU, chunked-jnp fallback


def encoder_decls(cfg: ArchConfig, input_dim: Optional[int] = None):
    """Param tree: token embed (or audio frame projection) + a stacked
    layer axis over the standard (norm1, attn, norm2, mlp) unit + final
    norm. Reuses the exact layer declarations of models/transformer.py."""
    unit = tf_lib._layer_decls(cfg, tf_lib.LayerSpec("attn", "dense"))
    stacked = jax.tree.map(
        lambda d: tf_lib._stack_decl(d, cfg.n_layers), unit,
        is_leaf=lambda x: isinstance(x, ParamDecl))
    decls = {
        "layers": stacked,
        "final_norm": norm_decls(cfg.norm, cfg.d_model),
    }
    if input_dim:
        decls["frame_proj"] = ParamDecl((input_dim, cfg.d_model),
                                        ("embed", None))
    else:
        decls["embed"] = ParamDecl((cfg.padded_vocab, cfg.d_model),
                                   ("vocab", "embed"), init="embed")
    return decls


def init_encoder(cfg: ArchConfig, rng, input_dim: Optional[int] = None):
    """f32 params (the serving feature path is all-f32 for determinism)."""
    params = init_params(encoder_decls(cfg, input_dim), rng)
    return jax.tree.map(lambda a: a.astype(jnp.float32), params)


def embed_tokens(cfg: ArchConfig, params, tokens):
    """tokens (B,S) int32, -1 = right-padding -> (B,S,d) f32. Row-local."""
    safe = jnp.clip(tokens, 0, cfg.padded_vocab - 1)
    return jnp.take(params["embed"], safe, axis=0).astype(jnp.float32)


def embed_frames(params, frames):
    """frames (B,S,F) f32 -> (B,S,d) f32 linear frontend. Row-local."""
    return jnp.einsum("bsf,fd->bsd", frames.astype(jnp.float32),
                      params["frame_proj"])


def _attention(q, k, v, *, impl: str, block: int, kv_chunk: int):
    if impl == "interpret":
        # CI kernel lane: the same Pallas flash kernel the TPU path runs,
        # executed through the interpreter
        from repro.kernels.flash_attention.kernel import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=True,
                                      kv_block=kv_chunk, interpret=True)
    return attn_lib.attention(q, k, v, impl=impl, causal=True,
                              q_chunk=block, kv_chunk=kv_chunk)


def blockwise_encode(cfg: ArchConfig, params, x, *, block: int,
                     kv_chunk: int, impl: Optional[str] = None):
    """x: (B,S,d) embedded inputs -> (B,S,d) final-norm hidden states.

    ``block`` chunks the query/FFN row axis (the activation knob);
    ``kv_chunk`` pins the online-softmax KV grid and must stay fixed
    across block sizes for the bitwise contract (the backend clamps it to
    the canonical sequence length so it never varies with pad length).
    """
    B, S, d = x.shape
    block = max(1, min(block, S))
    nb = -(-S // block)
    Sp = nb * block
    if Sp != S:
        x = jnp.pad(x, ((0, 0), (0, Sp - S), (0, 0)))
    positions = jnp.arange(Sp)[None, :]        # (1, Sp), broadcast over batch
    impl = impl or cfg.attention_impl

    def unit(h, lp):
        n1 = apply_norm(cfg.norm, lp["norm1"], h, cfg.norm_eps)
        q, k, v = attn_lib.project_qkv(lp["mixer"], n1, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.hd, cfg.qk_norm,
                                       cfg.norm_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = _attention(q, k, v, impl=impl, block=block, kv_chunk=kv_chunk)
        o = jnp.einsum("bse,ed->bsd", o.reshape(B, Sp, -1),
                       lp["mixer"]["w_o"])
        if "b_o" in lp["mixer"]:
            o = o + lp["mixer"]["b_o"]
        h = h + o
        n2 = apply_norm(cfg.norm, lp["norm2"], h, cfg.norm_eps)

        def ffn_block(_, hb):
            return None, mlp_apply(lp["mlp"], hb, cfg.mlp)

        step = jax.checkpoint(ffn_block) if cfg.remat else ffn_block
        _, fo = jax.lax.scan(
            step, None, jnp.moveaxis(n2.reshape(B, nb, block, d), 1, 0))
        h = h + jnp.moveaxis(fo, 0, 1).reshape(B, Sp, d)
        return h, None

    step = jax.checkpoint(unit) if cfg.remat else unit
    h, _ = jax.lax.scan(step, x, params["layers"])
    h = apply_norm(cfg.norm, params["final_norm"], h, cfg.norm_eps)
    return h[:, :S]


def pool_hidden(h, mask, pooling: str):
    """h (B,S,d), mask (B,S) bool -> (B,d) f32 features. Sample-local."""
    mask = mask.astype(jnp.float32)
    if pooling == "last":
        idx = jnp.maximum(jnp.sum(mask, axis=-1).astype(jnp.int32) - 1, 0)
        return jnp.take_along_axis(
            h, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0].astype(
                jnp.float32)
    denom = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    return (jnp.sum(h * mask[..., None], axis=1) / denom).astype(jnp.float32)


def activation_accounting(cfg: ArchConfig, batch: int, seq_len: int,
                          block: int, kv_chunk: int,
                          itemsize: int = 4) -> dict:
    """Analytic per-forward memory split (bytes), machine-independent.

    ``peak_activation_bytes`` is the largest per-block working set any
    single blockwise step holds live (attention score tile + softmax carry
    vs. the MLP intermediate) — independent of ``seq_len`` at a fixed
    block size, which is the claim table2/transformer_embed asserts.
    ``state_bytes`` is the O(S) part (residual stream + per-layer K/V
    projections) that any exact-attention forward must keep.
    ``unchunked_peak_bytes`` is the same accounting at block = kv_chunk =
    the padded sequence — the (S, S) score matrix a naive forward holds.
    """
    B = batch
    nb = -(-seq_len // max(block, 1))
    Sp = nb * max(block, 1)
    qc = min(block, Sp)
    kc = min(kv_chunk, Sp)
    H, KH, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KH
    ff_mult = 2 if cfg.mlp == "swiglu" else 1

    def _peak(qc_, kc_):
        scores = B * KH * G * qc_ * kc_          # (B,KH,G,qc,kc) f32 tile
        carry = B * KH * G * qc_ * (2 + D)       # online-softmax m,l,acc
        q_tile = B * qc_ * H * D
        attn_tile = scores + carry + q_tile
        mlp_tile = B * qc_ * (ff_mult * cfg.d_ff + 2 * cfg.d_model)
        return max(attn_tile, mlp_tile) * itemsize

    residual = B * Sp * cfg.d_model * itemsize
    kv_state = 2 * B * Sp * KH * D * itemsize
    return {
        "peak_activation_bytes": _peak(qc, kc),
        "state_bytes": residual + kv_state,
        "unchunked_peak_bytes": _peak(Sp, Sp),
        "blocks": nb,
        "block": qc,
        "kv_chunk": kc,
    }
