"""ResNet feature extractor in pure JAX (paper's scorer: ResNet-18 [19]).

The paper fine-tunes only the last layer on AL-selected samples; we mirror
that: ``resnet_features`` is the frozen extractor, a logistic head is fit on
top (see service/backends.py). ``resnet18_config`` is the paper-faithful
depth; benchmarks use ``tiny`` so one-round AL on CPU finishes in seconds.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.common.param import ParamDecl, init_params


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    stage_sizes: Sequence[int] = (2, 2, 2, 2)       # resnet-18
    widths: Sequence[int] = (64, 128, 256, 512)
    in_channels: int = 3
    num_classes: int = 10


def resnet18_config() -> ResNetConfig:
    return ResNetConfig()


def tiny_config(num_classes: int = 10) -> ResNetConfig:
    return ResNetConfig(stage_sizes=(1, 1), widths=(16, 32),
                        num_classes=num_classes)


def _conv_decl(cin, cout, k=3):
    return ParamDecl((k, k, cin, cout), (None, None, None, "tp"),
                     dtype=jnp.float32, fan_in_axes=(0, 1, 2))


def resnet_decls(cfg: ResNetConfig):
    decls = {"stem": _conv_decl(cfg.in_channels, cfg.widths[0])}
    blocks = []
    cin = cfg.widths[0]
    for si, (n, w) in enumerate(zip(cfg.stage_sizes, cfg.widths)):
        for bi in range(n):
            b = {
                "conv1": _conv_decl(cin, w),
                "conv2": _conv_decl(w, w),
                "scale1": ParamDecl((w,), ("norm",), dtype=jnp.float32,
                                    init="ones"),
                "scale2": ParamDecl((w,), ("norm",), dtype=jnp.float32,
                                    init="ones"),
            }
            if cin != w:
                b["proj"] = _conv_decl(cin, w, k=1)
            blocks.append(b)
            cin = w
    decls["blocks"] = blocks
    decls["head"] = ParamDecl((cin, cfg.num_classes), ("embed", "tp"),
                              dtype=jnp.float32)
    return decls


def init_resnet(cfg: ResNetConfig, rng):
    return init_params(resnet_decls(cfg), rng)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _norm(x, scale):
    # Per-sample (spatial-only) statistics, NOT batch statistics: each row's
    # features must be a pure function of that row so re-embedding a sample
    # in a different batch (cache eviction, push chunking) reproduces the
    # exact floats. The frozen extractor has no running BN stats to use, and
    # batch statistics at inference would leak co-batched rows into every
    # embedding — breaking the service's content-addressed embedding cache.
    mu = jnp.mean(x, axis=(1, 2), keepdims=True)
    var = jnp.var(x, axis=(1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale


def resnet_features(params, cfg: ResNetConfig, x):
    """x: (B,H,W,C) fp32 in [0,1] -> (B, widths[-1]) pooled features."""
    h = jax.nn.relu(_conv(x, params["stem"]))
    bi = 0
    cin = cfg.widths[0]
    for si, (n, w) in enumerate(zip(cfg.stage_sizes, cfg.widths)):
        for k in range(n):
            b = params["blocks"][bi]
            stride = 2 if (k == 0 and si > 0) else 1
            y = jax.nn.relu(_norm(_conv(h, b["conv1"], stride), b["scale1"]))
            y = _norm(_conv(y, b["conv2"]), b["scale2"])
            sc = h if "proj" not in b else _conv(h, b["proj"], stride)
            h = jax.nn.relu(y + sc)
            bi += 1
            cin = w
    return jnp.mean(h, axis=(1, 2))


def resnet_logits(params, cfg: ResNetConfig, x):
    return resnet_features(params, cfg, x) @ params["head"]
