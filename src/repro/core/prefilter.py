"""Centroid-gated pool prefilter: sublinear selection over shard pools.

The landmark idiom (a small summary gates which blocks get the expensive
full computation) applied to AL selection: each shard keeps a
``CentroidSummary`` — k-means centroids over its feats column, the pool
rows permuted into contiguous per-cluster segments, per-cluster radii, and
per-cluster cached uncertainty-score maxima ("caps") stamped with the head
epoch they were computed at. Queries then touch only the pool rows whose
cluster survives a bound check:

``gated_greedy_select`` (k-center / Core-Set lineage)
    Per slot, every cluster carries an upper bound on its best score:
    ``ub_j = min(M_j, T_j)`` where ``T_j = (min_c sqrt(d2(cent_j, c)) +
    radius_j)^2`` is the triangle-inequality bound over all folded centers
    ``c`` and ``M_j`` is the cluster's last exactly-computed max (valid
    forever: min-dists only decrease). A best-first loop evaluates
    clusters in descending-``ub`` order and stops once
    ``ub * (1 + slack) < best`` — everything else is skipped without
    reading a single row. Skipped clusters accumulate *pending* centers
    and catch up (fold the centers they missed) when their bound finally
    fails, so their min-dists are always exact when read.

    Exactness: pending centers fold ONE AT A TIME through the same
    single-center fused round as the ungated path, and fp ``min`` is
    exact and order-independent — so evaluated rows carry bitwise the
    min-dists the ungated oracle computes, and a loose bound (large
    ``slack``, every cluster always live) reproduces ``prefilter: false``
    bit-for-bit. With a tight bound, selections agree up to rounding of
    the *bound itself* (computed in f64, covered by ``slack``) and exact
    score ties across clusters.

``gated_top_k`` (uncertainty family)
    Clusters are scanned in descending order of their cached score cap;
    the scan stops when the cap of the next cluster is strictly below the
    current budget-th best candidate — rows there can neither enter nor
    reorder the top-k, so the result is ALWAYS bit-identical to the full
    scan. Caps are refreshed per head bump (stamped ``caps_head_epoch``);
    a stale or missing cap falls back to the shard's full scan, never to
    a wrong answer.

Rows appended after the last summary build form the *tail*: always
scanned (no summary covers them), folded with the same exact rounds. The
summary rebuilds once the tail outgrows the covered prefix.

``prefilter: false`` (no summaries attached) is the from-scratch oracle,
the same knob pattern as ``artifact_cache: false``.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import selection
from repro.kernels.pairwise import ops

BIG = 3.4e38


@dataclasses.dataclass(frozen=True)
class PrefilterConfig:
    """The serving config's prefilter knobs (``prefilter: true``)."""
    slack: float = 0.05       # relative bound slack; large = loose = oracle
    clusters: int = 0         # centroids per shard summary; 0 = auto
    min_rows: int = 256       # pools below this skip summaries (full scan)

    def auto_k(self, rows: int) -> int:
        k = self.clusters or min(max(rows // 256, 4), 64)
        return max(1, min(k, rows))


class CentroidSummary:
    """Per-shard centroid summary (immutable once published).

    ``xperm`` is a permuted COPY of the shard's first ``covered`` feats
    rows, contiguous per cluster: cluster ``j`` occupies
    ``xperm[starts[j]:starts[j+1]]`` and ``rowid`` maps each permuted
    position back to its shard-local pool row (ascending within a
    cluster, so within-cluster argmax tie-breaks match pool order).
    ``cents``/``radii`` (f64) anchor the triangle bounds; ``caps`` maps a
    score kind to per-cluster exact maxima over the covered rows, stamped
    with ``caps_head_epoch``. Caps refreshes publish a NEW object sharing
    the geometry arrays — pinned snapshots never observe mutation.
    """

    __slots__ = ("k", "cents", "radii", "starts", "rowid", "xperm",
                 "covered", "caps", "caps_head_epoch", "builds")

    def __init__(self, k, cents, radii, starts, rowid, xperm, covered,
                 caps=None, caps_head_epoch=-1, builds=0):
        self.k = int(k)
        self.cents = cents                  # (k, d) f64
        self.radii = radii                  # (k,) f64, sqrt-space
        self.starts = starts                # (k+1,) i64 segment offsets
        self.rowid = rowid                  # (covered,) i64 pool rows
        self.xperm = xperm                  # (covered, d) f32 permuted copy
        self.covered = int(covered)
        self.caps: Optional[Dict[str, np.ndarray]] = caps
        self.caps_head_epoch = int(caps_head_epoch)
        self.builds = int(builds)

    def with_caps(self, probs: np.ndarray, head_epoch: int,
                  track: bool = False) -> "CentroidSummary":
        """Copy-on-write caps refresh from the covered probs rows."""
        from repro.core.strategies.uncertainty import SCORE_FNS
        p = jnp.asarray(np.asarray(probs[:self.covered], np.float32))
        caps: Dict[str, np.ndarray] = {}
        for kind, fn in SCORE_FNS.items():
            sc = np.asarray(fn(p))[self.rowid]      # permuted scores
            cap = np.full(self.k, -np.inf, np.float32)
            for j in range(self.k):
                s, e = int(self.starts[j]), int(self.starts[j + 1])
                if e > s:
                    cap[j] = sc[s:e].max()
            caps[kind] = cap
        return CentroidSummary(self.k, self.cents, self.radii, self.starts,
                               self.rowid, self.xperm, self.covered,
                               caps=caps, caps_head_epoch=head_epoch,
                               builds=self.builds)


def build_summary(feats: np.ndarray, k: int, salt: str,
                  spill=None) -> CentroidSummary:
    """K-means the shard's feats (fused ``greedy_round`` seeding — the
    same kernel substrate as selection itself) and lay the pool out in
    cluster segments. Deterministic per (salt, rows, k)."""
    from repro.core.strategies.diversity import _kmeans
    rows, d = feats.shape
    x = jnp.asarray(np.asarray(feats, np.float32))
    rng = jax.random.PRNGKey(zlib.crc32(f"{salt}/{rows}/{k}".encode()))
    cents = np.asarray(_kmeans(rng, x, k, iters=4), np.float64)
    assign = np.asarray(ops.pairwise_argmin(
        x, jnp.asarray(cents, jnp.float32)))
    order = np.argsort(assign, kind="stable").astype(np.int64)
    counts = np.bincount(assign, minlength=k)
    starts = np.zeros(k + 1, np.int64)
    starts[1:] = np.cumsum(counts)
    xperm = np.ascontiguousarray(np.asarray(feats, np.float32)[order])
    if spill is not None:
        xperm = spill.adopt(xperm)
    diffs = np.asarray(feats, np.float64) - cents[assign]
    d2 = np.einsum("ij,ij->i", diffs, diffs)
    radii = np.zeros(k, np.float64)
    np.maximum.at(radii, assign, d2)
    return CentroidSummary(k, cents, np.sqrt(radii), starts, order, xperm,
                           covered=rows)


def maintain_summary(summary: Optional[CentroidSummary],
                     feats: Optional[np.ndarray],
                     probs: Optional[np.ndarray], head_epoch: int,
                     cfg: PrefilterConfig, spill=None,
                     salt: str = "") -> Optional[CentroidSummary]:
    """Incremental summary maintenance, PR-5 epoch style: ingest grows
    the (always-scanned) tail and only triggers a rebuild once the tail
    outgrows the covered prefix; a retrain refreshes the score caps from
    cached probs (zero embeds, copy-on-write); labeling touches nothing
    (caps over a superset stay upper bounds)."""
    if feats is None or feats.shape[0] < cfg.min_rows:
        return None
    rows = int(feats.shape[0])
    k = cfg.auto_k(rows)
    if summary is None or summary.k != k \
            or rows - summary.covered > max(summary.covered, cfg.min_rows):
        fresh = build_summary(feats, k, salt, spill)
        fresh.builds = (0 if summary is None else summary.builds) + 1
        if summary is not None and spill is not None:
            spill.release(summary.xperm)
        summary = fresh
    if probs is not None and probs.shape[0] >= summary.covered \
            and summary.caps_head_epoch != head_epoch:
        summary = summary.with_caps(probs, head_epoch)
    return summary


# ===========================================================================
# Gated uncertainty top-k
# ===========================================================================

def gated_top_k(shards: Sequence, kind: str, budget: int,
                executor=None) -> Tuple[np.ndarray, np.ndarray]:
    """``replica_top_k`` with per-shard cap-ordered cluster scans —
    bit-identical to the full scan by the stopping rule (strictly-below
    caps cannot contribute), at a fraction of the rows scored."""
    from repro.core.strategies.uncertainty import SCORE_FNS
    fn = SCORE_FNS[kind]

    def local(s):
        if s.n == 0:
            return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
        b = min(budget, s.n)
        summ = s.summary
        usable = (summ is not None and summ.caps is not None
                  and kind in summ.caps and s.probs_epoch >= 0
                  and summ.caps_head_epoch == s.probs_epoch
                  and s.pool_rows is not None)
        if not usable:
            # missing/stale summary: exact fallback to the full scan
            ops.record_pool_rows(s.n)
            v, i = jax.lax.top_k(fn(jnp.asarray(s.probs)), b)
            return np.asarray(v), s.gidx[np.asarray(i)]
        pool_rows = np.asarray(s.pool_rows)
        n_pool = (s.pool_feats.shape[0] if s.pool_feats is not None
                  else int(pool_rows.max()) + 1)
        inv = np.full(n_pool, -1, np.int64)
        inv[pool_rows] = np.arange(s.n)
        gidx = np.asarray(s.gidx)
        cand_v: List[np.ndarray] = []
        cand_g: List[np.ndarray] = []

        def score_rows(view_pos):
            if view_pos.size == 0:
                return
            ops.record_pool_rows(int(view_pos.size))
            v = np.asarray(fn(jnp.asarray(np.asarray(s.probs)[view_pos])))
            cand_v.append(np.asarray(v, np.float32))
            cand_g.append(gidx[view_pos])

        # tail rows (appended after the summary build) carry no cap:
        # always scanned
        score_rows(np.nonzero(pool_rows >= summ.covered)[0])
        caps = summ.caps[kind]
        order = np.argsort(-caps, kind="stable")
        for j in order:
            have = sum(v.size for v in cand_v)
            if have >= b:
                kth = np.partition(np.concatenate(cand_v), have - b)[have - b]
                # strictly below the b-th best: no row in this cluster
                # (score <= cap < kth) can enter or reorder the top-b.
                # Equal caps keep scanning — a tie could still displace
                # on the lower-global-index rule.
                if caps[j] < kth:
                    break
            members = summ.rowid[int(summ.starts[j]):
                                 int(summ.starts[j + 1])]
            vp = inv[members]
            score_rows(vp[vp >= 0])
        vals = np.concatenate(cand_v) if cand_v else np.zeros(0, np.float32)
        gs = np.concatenate(cand_g) if cand_g else np.zeros(0, np.int64)
        take = np.lexsort((gs, -vals))[:b]
        return vals[take], gs[take]

    parts = selection.replica_map(local, shards, executor)
    vals = np.concatenate([p[0] for p in parts])
    gidx = np.concatenate([p[1] for p in parts])
    order = np.lexsort((gidx, -vals))[:budget]
    return gidx[order], vals[order]


# ===========================================================================
# Gated greedy (k-center lineage)
# ===========================================================================

def _bucket(m: int) -> int:
    """Pad slice lengths to the next power of two (min 8): bounded jit
    retraces across ragged cluster sizes. Pad rows enter with mind=-1, so
    they fold harmlessly and can never win an argmax."""
    p = 8
    while p < m:
        p <<= 1
    return p


class _ShardEngine:
    """Per-shard gated greedy state: segment min-dists over the summary's
    permuted layout + the always-live tail, a shared queue of folded
    center entries, and per-segment pending cursors / bounds."""

    def __init__(self, shard, slack: float, impl: str = "auto",
                 warm_mind=None, warm_centers=None):
        self.impl = impl
        self.slack = float(slack)
        self.summary: Optional[CentroidSummary] = shard.summary
        feats = (shard.pool_feats if shard.pool_feats is not None
                 else np.asarray(shard.feats))
        self.pool_feats = feats
        n_pool = int(feats.shape[0])
        pool_rows = (np.asarray(shard.pool_rows)
                     if shard.pool_rows is not None
                     else np.arange(n_pool, dtype=np.int64))
        self.gpos = np.full(n_pool, -1, np.int64)
        self.gpos[pool_rows] = np.asarray(shard.gidx)
        in_view = np.zeros(n_pool, bool)
        in_view[pool_rows] = True
        self.entries: List[np.ndarray] = []      # queued center batches
        # warm_mind: persisted pool-level min-dists vs warm_centers
        # (core.selection.KCenterState). Segments/tail start from those
        # floats with ZERO entries queued — the first propose is pure
        # vector-op scoring, no (N, d) pool rows read (the ROADMAP's "lazy
        # warm start" follow-up). warm_centers still tighten the triangle
        # bounds exactly as queueing them would: T is a min over per-center
        # bounds, independent of fold chunking.
        if warm_mind is not None:
            assert int(warm_mind.shape[0]) == n_pool
            warm_mind = np.asarray(warm_mind, np.float32)
        summ = self.summary
        self.covered = 0 if summ is None else min(summ.covered, n_pool)
        if summ is not None:
            k = summ.k
            self.starts = np.asarray(summ.starts)
            self.rowid = np.asarray(summ.rowid)
            self.inv_perm = np.empty(self.covered, np.int64)
            self.inv_perm[self.rowid] = np.arange(self.covered)
            view_perm = in_view[self.rowid]
            live = (BIG if warm_mind is None
                    else warm_mind[self.rowid].astype(np.float64))
            self.mind_x = np.where(view_perm, live, -1.0).astype(np.float32)
            self.seg_alive = np.array(
                [int(view_perm[int(self.starts[j]):
                               int(self.starts[j + 1])].sum())
                 for j in range(k)])
            self.seg_pending = np.zeros(k, np.int64)
            self.T_sqrt = np.full(k, np.inf, np.float64)
            self.M = np.full(k, np.inf, np.float64)
        # the tail: rows past the covered prefix, always scanned
        tail_live = (BIG if warm_mind is None
                     else warm_mind[self.covered:].astype(np.float64))
        self.tail_mind = np.where(in_view[self.covered:], tail_live,
                                  -1.0).astype(np.float32)
        self.tail_alive = int(in_view[self.covered:].sum())
        self.tail_pending = 0
        if warm_mind is not None and warm_centers is not None \
                and len(warm_centers):
            self._tighten(np.asarray(warm_centers, np.float32))

    # ------------------------------------------------------------ state --
    def row_vec(self, pool_row: int) -> np.ndarray:
        return np.asarray(self.pool_feats[pool_row], np.float32)

    def add_center(self, vec: np.ndarray) -> None:
        self.entries.append(np.asarray(vec, np.float32)[None, :])
        self._tighten(self.entries[-1])

    def add_warm_start(self, centers: np.ndarray, r_block: int) -> None:
        """Queue init centers in the SAME r_block chunks the ungated
        ``warm_start_min_dist`` folds, so the multi-center matmul path
        produces the identical floats per chunk."""
        c = np.asarray(centers, np.float32)
        for s in range(0, c.shape[0], r_block):
            self.entries.append(c[s:s + r_block])
            self._tighten(self.entries[-1])

    def _tighten(self, batch: np.ndarray) -> None:
        if self.summary is None:
            return
        c = np.asarray(batch, np.float64)                  # (R, d)
        diff = self.summary.cents[:, None, :] - c[None, :, :]
        d2 = np.einsum("krd,krd->kr", diff, diff)          # (k, R)
        t = np.sqrt(d2) + self.summary.radii[:, None]
        self.T_sqrt = np.minimum(self.T_sqrt, t.min(axis=1))

    def mask_pool_row(self, pool_row: int) -> None:
        if pool_row >= self.covered:
            self.tail_mind[pool_row - self.covered] = -1.0
            self.tail_alive -= 1
            return
        xp = int(self.inv_perm[pool_row])
        self.mind_x[xp] = -1.0
        j = int(np.searchsorted(self.starts, xp, side="right")) - 1
        self.seg_alive[j] -= 1

    # ------------------------------------------------------------ folds --
    def _fold_slice(self, x_slice, mind_slice, pending_from: int):
        """Fold entries[pending_from:] into one contiguous row slice via
        the exact single/multi-center fused rounds (padded to a bucketed
        shape so jit retraces stay O(log) across ragged clusters).
        Returns (new mind, best score, best slice-local row)."""
        m = int(x_slice.shape[0])
        p = _bucket(m)
        d = x_slice.shape[1]
        xp = np.zeros((p, d), np.float32)
        xp[:m] = x_slice
        mp = np.full(p, -1.0, np.float32)
        mp[:m] = mind_slice
        xj = jnp.asarray(xp)
        nm = jnp.asarray(mp)
        li, lv = 0, -BIG
        for entry in self.entries[pending_from:]:
            sel = jnp.full((entry.shape[0],), -1, jnp.int32)
            nm, li, lv = ops.greedy_round(xj, nm, jnp.asarray(entry), sel,
                                          impl=self.impl)
        if pending_from >= len(self.entries):
            # nothing pending: score the current min-dists (vector op, no
            # pool rows read)
            sc = ops.masked_weighted_score(nm)
            li = jnp.argmax(sc)
            lv = sc[li]
        # writable copy: callers keep it as mutable fold state (winner
        # masking writes -1.0 into it), and np.asarray of a jax array is
        # a read-only view
        return np.array(nm[:m]), float(lv), int(li)

    def _fold_seg(self, j: int):
        s, e = int(self.starts[j]), int(self.starts[j + 1])
        x = self.summary.xperm[s:e]
        nm, lv, li = self._fold_slice(x, self.mind_x[s:e],
                                      int(self.seg_pending[j]))
        self.mind_x[s:e] = nm
        self.seg_pending[j] = len(self.entries)
        self.M[j] = lv
        if li >= e - s:                      # all rows dead: pad row won
            return None
        return (lv, int(self.rowid[s + li]))

    def _fold_tail(self):
        n_tail = self.tail_mind.shape[0]
        if n_tail == 0 or self.tail_alive <= 0:
            return None
        x = self.pool_feats[self.covered:]
        nm, lv, li = self._fold_slice(x, self.tail_mind, self.tail_pending)
        self.tail_mind = nm
        self.tail_pending = len(self.entries)
        if li >= n_tail:
            return None
        return (lv, self.covered + li)

    # ---------------------------------------------------------- propose --
    def propose(self):
        """Best-first gated scan: evaluate the tail + clusters in
        descending upper-bound order until ``ub * (1 + slack) < best``.
        Returns ``(score, global index, pool row)`` or None."""
        best = self._fold_tail()
        if self.summary is not None:
            ub = np.minimum(self.M, np.square(self.T_sqrt))
            order = sorted((j for j in range(self.summary.k)
                            if self.seg_alive[j] > 0),
                           key=lambda j: (-ub[j], j))
            for j in order:
                if best is not None and ub[j] * (1.0 + self.slack) < best[0]:
                    break                    # ordered desc: rest is pruned
                cand = self._fold_seg(j)
                if cand is not None and (best is None or cand[0] > best[0]
                                         or (cand[0] == best[0]
                                             and cand[1] < best[1])):
                    best = cand
        if best is None:
            return None
        val, pool_row = best
        return (val, int(self.gpos[pool_row]), pool_row)


def gated_greedy_select(rng, budget: int, shards: Sequence, *,
                        init_centers=None, slack: float = 0.05,
                        executor=None, impl: str = "auto",
                        state=None) -> np.ndarray:
    """Replica-sharded greedy k-center with the centroid gate — same
    local-propose / global-merge round structure as
    ``selection.replica_greedy_select``, same rng schedule, same
    (value desc, global index asc) merges.

    ``state`` (a ``core.selection.KCenterState``) seeds each engine's
    segment/tail min-dists from the session's persisted pool-level fold,
    so the warm start streams ZERO pool rows instead of every row once."""
    N = selection.replica_total(shards)
    nsh = len(shards)
    warm = init_centers is not None and init_centers.shape[0] > 0
    init = np.asarray(init_centers, np.float32) if warm else None
    engines = [(_ShardEngine(s, slack, impl,
                             warm_mind=(state.pool_mind(i)
                                        if state is not None and warm
                                        else None),
                             warm_centers=init if state is not None else None)
                if s.n else None)
               for i, s in enumerate(shards)]
    sel = np.zeros((budget,), np.int64)
    if warm:
        if state is None:
            for i, e in enumerate(engines):
                if e is not None:
                    rb = ops.autotuned_blocks(shards[i].n,
                                              init.shape[1]).r_block
                    e.add_warm_start(init, rb)
        start = 0
    else:
        # same rng call over the same N as the ungated path: same seed row
        first = int(jax.random.randint(rng, (), 0, N))
        fsi, fli = selection.locate_row(shards, first)
        seed = np.asarray(shards[fsi].feats[fli], np.float32)
        for e in engines:
            if e is not None:
                e.add_center(seed)
        fpool = (int(shards[fsi].pool_rows[fli])
                 if shards[fsi].pool_rows is not None else fli)
        engines[fsi].mask_pool_row(fpool)
        sel[0] = first
        start = 1

    def propose(i):
        e = engines[i]
        if e is None:
            return None
        p = e.propose()
        if p is None:
            return None
        return (p[0], p[1], i, p[2])

    for slot in range(start, budget):
        props = selection.replica_map(propose, range(nsh), executor)
        got = selection._merge_proposals(props)
        _, g, wi, pool_row = got
        sel[slot] = g
        center = engines[wi].row_vec(pool_row)
        engines[wi].mask_pool_row(pool_row)
        if slot + 1 < budget:
            for e in engines:
                if e is not None:
                    e.add_center(center)
    return sel
