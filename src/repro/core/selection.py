"""Distributed AL selection over a device mesh (pod-scale data selection)
and host-level replica sharding (service scale-out).

The paper's stage-level parallelism scales out here: every data shard scores
its slice of the pool locally, then

  * ``distributed_top_k``  — budget-B selection via local top-B + all_gather
    merge (log-depth reduction semantics; each device ships only B
    candidates, not its whole shard), and
  * ``distributed_k_center`` — greedy k-center where each round does a local
    argmax + a tiny all_gather of (dist, index, vector) candidates,

both as ``shard_map`` programs over the ``data`` axis with ``jax.lax``
collectives. Selection cost per round is O(pool/n_devices) compute +
O(n_devices x d) comm — independent of global pool size.

The second half of this module generalizes the same local-propose /
global-merge round structure to *host-level replica shards* — the serving
layer's ``replicas: N`` config. A pool is hash-partitioned by content key
(``replica_of``), each shard scores its rows on a thread-pool worker, and
the merges (``replica_top_k`` for the uncertainty family,
``replica_greedy_select`` for every greedy/k-center-lineage strategy) are
constructed to be bit-identical to the single-pool path:

  * every per-row computation (distances, uncertainty scores, weights) is
    slice-invariant — a shard's rows produce the same floats they would
    inside the full matrix;
  * shard-local row order preserves global pool order, so a shard-local
    argmax tie-break (lowest local index) IS the lowest global index within
    that shard;
  * cross-shard merges order candidates by (value desc, global index asc),
    exactly ``jnp.argmax`` / ``jax.lax.top_k`` semantics on the
    concatenated vector.

``ShardColumns`` + ``grow_append`` are the storage side of the same
contract: each shard's (feats, probs) artifact columns live in growable
append-only buffers with per-column epoch stamps, so a data change
refreshes O(delta) rows on the touched shards only (incremental view
maintenance) while queries pin immutable row-range snapshots.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
import zlib
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import Mesh, PartitionSpec as P


def shard_map(f, **kw):
    """shard_map with the static-replication check disabled (outputs are
    made replicated *dynamically* by the trailing all_gathers)."""
    try:
        return _shard_map(f, check_vma=False, **kw)
    except TypeError:  # older jax spelling
        return _shard_map(f, check_rep=False, **kw)


def distributed_top_k(scores: jax.Array, budget: int, mesh: Mesh,
                      axis: str = "data") -> jax.Array:
    """Global top-``budget`` indices of a data-sharded score vector.

    scores: (N,) sharded over ``axis``. Returns (budget,) global indices,
    replicated.
    """
    n_dev = mesh.shape[axis]
    N = scores.shape[0]
    shard = N // n_dev

    def local(s):
        s = s.reshape(-1)
        b = min(budget, s.shape[0])
        v, i = jax.lax.top_k(s, b)
        if b < budget:
            v = jnp.pad(v, (0, budget - b), constant_values=-jnp.inf)
            i = jnp.pad(i, (0, budget - b))
        base = jax.lax.axis_index(axis) * shard
        gi = i + base
        # merge: gather every device's candidates, take global top-B
        av = jax.lax.all_gather(v, axis)            # (n_dev, B)
        ai = jax.lax.all_gather(gi, axis)
        fv, fi = jax.lax.top_k(av.reshape(-1), budget)
        return ai.reshape(-1)[fi].astype(jnp.int32)

    fn = shard_map(local, mesh=mesh, in_specs=P(axis),
                   out_specs=P())
    return fn(scores)


def distributed_k_center(embeddings: jax.Array, budget: int, mesh: Mesh,
                         axis: str = "data",
                         init_center: Optional[jax.Array] = None,
                         impl: str = "auto",
                         weights: Optional[jax.Array] = None) -> jax.Array:
    """Greedy k-center over a data-sharded (N, d) embedding pool.

    Per round: all_gather the previous round's (value, global index, vector)
    candidates -> replicated argmax picks the winner -> ONE fused local pool
    pass (repro/kernels/pairwise.greedy_round) folds the winning vector into
    the local min-dists, masks the winner on its home shard, and yields the
    next local candidate. Returns (budget,) global indices.

    ``weights`` (optional (N,), sharded like the pool) makes every local
    pass the *weighted* fused round: local candidates — and therefore the
    cross-shard argmax, which compares the rounds' returned scores — rank
    by ``min_dist * weight``. The hybrid strategies ship uncertainty here.
    """
    from repro.kernels.pairwise import ops
    n_dev = mesh.shape[axis]
    N, d = embeddings.shape
    shard = N // n_dev
    weighted = weights is not None
    w_arr = (jnp.ones((N,), jnp.float32) if weights is None
             else weights.astype(jnp.float32))

    def local(emb, wloc):
        emb = emb.reshape(shard, d).astype(jnp.float32)
        wloc = wloc.reshape(shard)
        base = jax.lax.axis_index(axis) * shard
        sel = jnp.zeros((budget,), jnp.int32)
        start = 0
        if init_center is None:
            # seed = global point 0; it IS the first returned center
            # (sel[0] stays 0 == the seed's global index)
            c0 = jax.lax.all_gather(emb[:1], axis)[0, 0]
            start = 1
        else:
            c0 = init_center.astype(jnp.float32)
        mind = jnp.sum((emb - c0) ** 2, axis=-1)
        if init_center is None:
            on_shard0 = jax.lax.axis_index(axis) == 0
            mind = jnp.where((jnp.arange(shard) == 0) & on_shard0, -1.0, mind)
        if weighted:
            score0 = ops.masked_weighted_score(mind, wloc)
        else:
            score0 = mind
        li = jnp.argmax(score0).astype(jnp.int32)
        lv = score0[li]

        def body(i, carry):
            mind, sel, li, lv = carry
            cand_v = jax.lax.all_gather(lv, axis)          # (n_dev,)
            cand_i = jax.lax.all_gather(li + base, axis)
            cand_e = jax.lax.all_gather(emb[li], axis)     # (n_dev, d)
            w = jnp.argmax(cand_v)
            sel = sel.at[i].set(cand_i[w].astype(jnp.int32))
            center = cand_e[w]
            # never re-pick the winner on its home shard
            is_mine = (cand_i[w] >= base) & (cand_i[w] < base + shard)
            mask = jnp.where(is_mine, cand_i[w] - base, -1).astype(jnp.int32)
            mind, li, lv = ops.greedy_round(
                emb, mind, center[None, :], mask[None],
                weights=wloc if weighted else None, impl=impl)
            return mind, sel, li, lv

        _, sel, _, _ = jax.lax.fori_loop(start, budget, body,
                                         (mind, sel, li, lv))
        return sel

    fn = shard_map(local, mesh=mesh, in_specs=(P(axis), P(axis)),
                   out_specs=P())
    return fn(embeddings, w_arr)


def sharded_scores(logits: jax.Array, kind: str, mesh: Mesh,
                   axis: str = "data") -> jax.Array:
    """Data-parallel fused uncertainty scoring (stays sharded)."""
    from repro.kernels.uncertainty import ops

    def local(lg):
        return ops.uncertainty_scores(lg, kind)

    fn = shard_map(local, mesh=mesh, in_specs=P(axis, None),
                   out_specs=P(axis))
    return fn(logits)


# ===========================================================================
# Host-level replica sharding (the serving layer's ``replicas: N``)
# ===========================================================================

def replica_of(key: str, replicas: int) -> int:
    """Content-hash shard assignment: stable across pool mutations, so a
    sample lands on the same replica no matter when (or how often) it is
    pushed."""
    return zlib.crc32(key.encode()) % max(int(replicas), 1)


@dataclasses.dataclass
class ShardView:
    """One replica shard's slice of the (unlabeled) pool.

    Rows are in global pool order; ``gidx[i]`` is row ``i``'s position in
    that global order. Preserving the order inside each shard is what makes
    shard-local argmax tie-breaks (lowest local index) compose with the
    cross-shard merge (lowest global index) into exactly the single-pool
    ``jnp.argmax`` rule.
    """
    feats: np.ndarray                 # (n, d)
    probs: Optional[np.ndarray]       # (n, C) or None
    gidx: np.ndarray                  # (n,) int64 global positions
    # -- centroid-prefilter context (optional; None = ungated) ----------
    # the shard's pinned CentroidSummary (core.prefilter), its pool-local
    # row ids for the view rows, the full pinned (rows, d) feats view the
    # summary's permutation indexes into, and the probs head epoch the
    # snapshot was pinned at (gates the summary's cached score caps)
    summary: Optional[Any] = None
    pool_rows: Optional[np.ndarray] = None    # (n,) int64 shard-local rows
    pool_feats: Optional[np.ndarray] = None   # (rows, d) pinned feats view
    probs_epoch: int = -1

    @property
    def n(self) -> int:
        return int(self.gidx.shape[0])


class ColumnSpill:
    """mmap-backed allocation for artifact columns past a RAM budget.

    Buffers whose capacity exceeds ``ram_bytes`` are allocated as
    ``np.memmap`` files instead of RAM arrays, so a shard's pool can
    outgrow memory with NO change to the epoch/snapshot contract: the
    append-only discipline means spilled rows are immutable once written,
    and a pinned ``buf[:rows]`` view over a memmap behaves exactly like
    one over a RAM array.

    Files follow the cache's atomic-publish idiom (size via truncate on a
    tmp name, then ``os.replace``) so a killed process never leaves a
    half-sized file for a later reader to map. Unlike the cache's zstd
    spill, columns stay uncompressed — they are live random-access
    mappings, not cold blobs. ``release`` unlinks a superseded buffer's
    file; POSIX keeps the data alive for any still-pinned mapping, so
    snapshot views survive both growth and release.
    """

    def __init__(self, directory: str, ram_bytes: int):
        self.directory = directory
        self.ram_bytes = int(ram_bytes)
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._seq = 0
        self.spill_events = 0       # allocations that went to disk
        self.spilled_bytes = 0      # capacity bytes currently mmap-backed

    def should_spill(self, nbytes: int) -> bool:
        return int(nbytes) > self.ram_bytes

    def allocate(self, shape: Tuple[int, ...], dtype) -> np.memmap:
        dt = np.dtype(dtype)
        nbytes = int(np.prod(shape)) * dt.itemsize
        with self._lock:
            seq = self._seq
            self._seq += 1
        final = os.path.join(self.directory, f"col-{seq:08d}.mmap")
        tmp = final + f".tmp.{os.getpid()}"
        os.makedirs(self.directory, exist_ok=True)   # survive a cleanup race
        with open(tmp, "wb") as f:
            f.truncate(max(nbytes, 1))
        os.replace(tmp, final)
        # open AFTER the rename so the mapping's .filename is the final
        # path — release() unlinks by that name
        m = np.memmap(final, dtype=dt, mode="r+", shape=shape)
        with self._lock:
            self.spill_events += 1
            self.spilled_bytes += nbytes
        return m

    def release(self, arr) -> None:
        """Unlink a superseded buffer's backing file (no-op for RAM
        arrays). Pinned snapshot views keep reading the unlinked data."""
        if not isinstance(arr, np.memmap):
            return
        with self._lock:
            self.spilled_bytes -= int(arr.nbytes)
        try:
            os.unlink(arr.filename)
        except OSError:
            pass

    def adopt(self, arr: np.ndarray) -> np.ndarray:
        """Copy ``arr`` into a fresh mmap buffer when it is past the RAM
        budget; return it unchanged otherwise (whole-buffer allocations
        such as head-refresh probs and summary permutations)."""
        if not self.should_spill(arr.nbytes):
            return arr
        m = self.allocate(arr.shape, arr.dtype)
        m[...] = arr
        return m


def grow_append(buf: Optional[np.ndarray], rows: int, new: np.ndarray,
                spill: Optional[ColumnSpill] = None
                ) -> Tuple[np.ndarray, int]:
    """Append ``new`` rows to a growable buffer; amortized O(rows added).

    Returns ``(buffer, valid_rows)``. Capacity doubles on overflow, so a
    pool built from B-row pushes costs O(N) row copies total instead of the
    O(N^2) of re-stacking the pool per push. The append discipline is what
    makes buffers safe to snapshot concurrently: rows ``[0:rows]`` are
    never rewritten (a reallocation leaves the old buffer intact for any
    pinned view), so a reader holding ``buf[:rows]`` can never observe a
    mutation.

    With ``spill`` (a ``ColumnSpill``), a reallocation whose capacity
    bytes exceed the spill's RAM budget lands in an mmap-backed file
    instead of RAM, and the superseded buffer's file (if any) is
    unlinked — pinned views keep their mapping either way.
    """
    new = np.asarray(new)
    if buf is not None and rows and (buf.shape[1:] != new.shape[1:]
                                     or buf.dtype != new.dtype):
        # appending incompatible rows would either crash the copy or
        # silently cast the old rows — both corrupt the column; fail loud
        raise ValueError(
            f"grow_append: rows of shape {new.shape[1:]}/{new.dtype} "
            f"cannot extend a buffer of {buf.shape[1:]}/{buf.dtype}")
    need = rows + int(new.shape[0])
    if buf is None or buf.shape[0] < need or buf.shape[1:] != new.shape[1:] \
            or buf.dtype != new.dtype:     # latter two only when rows == 0
        cap = max(need, 2 * (0 if buf is None else int(buf.shape[0])), 8)
        shape = (cap,) + new.shape[1:]
        nbytes = int(np.prod(shape)) * new.dtype.itemsize
        if spill is not None and spill.should_spill(nbytes):
            grown = spill.allocate(shape, new.dtype)
        else:
            grown = np.empty(shape, new.dtype)
        if buf is not None and rows:
            grown[:rows] = buf[:rows]
        if spill is not None and buf is not None:
            spill.release(buf)
        buf = grown
    buf[rows:need] = new
    return buf, need


class ShardColumns:
    """Incrementally-maintained artifact columns for ONE replica shard.

    The two columns have decoupled lifetimes, each stamped with the epoch
    it is fresh at:

    ``feats``
        Growable (cap, d) buffer; rows ``[0:feats_rows]`` valid, stamped
        ``feats_epoch`` (the shard's ``rows_epoch`` at refresh). A delta
        refresh embeds ONLY ``keys[feats_rows:]`` and extends the buffer
        in place — O(delta), never a full re-stack.
    ``probs``
        Growable (cap, C) buffer; rows ``[0:probs_rows]`` valid, stamped
        ``probs_head_epoch``. A head bump recomputes all rows from the
        cached feats into a FRESH buffer (zero re-embeds, and pinned
        snapshots keep their old rows); a rows-only change appends probs
        for just the new rows.

    Thread contract: mutated only under the owning session's artifact
    lock; ``keys`` is append-only (appends happen under the session pool
    lock), so slicing it against a captured bound is race-free.
    """

    __slots__ = ("keys", "rows_epoch", "feats", "feats_rows", "feats_epoch",
                 "probs", "probs_rows", "probs_head_epoch", "builds",
                 "spill", "summary", "lineage")

    def __init__(self, spill: Optional[ColumnSpill] = None):
        self.keys: list = []          # shard-local key order == global order
        self.rows_epoch = 0           # bumps per row-appending event
        self.feats: Optional[np.ndarray] = None
        self.feats_rows = 0
        self.feats_epoch = 0
        self.probs: Optional[np.ndarray] = None
        self.probs_rows = 0
        self.probs_head_epoch = -1    # -1 = never computed
        self.builds = 0               # refresh events that touched this shard
        self.spill = spill            # None = RAM-only columns
        self.summary = None           # CentroidSummary (core.prefilter)
        self.lineage = 0              # bumps when rows [0:feats_rows] are
        #                               no longer append-extensions of what a
        #                               cached per-row state saw (reset())

    def reset(self) -> None:
        """Drop both columns (the non-incremental full-rebuild path)."""
        if self.spill is not None:
            self.spill.release(self.feats)
            self.spill.release(self.probs)
            if self.summary is not None:
                self.spill.release(getattr(self.summary, "xperm", None))
        self.feats, self.feats_rows, self.feats_epoch = None, 0, 0
        self.probs, self.probs_rows, self.probs_head_epoch = None, 0, -1
        self.summary = None
        self.lineage += 1

    def feats_view(self, d: int) -> np.ndarray:
        if self.feats is None:
            return np.zeros((0, d), np.float32)
        return self.feats[:self.feats_rows]

    def probs_view(self, c: int) -> np.ndarray:
        if self.probs is None:
            return np.zeros((0, c), np.float32)
        return self.probs[:self.probs_rows]


def replica_map(fn: Callable, items: Sequence, executor=None) -> list:
    """Apply ``fn`` to every item — across the shard thread pool when one
    is given (per-shard scoring runs in parallel), serially otherwise."""
    items = list(items)
    if executor is None or len(items) <= 1:
        return [fn(it) for it in items]
    return list(executor.map(fn, items))


def replica_total(shards: Sequence[ShardView]) -> int:
    return sum(s.n for s in shards)


def locate_row(shards: Sequence[ShardView], gidx: int) -> Tuple[int, int]:
    """(shard, local row) of a global pool position."""
    for si, s in enumerate(shards):
        j = int(np.searchsorted(s.gidx, gidx))
        if j < s.n and int(s.gidx[j]) == gidx:
            return si, j
    raise IndexError(f"global row {gidx} not on any shard")


def gather_rows(shards: Sequence[ShardView], rows: Sequence[int],
                arrays: Optional[Sequence[np.ndarray]] = None) -> np.ndarray:
    """Gather global pool rows into one array — the coordinator-side
    collect for warm starts, density references, DBAL's prefiltered subset
    and per-row scalars (``arrays`` may have any trailing shape; defaults
    to the shard feature matrices)."""
    if arrays is None:
        arrays = [np.asarray(s.feats) for s in shards]
    out = []
    for g in rows:
        si, li = locate_row(shards, int(g))
        out.append(np.asarray(arrays[si])[li])
    if not out:
        a0 = np.asarray(arrays[0])
        return np.zeros((0,) + a0.shape[1:], a0.dtype)
    return np.stack(out)


def replica_top_k(shards: Sequence[ShardView],
                  scores_list: Sequence[jax.Array], budget: int,
                  executor=None) -> Tuple[np.ndarray, np.ndarray]:
    """Exact ``jax.lax.top_k`` over a sharded score vector.

    Each shard ships only its local top-min(budget, n) candidates; the merge
    orders them by (value desc, global index asc) — ``lax.top_k``'s
    documented tie rule — so the returned (indices, values) match the
    single-pool call bit-for-bit.
    """
    def local(args):
        s, sc = args
        if s.n == 0:
            return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
        b = min(budget, s.n)
        v, i = jax.lax.top_k(jnp.asarray(sc), b)
        return np.asarray(v), s.gidx[np.asarray(i)]

    parts = replica_map(local, list(zip(shards, scores_list)), executor)
    vals = np.concatenate([p[0] for p in parts])
    gidx = np.concatenate([p[1] for p in parts])
    order = np.lexsort((gidx, -vals))[:budget]
    return gidx[order], vals[order]


def replica_seed_min_dist(shards: Sequence[ShardView],
                          emb_list: Sequence[jax.Array], first: int):
    """Per-shard min sq-dists to the seed center at global row ``first``,
    with the seed's own row masked (-1.0) on its home shard — the shared
    init for every greedy loop whose first center is a random draw
    (k-center greedy, BADGE's D² sampling)."""
    from repro.kernels.pairwise import ops
    fsi, fli = locate_row(shards, first)
    mind = []
    for i, s in enumerate(shards):
        if s.n == 0:
            mind.append(None)
            continue
        m = ops.sq_dist_to_center(emb_list[i], emb_list[fsi][fli])
        if i == fsi:
            m = m.at[fli].set(-1.0)
        mind.append(m)
    return mind


def _merge_proposals(props):
    """Cross-shard winner: max value, ties to the lowest global index —
    the sharded spelling of ``jnp.argmax`` over the concatenated scores."""
    best = None
    for p in props:
        if p is None:
            continue
        if best is None or p[0] > best[0] or (p[0] == best[0]
                                              and p[1] < best[1]):
            best = p
    return best


def replica_greedy_select(shards: Sequence[ShardView],
                          emb_list: Sequence[jax.Array], budget: int, *,
                          mind_list: Sequence[Optional[jax.Array]],
                          sel: np.ndarray, start: int,
                          weight_for_slot: Callable[[int, int], Optional[jax.Array]],
                          executor=None, impl: str = "auto",
                          capture: Optional[list] = None) -> np.ndarray:
    """Local-propose / global-dedup greedy rounds over replica shards —
    ``distributed_k_center``'s round structure generalized to hash-sharded
    pools and per-slot weights (static weights for weighted k-center,
    fresh Gumbel draws per slot for BADGE's D² sampling).

    Per slot: every shard runs ONE fused ``greedy_round`` over its rows
    (min-dist fold + winner masking + local weighted argmax), proposes
    ``(score, global index)``, and the coordinator merge picks the winner.
    ``weight_for_slot(slot, shard)`` supplies the weights ranking the
    candidate for ``slot``. Bit-identical to the single-pool greedy loop:
    the per-row floats are slice-invariant and both tie-break layers reduce
    to the lowest global index.

    ``capture`` (optional list) records the merged winner's score per slot
    in slot order — the standing-query replay engine (service layer) stores
    them so a later emit over a grown pool can prove "no new row beats any
    recorded winner" by streaming only the delta rows.
    """
    from repro.kernels.pairwise import ops
    nsh = len(shards)
    mind = list(mind_list)

    def propose(i):
        s = shards[i]
        if s.n == 0:
            return None
        sc = ops.masked_weighted_score(mind[i], weight_for_slot(start, i))
        li = int(jnp.argmax(sc))
        return (float(sc[li]), int(s.gidx[li]), i, li)

    props = replica_map(propose, range(nsh), executor)
    for slot in range(start, budget):
        v, g, win_shard, win_local = _merge_proposals(props)
        if capture is not None:
            capture.append(float(v))
        sel[slot] = g
        center = emb_list[win_shard][win_local]

        def fold(i, win_shard=win_shard, win_local=win_local,
                 center=center, slot=slot):
            s = shards[i]
            if s.n == 0:
                return None
            mask = jnp.asarray(
                [win_local if i == win_shard else -1], jnp.int32)
            nm, li, lv = ops.greedy_round(
                emb_list[i], mind[i], center[None, :], mask,
                weights=weight_for_slot(slot + 1, i), impl=impl)
            mind[i] = nm
            return (float(lv), int(s.gidx[int(li)]), i, int(li))

        props = replica_map(fold, range(nsh), executor)
    return sel


# ===========================================================================
# Persistent per-session k-center strategy state (O(delta) warm starts)
# ===========================================================================

@dataclasses.dataclass
class KCenterState:
    """One query's view of the persisted min-dist state.

    ``minds[si]`` is the shard's (rows,) float32 min squared distance of
    every POOL row (labeled and unlabeled alike) to the folded center set.
    The arrays are owned by the cache and treated as immutable — consumers
    gather or copy, never write.
    """
    minds: Sequence[np.ndarray]
    rows: Sequence[int]
    # standing-query replay capture: when set, ``sharded_k_center`` threads
    # it into ``replica_greedy_select(capture=...)``
    capture: Optional[list] = None

    def view_minds(self, shards) -> list:
        """Per-shard min-dists gathered down to the query's (unlabeled)
        view rows, as jnp arrays ready for the greedy loop. Requires
        ``ShardView.pool_rows``. Row gathers reproduce the exact floats a
        from-scratch ``warm_start_min_dist`` over the view would compute:
        per-(row, center) distances are slice-invariant (module contract)
        and the min fold is exact."""
        out = []
        for i, s in enumerate(shards):
            if s.n == 0:
                out.append(None)
                continue
            out.append(jnp.asarray(self.minds[i][np.asarray(s.pool_rows)]))
        return out

    def pool_mind(self, i: int) -> np.ndarray:
        return self.minds[i]


class KCenterStateCache:
    """Per-session persisted k-center min-dist vectors (ROADMAP: carry the
    artifact epoch-stamping into strategy state).

    The cache keys per-shard min-dist columns on the same append-only
    discipline as ``ShardColumns``: a vector computed over rows
    ``[0:rows]`` against centers ``locs[:k]`` stays exact when rows are
    appended (extend by folding ALL centers over just the new rows) or
    centers are appended (fold just the new centers over all rows and take
    the elementwise min) — both O(delta), both bitwise equal to a
    from-scratch fold because per-(row, center) squared distances are
    invariant to which other rows/centers share the call and ``min`` is an
    exact, order-independent fold. Validity stamps:

      * shard ``lineage`` — a ``ShardColumns.reset()`` invalidates the
        shard (its feats rows are no longer an append-extension);
      * ``head_version`` — a head retrain invalidates everything (the
        spec's conservative row of the invalidation matrix; labeling a
        sample invalidates NOTHING since pool rows and feats are
        untouched, it only appends centers);
      * center ``locs`` prefix — cached center order must be a prefix of
        the query's fold order, else rebuild.

    Thread contract: ``prepare`` is the only mutator and serializes on an
    internal lock (PSHEA candidate races); handed-out arrays are never
    written again (extends allocate fresh arrays).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._minds: dict = {}       # si -> np (rows,) f32
        self._rows: dict = {}        # si -> int
        self._lineage: dict = {}     # si -> int
        self._locs: tuple = ()       # ((si, li), ...) centers in fold order
        self._head_version = -1
        self.counters = {
            "rebuilds": 0, "extends": 0, "center_extends": 0,
            "invalidations": 0, "hits": 0,
            "rows_extended": 0, "rows_reused": 0,
        }

    def _drop_all(self):
        if self._minds or self._locs:
            self.counters["invalidations"] += 1
        self._minds, self._rows, self._lineage = {}, {}, {}
        self._locs = ()

    def invalidate(self) -> None:
        """Head retrain: min-dists are conservatively dropped on every
        shard; feats columns are untouched so nothing re-embeds."""
        with self._lock:
            self._drop_all()
            self._head_version = -1

    def stats(self) -> dict:
        with self._lock:
            return dict(self.counters)

    def prepare(self, *, feats_l, rows_l, lineages, head_version, locs,
                centers, capture=None) -> Optional[KCenterState]:
        """Produce this query's :class:`KCenterState`, reusing cached
        vectors where the stamps allow and folding only the row/center
        deltas. ``centers[k]`` must be the feats row at ``locs[k]``."""
        from repro.kernels.pairwise import ops
        locs = tuple(tuple(p) for p in locs)
        k = len(locs)
        if k == 0:
            return None
        centers = np.asarray(centers, np.float32)
        nsh = len(feats_l)
        with self._lock:
            if head_version != self._head_version:
                self._drop_all()
                self._head_version = head_version
            kc = len(self._locs)
            if self._locs != locs[:kc]:
                # non-prefix center reorder (e.g. a relabel changed fold
                # order) — exactness is unprovable incrementally
                self._drop_all()
                kc = 0
            new_centers = centers[kc:]
            reused = False
            minds, rows_out = [], []
            for si in range(nsh):
                rows = int(rows_l[si])
                feats = np.asarray(feats_l[si])[:rows]
                m = self._minds.get(si)
                if m is not None and self._lineage.get(si) != lineages[si]:
                    self.counters["invalidations"] += 1
                    m = None
                if m is None:
                    if rows:
                        m = np.asarray(ops.warm_start_min_dist(
                            jnp.asarray(feats), jnp.asarray(centers)),
                            np.float32)
                    else:
                        m = np.zeros((0,), np.float32)
                    self.counters["rebuilds"] += 1
                else:
                    reused = True
                    rc = int(self._rows[si])
                    if len(new_centers) and rc:
                        # center delta: fold only the new centers over the
                        # cached rows; elementwise min == one joint fold
                        nm = np.asarray(ops.warm_start_min_dist(
                            jnp.asarray(feats[:rc]),
                            jnp.asarray(new_centers)), np.float32)
                        m = np.minimum(m[:rc], nm)
                        self.counters["center_extends"] += 1
                    if rows > rc:
                        # row delta: fold ALL centers over just the new rows
                        ext = np.asarray(ops.warm_start_min_dist(
                            jnp.asarray(feats[rc:rows]),
                            jnp.asarray(centers)), np.float32)
                        m = np.concatenate([m[:rc], ext])
                        self.counters["extends"] += 1
                        self.counters["rows_extended"] += rows - rc
                    self.counters["rows_reused"] += min(rows, rc)
                if rows >= int(self._rows.get(si, -1)):
                    # store the newest view (a raced query pinned at older
                    # rows serves a slice without shrinking the cache)
                    self._minds[si] = m
                    self._rows[si] = max(rows, int(self._rows.get(si, 0)))
                    self._lineage[si] = lineages[si]
                minds.append(m[:rows])
                rows_out.append(rows)
            self._locs = locs
            if reused:
                self.counters["hits"] += 1
            return KCenterState(minds=minds, rows=rows_out, capture=capture)
