"""Distributed AL selection over a device mesh (pod-scale data selection).

The paper's stage-level parallelism scales out here: every data shard scores
its slice of the pool locally, then

  * ``distributed_top_k``  — budget-B selection via local top-B + all_gather
    merge (log-depth reduction semantics; each device ships only B
    candidates, not its whole shard), and
  * ``distributed_k_center`` — greedy k-center where each round does a local
    argmax + a tiny all_gather of (dist, index, vector) candidates,

both as ``shard_map`` programs over the ``data`` axis with ``jax.lax``
collectives. Selection cost per round is O(pool/n_devices) compute +
O(n_devices x d) comm — independent of global pool size.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import Mesh, PartitionSpec as P


def shard_map(f, **kw):
    """shard_map with the static-replication check disabled (outputs are
    made replicated *dynamically* by the trailing all_gathers)."""
    try:
        return _shard_map(f, check_vma=False, **kw)
    except TypeError:  # older jax spelling
        return _shard_map(f, check_rep=False, **kw)


def distributed_top_k(scores: jax.Array, budget: int, mesh: Mesh,
                      axis: str = "data") -> jax.Array:
    """Global top-``budget`` indices of a data-sharded score vector.

    scores: (N,) sharded over ``axis``. Returns (budget,) global indices,
    replicated.
    """
    n_dev = mesh.shape[axis]
    N = scores.shape[0]
    shard = N // n_dev

    def local(s):
        s = s.reshape(-1)
        b = min(budget, s.shape[0])
        v, i = jax.lax.top_k(s, b)
        if b < budget:
            v = jnp.pad(v, (0, budget - b), constant_values=-jnp.inf)
            i = jnp.pad(i, (0, budget - b))
        base = jax.lax.axis_index(axis) * shard
        gi = i + base
        # merge: gather every device's candidates, take global top-B
        av = jax.lax.all_gather(v, axis)            # (n_dev, B)
        ai = jax.lax.all_gather(gi, axis)
        fv, fi = jax.lax.top_k(av.reshape(-1), budget)
        return ai.reshape(-1)[fi].astype(jnp.int32)

    fn = shard_map(local, mesh=mesh, in_specs=P(axis),
                   out_specs=P())
    return fn(scores)


def distributed_k_center(embeddings: jax.Array, budget: int, mesh: Mesh,
                         axis: str = "data",
                         init_center: Optional[jax.Array] = None,
                         impl: str = "auto",
                         weights: Optional[jax.Array] = None) -> jax.Array:
    """Greedy k-center over a data-sharded (N, d) embedding pool.

    Per round: all_gather the previous round's (value, global index, vector)
    candidates -> replicated argmax picks the winner -> ONE fused local pool
    pass (repro/kernels/pairwise.greedy_round) folds the winning vector into
    the local min-dists, masks the winner on its home shard, and yields the
    next local candidate. Returns (budget,) global indices.

    ``weights`` (optional (N,), sharded like the pool) makes every local
    pass the *weighted* fused round: local candidates — and therefore the
    cross-shard argmax, which compares the rounds' returned scores — rank
    by ``min_dist * weight``. The hybrid strategies ship uncertainty here.
    """
    from repro.kernels.pairwise import ops
    n_dev = mesh.shape[axis]
    N, d = embeddings.shape
    shard = N // n_dev
    weighted = weights is not None
    w_arr = (jnp.ones((N,), jnp.float32) if weights is None
             else weights.astype(jnp.float32))

    def local(emb, wloc):
        emb = emb.reshape(shard, d).astype(jnp.float32)
        wloc = wloc.reshape(shard)
        base = jax.lax.axis_index(axis) * shard
        sel = jnp.zeros((budget,), jnp.int32)
        start = 0
        if init_center is None:
            # seed = global point 0; it IS the first returned center
            # (sel[0] stays 0 == the seed's global index)
            c0 = jax.lax.all_gather(emb[:1], axis)[0, 0]
            start = 1
        else:
            c0 = init_center.astype(jnp.float32)
        mind = jnp.sum((emb - c0) ** 2, axis=-1)
        if init_center is None:
            on_shard0 = jax.lax.axis_index(axis) == 0
            mind = jnp.where((jnp.arange(shard) == 0) & on_shard0, -1.0, mind)
        if weighted:
            score0 = ops.masked_weighted_score(mind, wloc)
        else:
            score0 = mind
        li = jnp.argmax(score0).astype(jnp.int32)
        lv = score0[li]

        def body(i, carry):
            mind, sel, li, lv = carry
            cand_v = jax.lax.all_gather(lv, axis)          # (n_dev,)
            cand_i = jax.lax.all_gather(li + base, axis)
            cand_e = jax.lax.all_gather(emb[li], axis)     # (n_dev, d)
            w = jnp.argmax(cand_v)
            sel = sel.at[i].set(cand_i[w].astype(jnp.int32))
            center = cand_e[w]
            # never re-pick the winner on its home shard
            is_mine = (cand_i[w] >= base) & (cand_i[w] < base + shard)
            mask = jnp.where(is_mine, cand_i[w] - base, -1).astype(jnp.int32)
            mind, li, lv = ops.greedy_round(
                emb, mind, center[None, :], mask[None],
                weights=wloc if weighted else None, impl=impl)
            return mind, sel, li, lv

        _, sel, _, _ = jax.lax.fori_loop(start, budget, body,
                                         (mind, sel, li, lv))
        return sel

    fn = shard_map(local, mesh=mesh, in_specs=(P(axis), P(axis)),
                   out_specs=P())
    return fn(embeddings, w_arr)


def sharded_scores(logits: jax.Array, kind: str, mesh: Mesh,
                   axis: str = "data") -> jax.Array:
    """Data-parallel fused uncertainty scoring (stays sharded)."""
    from repro.kernels.uncertainty import ops

    def local(lg):
        return ops.uncertainty_scores(lg, kind)

    fn = shard_map(local, mesh=mesh, in_specs=P(axis, None),
                   out_specs=P(axis))
    return fn(logits)
