"""Hybrid strategies (uncertainty x diversity) — beyond the paper's zoo.

All three hybrids ride the SAME fused Pallas substrate as pure k-center
(repro/kernels/pairwise.greedy_round): one (N, d) pool read per selected
center, with per-row weights folded into the round's argmax.

BADGE-lite: k-means++ sampling over uncertainty-scaled embeddings — the
gradient-embedding magnitude of BADGE [2] collapses to (1 - p_max) * h for
the last-layer bias-free case, which keeps the embedding dimension at d
instead of V*d (V up to 256k here). The D^2 sampling step is a weighted
fused round via the Gumbel-max trick (see ``kmeans_pp_sample``).

margin_density: weighted k-center greedy where the weight is margin
uncertainty x local density — uncertain points in dense regions win the
per-round argmax, min-dist keeps the batch spread out.

weighted_kcenter: k-center greedy with least-confidence weights (and the
Core-Set warm start when labeled embeddings are attached).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies.base import (Strategy, unit_weights,
                                        unit_weights_parts)
from repro.core.strategies.uncertainty import lc_scores, mc_scores


def kmeans_pp_sample(rng, x, k: int, impl: str = "auto"):
    """k-means++ seeding AS the selection (BADGE's sampler). x: (N,d).

    D^2 sampling rides the fused greedy round: drawing
    ``idx ~ Categorical(p ∝ min_dist)`` equals
    ``argmax(min_dist * exp(gumbel))`` (Gumbel-max trick, exp is monotone),
    which is exactly the kernel's weighted argmax. Each round is therefore
    ONE (N, d) pool pass — min-dist fold, selected-row masking, and the
    next *sample* all in the same read — instead of the separate
    distance / minimum / scatter / categorical passes of the naive loop.
    """
    N, _ = x.shape
    x = x.astype(jnp.float32)
    from repro.kernels.pairwise import ops
    keys = jax.random.split(rng, k + 1)
    first = jax.random.randint(keys[0], (), 0, N).astype(jnp.int32)
    sel0 = jnp.zeros((k,), jnp.int32).at[0].set(first)
    mind0 = ops.sq_dist_to_center(x, x[first]).at[first].set(-1.0)
    # sampling weights for pick i are drawn from keys[i]; the round that
    # folds center i-1 already computes pick i's weighted argmax
    w1 = jnp.exp(jax.random.gumbel(keys[1], (N,), jnp.float32))
    nxt0 = jnp.argmax(ops.masked_weighted_score(mind0, w1)).astype(jnp.int32)

    def body(i, carry):
        mind, sel, nxt = carry
        sel = sel.at[i].set(nxt)
        w = jnp.exp(jax.random.gumbel(keys[i + 1], (N,), jnp.float32))
        mind, nxt, _ = ops.greedy_round(x, mind, x[nxt][None, :], nxt[None],
                                        weights=w, impl=impl)
        return mind, sel, nxt

    _, sel, _ = jax.lax.fori_loop(1, k, body, (mind0, sel0, nxt0))
    return sel


def _badge_select(rng, budget, *, probs, embeddings, labeled_embeddings=None):
    g = (lc_scores(probs)[:, None].astype(jnp.float32)
         * embeddings.astype(jnp.float32))
    return kmeans_pp_sample(rng, g, budget)


def density_scores(rng, embeddings, n_ref: int = 256):
    """Local density in [0, 1] (higher = denser): negated mean sq-dist to a
    *random* reference subset, min-max normalized. The subset is drawn with
    ``rng`` — NOT the first rows, which would make density depend on pool
    order — so the estimate is permutation-invariant in expectation."""
    from repro.kernels.pairwise import ops
    emb = embeddings.astype(jnp.float32)
    N = emb.shape[0]
    n_ref = min(n_ref, N)
    ridx = jax.random.choice(rng, N, (n_ref,), replace=False)
    d = ops.pairwise_sq_dists(emb, emb[ridx]).mean(-1)
    return 1.0 - (d - d.min()) / jnp.maximum(d.max() - d.min(), 1e-9)


def _margin_density_select(rng, budget, *, probs, embeddings,
                           labeled_embeddings=None):
    """Margin x local-density: prefer uncertain points in dense regions.

    Runs as a *weighted fused* k-center greedy: weight = margin x density,
    so every selection round is one pool pass and the returned batch is
    diverse instead of the top-k clump of a pure score sort."""
    from repro.core.strategies.diversity import k_center_greedy
    k_ref, k_sel = jax.random.split(rng)
    m = unit_weights(mc_scores(probs))
    dens = density_scores(k_ref, embeddings)
    w = unit_weights(m * dens)
    return k_center_greedy(k_sel, budget, embeddings, weights=w)


def _weighted_kcenter_select(rng, budget, *, probs, embeddings,
                             labeled_embeddings=None):
    """K-center greedy with least-confidence weights — the canonical
    uncertainty-weighted diversity strategy on the fused substrate."""
    from repro.core.strategies.diversity import k_center_greedy
    w = unit_weights(lc_scores(probs))
    return k_center_greedy(rng, budget, embeddings,
                           init_centers=labeled_embeddings, weights=w)


# ------------------------------------------------- replica-sharded paths --
def sharded_kmeans_pp(rng, x_list, shards, k: int, executor=None,
                      impl: str = "auto"):
    """Replica-sharded ``kmeans_pp_sample``: the per-slot Gumbel weights are
    drawn over the FULL (N,) pool from the same key schedule as the single
    path and sliced per shard by global position, so each D² draw is the
    identical categorical sample."""
    import threading
    from repro.core import selection
    N = selection.replica_total(shards)
    keys = jax.random.split(rng, k + 1)
    first = int(jax.random.randint(keys[0], (), 0, N))
    mind = selection.replica_seed_min_dist(shards, x_list, first)
    sel = np.zeros((k,), np.int64)
    sel[0] = first
    gumbel = {}                        # slot -> full (N,) weight draw
    gumbel_lock = threading.Lock()     # shards race on a slot's first use

    def weight_for_slot(slot, i):
        with gumbel_lock:
            if slot not in gumbel:
                # slots advance monotonically: older draws are dead
                for old in [s for s in gumbel if s < slot]:
                    del gumbel[old]
                gumbel[slot] = jnp.exp(
                    jax.random.gumbel(keys[slot], (N,), jnp.float32))
            w = gumbel[slot]
        return w[jnp.asarray(shards[i].gidx)]

    return selection.replica_greedy_select(
        shards, x_list, k, mind_list=mind, sel=sel, start=1,
        weight_for_slot=weight_for_slot, executor=executor, impl=impl)


def _badge_sharded(rng, budget, shards, *, labeled_embeddings=None,
                   executor=None, prefilter=None, state=None):
    # prefilter accepted-and-ignored: D² sampling draws fresh Gumbel
    # weights per slot, which no distance-only centroid bound can cap.
    # state likewise: BADGE's geometry is the uncertainty-scaled gradient
    # embedding, not the raw feats the persisted min-dists were folded over
    from repro.core import selection
    g_list = selection.replica_map(
        lambda s: (lc_scores(jnp.asarray(s.probs))[:, None]
                   .astype(jnp.float32)
                   * jnp.asarray(s.feats, jnp.float32)),
        shards, executor)
    return sharded_kmeans_pp(rng, g_list, shards, budget, executor=executor)


def density_scores_sharded(rng, shards, executor=None, n_ref: int = 256):
    """Sharded ``density_scores``: one global reference draw + gather, then
    per-shard mean-sq-dist rows and a global min/max normalize."""
    from repro.core import selection
    from repro.core.strategies.base import global_min_max
    from repro.kernels.pairwise import ops
    N = selection.replica_total(shards)
    n_ref = min(n_ref, N)
    ridx = np.asarray(jax.random.choice(rng, N, (n_ref,), replace=False))
    ref = jnp.asarray(selection.gather_rows(shards, ridx), jnp.float32)
    d_list = selection.replica_map(
        lambda s: ops.pairwise_sq_dists(
            jnp.asarray(s.feats, jnp.float32), ref).mean(-1)
        if s.n else jnp.zeros((0,), jnp.float32),
        shards, executor)
    lo, hi = global_min_max(d_list)
    return [1.0 - (d - lo) / jnp.maximum(hi - lo, 1e-9) for d in d_list]


def _margin_density_sharded(rng, budget, shards, *, labeled_embeddings=None,
                            executor=None, prefilter=None, state=None):
    # prefilter accepted-and-ignored: weighted rounds (see sharded_k_center).
    # state accepted-and-ignored: margin_density never warm-starts
    from repro.core import selection
    from repro.core.strategies.diversity import sharded_k_center
    k_ref, k_sel = jax.random.split(rng)
    mc_list = selection.replica_map(
        lambda s: mc_scores(jnp.asarray(s.probs)), shards, executor)
    m_list = unit_weights_parts(mc_list)
    dens_list = density_scores_sharded(k_ref, shards, executor)
    w_list = unit_weights_parts([m * d for m, d in zip(m_list, dens_list)])
    return sharded_k_center(k_sel, budget, shards, weights_list=w_list,
                            executor=executor)


def _weighted_kcenter_sharded(rng, budget, shards, *,
                              labeled_embeddings=None, executor=None,
                              prefilter=None, state=None):
    # prefilter accepted-and-ignored: weighted rounds (see sharded_k_center).
    # state IS forwarded: the warm-start min-dist fold is unweighted (weights
    # only rank the per-slot argmax), so the persisted vectors are the exact
    # floats this strategy's warm fold would recompute
    from repro.core import selection
    from repro.core.strategies.diversity import sharded_k_center
    lc_list = selection.replica_map(
        lambda s: lc_scores(jnp.asarray(s.probs)), shards, executor)
    w_list = unit_weights_parts(lc_list)
    return sharded_k_center(rng, budget, shards,
                            init_centers=labeled_embeddings,
                            weights_list=w_list, executor=executor,
                            state=state)


badge = Strategy("badge", ("probs", "embeddings"), _badge_select,
                 _badge_sharded)
margin_density = Strategy("margin_density", ("probs", "embeddings"),
                          _margin_density_select, _margin_density_sharded)
weighted_kcenter = Strategy("weighted_kcenter", ("probs", "embeddings"),
                            _weighted_kcenter_select,
                            _weighted_kcenter_sharded)
