"""Hybrid strategies (uncertainty x diversity) — beyond the paper's zoo.

All three hybrids ride the SAME fused Pallas substrate as pure k-center
(repro/kernels/pairwise.greedy_round): one (N, d) pool read per selected
center, with per-row weights folded into the round's argmax.

BADGE-lite: k-means++ sampling over uncertainty-scaled embeddings — the
gradient-embedding magnitude of BADGE [2] collapses to (1 - p_max) * h for
the last-layer bias-free case, which keeps the embedding dimension at d
instead of V*d (V up to 256k here). The D^2 sampling step is a weighted
fused round via the Gumbel-max trick (see ``kmeans_pp_sample``).

margin_density: weighted k-center greedy where the weight is margin
uncertainty x local density — uncertain points in dense regions win the
per-round argmax, min-dist keeps the batch spread out.

weighted_kcenter: k-center greedy with least-confidence weights (and the
Core-Set warm start when labeled embeddings are attached).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.strategies.base import Strategy, unit_weights
from repro.core.strategies.uncertainty import lc_scores, mc_scores


def kmeans_pp_sample(rng, x, k: int, impl: str = "auto"):
    """k-means++ seeding AS the selection (BADGE's sampler). x: (N,d).

    D^2 sampling rides the fused greedy round: drawing
    ``idx ~ Categorical(p ∝ min_dist)`` equals
    ``argmax(min_dist * exp(gumbel))`` (Gumbel-max trick, exp is monotone),
    which is exactly the kernel's weighted argmax. Each round is therefore
    ONE (N, d) pool pass — min-dist fold, selected-row masking, and the
    next *sample* all in the same read — instead of the separate
    distance / minimum / scatter / categorical passes of the naive loop.
    """
    N, _ = x.shape
    x = x.astype(jnp.float32)
    from repro.kernels.pairwise import ops
    keys = jax.random.split(rng, k + 1)
    first = jax.random.randint(keys[0], (), 0, N).astype(jnp.int32)
    sel0 = jnp.zeros((k,), jnp.int32).at[0].set(first)
    mind0 = ops.sq_dist_to_center(x, x[first]).at[first].set(-1.0)
    # sampling weights for pick i are drawn from keys[i]; the round that
    # folds center i-1 already computes pick i's weighted argmax
    w1 = jnp.exp(jax.random.gumbel(keys[1], (N,), jnp.float32))
    nxt0 = jnp.argmax(ops.masked_weighted_score(mind0, w1)).astype(jnp.int32)

    def body(i, carry):
        mind, sel, nxt = carry
        sel = sel.at[i].set(nxt)
        w = jnp.exp(jax.random.gumbel(keys[i + 1], (N,), jnp.float32))
        mind, nxt, _ = ops.greedy_round(x, mind, x[nxt][None, :], nxt[None],
                                        weights=w, impl=impl)
        return mind, sel, nxt

    _, sel, _ = jax.lax.fori_loop(1, k, body, (mind0, sel0, nxt0))
    return sel


def _badge_select(rng, budget, *, probs, embeddings, labeled_embeddings=None):
    g = (lc_scores(probs)[:, None].astype(jnp.float32)
         * embeddings.astype(jnp.float32))
    return kmeans_pp_sample(rng, g, budget)


def density_scores(rng, embeddings, n_ref: int = 256):
    """Local density in [0, 1] (higher = denser): negated mean sq-dist to a
    *random* reference subset, min-max normalized. The subset is drawn with
    ``rng`` — NOT the first rows, which would make density depend on pool
    order — so the estimate is permutation-invariant in expectation."""
    from repro.kernels.pairwise import ops
    emb = embeddings.astype(jnp.float32)
    N = emb.shape[0]
    n_ref = min(n_ref, N)
    ridx = jax.random.choice(rng, N, (n_ref,), replace=False)
    d = ops.pairwise_sq_dists(emb, emb[ridx]).mean(-1)
    return 1.0 - (d - d.min()) / jnp.maximum(d.max() - d.min(), 1e-9)


def _margin_density_select(rng, budget, *, probs, embeddings,
                           labeled_embeddings=None):
    """Margin x local-density: prefer uncertain points in dense regions.

    Runs as a *weighted fused* k-center greedy: weight = margin x density,
    so every selection round is one pool pass and the returned batch is
    diverse instead of the top-k clump of a pure score sort."""
    from repro.core.strategies.diversity import k_center_greedy
    k_ref, k_sel = jax.random.split(rng)
    m = unit_weights(mc_scores(probs))
    dens = density_scores(k_ref, embeddings)
    w = unit_weights(m * dens)
    return k_center_greedy(k_sel, budget, embeddings, weights=w)


def _weighted_kcenter_select(rng, budget, *, probs, embeddings,
                             labeled_embeddings=None):
    """K-center greedy with least-confidence weights — the canonical
    uncertainty-weighted diversity strategy on the fused substrate."""
    from repro.core.strategies.diversity import k_center_greedy
    w = unit_weights(lc_scores(probs))
    return k_center_greedy(rng, budget, embeddings,
                           init_centers=labeled_embeddings, weights=w)


badge = Strategy("badge", ("probs", "embeddings"), _badge_select)
margin_density = Strategy("margin_density", ("probs", "embeddings"),
                          _margin_density_select)
weighted_kcenter = Strategy("weighted_kcenter", ("probs", "embeddings"),
                            _weighted_kcenter_select)
