"""Hybrid strategies (uncertainty x diversity) — beyond the paper's zoo.

BADGE-lite: k-means++ sampling over uncertainty-scaled embeddings — the
gradient-embedding magnitude of BADGE [2] collapses to (1 - p_max) * h for
the last-layer bias-free case, which keeps the embedding dimension at d
instead of V*d (V up to 256k here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.strategies.base import Strategy
from repro.core.strategies.uncertainty import lc_scores, mc_scores


def kmeans_pp_sample(rng, x, k: int):
    """k-means++ seeding AS the selection (BADGE's sampler). x: (N,d)."""
    N, _ = x.shape
    keys = jax.random.split(rng, k + 1)
    first = jax.random.randint(keys[0], (), 0, N).astype(jnp.int32)
    sel0 = jnp.zeros((k,), jnp.int32).at[0].set(first)
    d0 = jnp.sum((x - x[first]) ** 2, axis=-1)

    def body(i, carry):
        mind, sel = carry
        p = mind / jnp.maximum(jnp.sum(mind), 1e-12)
        idx = jax.random.categorical(keys[i], jnp.log(p + 1e-12)).astype(
            jnp.int32)
        sel = sel.at[i].set(idx)
        nd = jnp.sum((x - x[idx]) ** 2, axis=-1)
        mind = jnp.minimum(mind, nd).at[idx].set(0.0)
        return mind, sel

    _, sel = jax.lax.fori_loop(1, k, body, (d0.at[first].set(0.0), sel0))
    return sel


def _badge_select(rng, budget, *, probs, embeddings, labeled_embeddings=None):
    g = (lc_scores(probs)[:, None].astype(jnp.float32)
         * embeddings.astype(jnp.float32))
    return kmeans_pp_sample(rng, g, budget)


def _margin_density_select(rng, budget, *, probs, embeddings,
                           labeled_embeddings=None):
    """Margin x local-density: prefer uncertain points in dense regions."""
    from repro.kernels.pairwise import ops
    m = mc_scores(probs).astype(jnp.float32)
    m = (m - m.min()) / jnp.maximum(m.max() - m.min(), 1e-9)
    # density ~ mean sq-dist to a random reference subset (lower = denser)
    ref = embeddings[:256].astype(jnp.float32)
    d = ops.pairwise_sq_dists(embeddings.astype(jnp.float32), ref).mean(-1)
    dens = 1.0 - (d - d.min()) / jnp.maximum(d.max() - d.min(), 1e-9)
    from repro.core.strategies.base import top_k_select
    return top_k_select(m * dens, budget)


badge = Strategy("badge", ("probs", "embeddings"), _badge_select)
margin_density = Strategy("margin_density", ("probs", "embeddings"),
                          _margin_density_select)
