"""AL Strategy Zoo (paper Table 1 column 'AL Strategy Zoo').

Every strategy ships two implementations with bit-identical selections:
``select`` over one pool matrix, and ``select_sharded`` over the serving
layer's replica shards (core.selection's local-propose/global-merge
machinery) — the contract ``SHARDED_COMPLETE`` asserts and
tests/test_sharding.py verifies per strategy.
"""
from __future__ import annotations

from typing import Dict

from repro.core.strategies.base import Strategy
from repro.core.strategies.diversity import (core_set, dbal, k_center,
                                             random_sampling)
from repro.core.strategies.hybrid import (badge, margin_density,
                                          weighted_kcenter)
from repro.core.strategies.uncertainty import (entropy_sampling,
                                               least_confidence,
                                               margin_confidence,
                                               ratio_confidence)

ZOO: Dict[str, Strategy] = {
    s.name: s for s in [
        least_confidence, margin_confidence, ratio_confidence,
        entropy_sampling, k_center, core_set, dbal, random_sampling,
        badge, margin_density, weighted_kcenter,
    ]
}

# the 7 candidates PSHEA launches (paper §4.3.3) + lower-bound baseline
PAPER_SEVEN = ["lc", "mc", "rc", "es", "kcg", "coreset", "dbal"]

# the hybrids every agent may additionally race once the pool has both
# probs and embeddings — all ride the fused weighted greedy round
HYBRIDS = ["badge", "margin_density", "weighted_kcenter"]

# replica sharding only works if NO strategy silently lacks a sharded path
# (the server would have to fall back and the `replicas` knob would lie)
SHARDED_COMPLETE = all(s.sharded_fn is not None for s in ZOO.values())
assert SHARDED_COMPLETE, sorted(
    n for n, s in ZOO.items() if s.sharded_fn is None)


def get_strategy(name: str) -> Strategy:
    if name not in ZOO:
        raise KeyError(f"unknown strategy {name!r}; zoo = {sorted(ZOO)}")
    return ZOO[name]
