"""AL Strategy Zoo (paper Table 1 column 'AL Strategy Zoo')."""
from __future__ import annotations

from typing import Dict

from repro.core.strategies.base import Strategy
from repro.core.strategies.diversity import (core_set, dbal, k_center,
                                             random_sampling)
from repro.core.strategies.hybrid import (badge, margin_density,
                                          weighted_kcenter)
from repro.core.strategies.uncertainty import (entropy_sampling,
                                               least_confidence,
                                               margin_confidence,
                                               ratio_confidence)

ZOO: Dict[str, Strategy] = {
    s.name: s for s in [
        least_confidence, margin_confidence, ratio_confidence,
        entropy_sampling, k_center, core_set, dbal, random_sampling,
        badge, margin_density, weighted_kcenter,
    ]
}

# the 7 candidates PSHEA launches (paper §4.3.3) + lower-bound baseline
PAPER_SEVEN = ["lc", "mc", "rc", "es", "kcg", "coreset", "dbal"]

# the hybrids every agent may additionally race once the pool has both
# probs and embeddings — all ride the fused weighted greedy round
HYBRIDS = ["badge", "margin_density", "weighted_kcenter"]


def get_strategy(name: str) -> Strategy:
    if name not in ZOO:
        raise KeyError(f"unknown strategy {name!r}; zoo = {sorted(ZOO)}")
    return ZOO[name]
