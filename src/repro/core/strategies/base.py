"""AL strategy API.

A strategy consumes model artifacts for the *unlabeled pool* — class
probabilities (uncertainty family) and/or penultimate embeddings (diversity
family) — and returns exactly ``budget`` unique pool indices. All strategies
are pure-JAX (jit-able, shard_map-able); the service layer feeds them from
the distributed scorer.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str
    needs: Sequence[str]          # subset of {"probs", "embeddings"}
    select_fn: Callable           # (rng, budget, **artifacts) -> (budget,) i32

    def select(self, rng, budget: int, *, probs=None, embeddings=None,
               labeled_embeddings=None) -> jax.Array:
        kw = {}
        if "probs" in self.needs:
            assert probs is not None, f"{self.name} needs probs"
            kw["probs"] = probs
        if "embeddings" in self.needs:
            assert embeddings is not None, f"{self.name} needs embeddings"
            kw["embeddings"] = embeddings
            kw["labeled_embeddings"] = labeled_embeddings
        return self.select_fn(rng, budget, **kw)


def top_k_select(scores: jax.Array, budget: int) -> jax.Array:
    """Indices of the ``budget`` highest scores (higher = more informative)."""
    _, idx = jax.lax.top_k(scores, budget)
    return idx.astype(jnp.int32)


def unit_weights(scores: jax.Array, floor: float = 1e-3) -> jax.Array:
    """Min-max normalize scores into [floor, 1] selection weights.

    The fused greedy round multiplies weights into the argmax score, so
    they must be non-negative and should not collapse to zero for whole
    regions — the floor keeps every row eligible (a zero weight would make
    a far-but-confident point permanently unselectable)."""
    s = scores.astype(jnp.float32)
    s = (s - s.min()) / jnp.maximum(s.max() - s.min(), 1e-9)
    return floor + (1.0 - floor) * s
