"""AL strategy API.

A strategy consumes model artifacts for the *unlabeled pool* — class
probabilities (uncertainty family) and/or penultimate embeddings (diversity
family) — and returns exactly ``budget`` unique pool indices. All strategies
are pure-JAX (jit-able, shard_map-able); the service layer feeds them from
the distributed scorer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str
    needs: Sequence[str]          # subset of {"probs", "embeddings"}
    select_fn: Callable           # (rng, budget, **artifacts) -> (budget,) i32
    # replica-sharded implementation, bit-identical to select_fn:
    # (rng, budget, shards, *, labeled_embeddings, executor) -> (budget,) idx
    sharded_fn: Optional[Callable] = None

    def select(self, rng, budget: int, *, probs=None, embeddings=None,
               labeled_embeddings=None) -> jax.Array:
        kw = {}
        if "probs" in self.needs:
            assert probs is not None, f"{self.name} needs probs"
            kw["probs"] = probs
        if "embeddings" in self.needs:
            assert embeddings is not None, f"{self.name} needs embeddings"
            kw["embeddings"] = embeddings
            kw["labeled_embeddings"] = labeled_embeddings
        return self.select_fn(rng, budget, **kw)

    def select_sharded(self, rng, budget: int, shards, *,
                       labeled_embeddings=None, executor=None,
                       prefilter=None, state=None):
        """Run the strategy over replica shards (``core.selection``'s
        ``ShardView`` list). Returns global pool positions, bit-identical
        to ``select`` over the concatenated pool.

        ``prefilter`` (a ``core.prefilter.PrefilterConfig``) opts into the
        centroid-gated sublinear scan for the strategies that support it
        (uncertainty top-k, unweighted k-center lineage); shards without a
        usable summary — and strategies that need fresh per-slot weights —
        fall back to the full scan, never to a wrong answer.

        ``state`` (a ``core.selection.KCenterState``) hands warm-started
        k-center strategies the session's persisted min-dist vectors so
        the warm fold costs O(new rows) instead of O(pool); strategies
        outside the warm k-center lineage accept and ignore it (same
        contract as ``prefilter``). Bit-identity is unchanged — the state
        holds the exact floats the from-scratch fold would produce."""
        if self.sharded_fn is None:
            raise NotImplementedError(
                f"strategy {self.name!r} has no sharded implementation")
        return self.sharded_fn(rng, budget, shards,
                               labeled_embeddings=labeled_embeddings,
                               executor=executor, prefilter=prefilter,
                               state=state)


def top_k_select(scores: jax.Array, budget: int) -> jax.Array:
    """Indices of the ``budget`` highest scores (higher = more informative)."""
    _, idx = jax.lax.top_k(scores, budget)
    return idx.astype(jnp.int32)


def unit_weights(scores: jax.Array, floor: float = 1e-3) -> jax.Array:
    """Min-max normalize scores into [floor, 1] selection weights.

    The fused greedy round multiplies weights into the argmax score, so
    they must be non-negative and should not collapse to zero for whole
    regions — the floor keeps every row eligible (a zero weight would make
    a far-but-confident point permanently unselectable)."""
    s = scores.astype(jnp.float32)
    s = (s - s.min()) / jnp.maximum(s.max() - s.min(), 1e-9)
    return floor + (1.0 - floor) * s


def global_min_max(parts):
    """(min, max) scalars over a sharded vector: min-of-mins is the exact
    elementwise minimum, so no float drift vs the concatenated reduce.
    Empty shards are skipped."""
    nonempty = [p for p in parts if p.shape[0]]
    lo = functools.reduce(jnp.minimum, [jnp.min(p) for p in nonempty])
    hi = functools.reduce(jnp.maximum, [jnp.max(p) for p in nonempty])
    return lo, hi


def unit_weights_parts(scores_list, floor: float = 1e-3) -> list:
    """``unit_weights`` over a sharded score vector: one global min/max,
    then the identical per-row transform on every shard — bit-identical to
    ``unit_weights`` over the concatenated vector."""
    parts = [s.astype(jnp.float32) for s in scores_list]
    lo, hi = global_min_max(parts)
    span = jnp.maximum(hi - lo, 1e-9)
    return [floor + (1.0 - floor) * ((p - lo) / span) for p in parts]
