"""Diversity-based strategies: KCG, Core-Set, DBAL (+ Random baseline).

K-center greedy is the paper's heaviest strategy (Fig. 4b: lowest
throughput); the inner ``min(dist(pool, new_center))`` update is the fused
Pallas kernel in repro/kernels/pairwise.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.strategies.base import Strategy
from repro.core.strategies.uncertainty import lc_scores


def _min_dist_update(embeddings, center, mindist):
    from repro.kernels.pairwise import ops
    d = ops.sq_dist_to_center(embeddings, center)
    return jnp.minimum(mindist, d)


def k_center_greedy(rng, budget: int, embeddings, init_centers=None):
    """2-approx k-center: repeatedly take the point farthest from all
    centers. init_centers: (M,d) existing (labeled) centers or None."""
    N, _ = embeddings.shape
    emb = embeddings.astype(jnp.float32)
    selected = jnp.zeros((budget,), jnp.int32)
    start = 0
    if init_centers is not None and init_centers.shape[0] > 0:
        from repro.kernels.pairwise import ops
        mindist = ops.pairwise_min_dist(emb, init_centers.astype(jnp.float32))
    else:
        # the seed IS the first returned center (otherwise its cluster can
        # be silently dropped from the returned set)
        first = jax.random.randint(rng, (), 0, N).astype(jnp.int32)
        selected = selected.at[0].set(first)
        mindist = jnp.sum((emb - emb[first]) ** 2, axis=-1).at[first].set(-1.0)
        start = 1

    def body(i, carry):
        mindist, selected = carry
        idx = jnp.argmax(mindist).astype(jnp.int32)
        selected = selected.at[i].set(idx)
        mindist = _min_dist_update(emb, emb[idx], mindist)
        mindist = mindist.at[idx].set(-1.0)   # never re-pick
        return mindist, selected

    _, selected = jax.lax.fori_loop(start, budget, body, (mindist, selected))
    return selected


def _kcg_select(rng, budget, *, embeddings, labeled_embeddings=None):
    return k_center_greedy(rng, budget, embeddings, init_centers=None)


def _coreset_select(rng, budget, *, embeddings, labeled_embeddings=None):
    return k_center_greedy(rng, budget, embeddings,
                           init_centers=labeled_embeddings)


def _kmeans(rng, x, k: int, iters: int = 10, weights=None):
    """Weighted Lloyd's with kmeans++-style seeding. x: (N,d) f32."""
    N, d = x.shape
    w = jnp.ones((N,), jnp.float32) if weights is None else weights
    keys = jax.random.split(rng, 2)
    # seeding: weighted random first, then farthest-point (cheap ++ variant)
    first = jax.random.categorical(keys[0], jnp.log(w + 1e-9))
    cent0 = jnp.zeros((k, d), jnp.float32).at[0].set(x[first])

    def seed_body(i, cent):
        from repro.kernels.pairwise import ops
        md = ops.pairwise_min_dist(x, cent) * w
        md = jnp.where(jnp.arange(N) < 0, 0.0, md)
        idx = jnp.argmax(md)
        return cent.at[i].set(x[idx])

    cents = jax.lax.fori_loop(1, k, seed_body, cent0)

    def lloyd(_, cents):
        from repro.kernels.pairwise import ops
        assign = ops.pairwise_argmin(x, cents)           # (N,)
        one = jax.nn.one_hot(assign, k, dtype=jnp.float32) * w[:, None]
        num = one.T @ x                                   # (k,d)
        den = jnp.maximum(one.sum(0)[:, None], 1e-9)
        return num / den

    cents = jax.lax.fori_loop(0, iters, lloyd, cents)
    return cents


def diverse_mini_batch(rng, budget: int, probs, embeddings, beta: int = 10):
    """DBAL [55]: prefilter beta*budget by LC, weighted k-means, then pick
    the nearest pool point to each centroid (unique via masking)."""
    from repro.kernels.pairwise import ops
    scores = lc_scores(probs)
    m = min(beta * budget, scores.shape[0])
    top_scores, top_idx = jax.lax.top_k(scores, m)
    x = embeddings[top_idx].astype(jnp.float32)
    cents = _kmeans(rng, x, budget, weights=jnp.maximum(top_scores, 1e-6))

    # nearest point to each centroid without duplicates
    d2 = ops.pairwise_sq_dists(cents, x)                  # (k, m)

    def body(i, carry):
        taken_mask, sel = carry
        row = jnp.where(taken_mask, jnp.inf, d2[i])
        j = jnp.argmin(row)
        return taken_mask.at[j].set(True), sel.at[i].set(top_idx[j])

    sel = jnp.zeros((budget,), jnp.int32)
    _, sel = jax.lax.fori_loop(0, budget, body,
                               (jnp.zeros((m,), bool), sel))
    return sel


def _dbal_select(rng, budget, *, probs, embeddings, labeled_embeddings=None):
    return diverse_mini_batch(rng, budget, probs, embeddings)


def _random_select(rng, budget, *, probs=None):
    n = probs.shape[0]
    return jax.random.permutation(rng, n)[:budget].astype(jnp.int32)


k_center = Strategy("kcg", ("embeddings",), _kcg_select)
core_set = Strategy("coreset", ("embeddings",), _coreset_select)
dbal = Strategy("dbal", ("probs", "embeddings"), _dbal_select)
random_sampling = Strategy("random", ("probs",), _random_select)
