"""Diversity-based strategies: KCG, Core-Set, DBAL (+ Random baseline).

K-center greedy is the paper's heaviest strategy (Fig. 4b: lowest
throughput); every greedy round is ONE fused Pallas pass
(repro/kernels/pairwise.greedy_round_pallas): the pool is read once per
selected center, with the min-dist update, selected-index masking, and the
next argmax folded into that read. The Core-Set warm start folds labeled
centers in chunks via the same kernel (ops.warm_start_min_dist).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies.base import Strategy, unit_weights
from repro.core.strategies.uncertainty import lc_scores


def k_center_greedy(rng, budget: int, embeddings, init_centers=None,
                    impl: str = "auto", weights=None):
    """2-approx k-center: repeatedly take the point farthest from all
    centers. init_centers: (M,d) existing (labeled) centers or None.

    ``weights`` (optional (N,) non-negative f32) turns each round into the
    *weighted* fused pass: the next center maximizes ``min_dist * weight``
    while the min-dist fold itself stays unweighted — uncertainty decides
    among the far points, distance still defines "far". ``weights=None``
    takes the identical unweighted path as before (regression anchor)."""
    from repro.kernels.pairwise import ops
    N, _ = embeddings.shape
    emb = embeddings.astype(jnp.float32)
    w = None if weights is None else weights.astype(jnp.float32)
    selected = jnp.zeros((budget,), jnp.int32)
    start = 0
    if init_centers is not None and init_centers.shape[0] > 0:
        mindist = ops.warm_start_min_dist(emb,
                                          init_centers.astype(jnp.float32),
                                          impl=impl)
    else:
        # the seed IS the first returned center (otherwise its cluster can
        # be silently dropped from the returned set)
        first = jax.random.randint(rng, (), 0, N).astype(jnp.int32)
        selected = selected.at[0].set(first)
        mindist = ops.sq_dist_to_center(emb, emb[first]).at[first].set(-1.0)
        start = 1
    if w is None:
        nxt = jnp.argmax(mindist).astype(jnp.int32)
    else:
        # same masked-score rule as the kernel: selected rows never win
        nxt = jnp.argmax(ops.masked_weighted_score(mindist, w)).astype(
            jnp.int32)

    def body(i, carry):
        mindist, selected, nxt = carry
        selected = selected.at[i].set(nxt)
        # one fused pool pass: fold the new center in, mask it, get the
        # following round's (weighted) argmax
        mindist, nxt, _ = ops.greedy_round(emb, mindist, emb[nxt][None, :],
                                           nxt[None], weights=w, impl=impl)
        return mindist, selected, nxt

    _, selected, _ = jax.lax.fori_loop(start, budget, body,
                                       (mindist, selected, nxt))
    return selected


def _kcg_select(rng, budget, *, embeddings, labeled_embeddings=None):
    return k_center_greedy(rng, budget, embeddings, init_centers=None)


def _coreset_select(rng, budget, *, embeddings, labeled_embeddings=None):
    return k_center_greedy(rng, budget, embeddings,
                           init_centers=labeled_embeddings)


def _kmeans(rng, x, k: int, iters: int = 10, weights=None):
    """Weighted Lloyd's with kmeans++-style seeding. x: (N,d) f32."""
    from repro.kernels.pairwise import ops
    N, d = x.shape
    w = jnp.ones((N,), jnp.float32) if weights is None else weights
    keys = jax.random.split(rng, 2)
    # seeding: weighted random first, then farthest-point (cheap ++ variant).
    # The running min-dist only ever sees FILLED centroid rows — recomputing
    # against the whole (k, d) buffer would let zero-initialized rows act as
    # phantom centers at the origin.
    first = jax.random.categorical(keys[0], jnp.log(w + 1e-9))
    cent0 = jnp.zeros((k, d), jnp.float32).at[0].set(x[first])
    mind0 = ops.sq_dist_to_center(x, x[first])
    no_mask = jnp.full((1,), -1, jnp.int32)
    nxt0 = jnp.argmax(mind0 * w).astype(jnp.int32)

    def seed_body(i, carry):
        cents, mind, nxt = carry
        cents = cents.at[i].set(x[nxt])
        mind, nxt, _ = ops.greedy_round(x, mind, x[nxt][None, :], no_mask,
                                        weights=w)
        return cents, mind, nxt

    cents, _, _ = jax.lax.fori_loop(1, k, seed_body, (cent0, mind0, nxt0))

    def lloyd(_, cents):
        assign = ops.pairwise_argmin(x, cents)           # (N,)
        one = jax.nn.one_hot(assign, k, dtype=jnp.float32) * w[:, None]
        num = one.T @ x                                   # (k,d)
        den = jnp.maximum(one.sum(0)[:, None], 1e-9)
        return num / den

    cents = jax.lax.fori_loop(0, iters, lloyd, cents)
    return cents


def _dbal_match(rng, budget: int, x, top_scores, top_idx, match_weights=None):
    """DBAL's tail shared by the single-pool and sharded paths: weighted
    k-means over the prefiltered subset ``x``, then match each centroid to
    a unique pool point. With ``match_weights`` (per-row of ``x``,
    non-negative) the matching cost is ``d2 / weight`` — the min-problem
    mirror of the fused round's ``min_dist * weight`` argmax, so uncertain
    points win centroid ties instead of being coin-flipped away."""
    from repro.kernels.pairwise import ops
    m = x.shape[0]
    cents = _kmeans(rng, x, budget, weights=jnp.maximum(top_scores, 1e-6))
    d2 = ops.pairwise_sq_dists(cents, x)                  # (k, m)
    cost = (d2 if match_weights is None
            else d2 / jnp.maximum(match_weights, 1e-6)[None, :])

    def body(i, carry):
        taken_mask, sel = carry
        row = jnp.where(taken_mask, jnp.inf, cost[i])
        j = jnp.argmin(row)
        return taken_mask.at[j].set(True), sel.at[i].set(top_idx[j])

    sel = jnp.zeros((budget,), jnp.int32)
    _, sel = jax.lax.fori_loop(0, budget, body,
                               (jnp.zeros((m,), bool), sel))
    return sel


def diverse_mini_batch(rng, budget: int, probs, embeddings, beta: int = 10,
                       weights=None):
    """DBAL [55]: prefilter beta*budget by LC, weighted k-means, then pick
    the nearest pool point to each centroid (unique via masking).

    ``weights`` (optional (N,) over the pool) threads into the
    centroid-matching step (``weights=None`` keeps the unweighted match)."""
    scores = lc_scores(probs)
    m = min(beta * budget, scores.shape[0])
    top_scores, top_idx = jax.lax.top_k(scores, m)
    x = embeddings[top_idx].astype(jnp.float32)
    mw = None if weights is None else weights[top_idx]
    return _dbal_match(rng, budget, x, top_scores, top_idx, match_weights=mw)


def _dbal_select(rng, budget, *, probs, embeddings, labeled_embeddings=None):
    # centroid matching rides the same LC weighting as the fused hybrids
    # (ROADMAP PR-2 open item): among near-equidistant candidates the more
    # uncertain point is matched first
    return diverse_mini_batch(rng, budget, probs, embeddings,
                              weights=unit_weights(lc_scores(probs)))


def _random_select(rng, budget, *, probs=None):
    n = probs.shape[0]
    return jax.random.permutation(rng, n)[:budget].astype(jnp.int32)


# ------------------------------------------------- replica-sharded paths --
def sharded_k_center(rng, budget: int, shards, *, init_centers=None,
                     weights_list=None, executor=None, impl: str = "auto",
                     prefilter=None, state=None):
    """Replica-sharded ``k_center_greedy``: per-shard fused rounds +
    cross-shard (value, global index) merges — selections bit-identical to
    the single-pool path for every shard count (see core.selection).

    ``prefilter`` routes the UNWEIGHTED geometry (kcg/coreset) through the
    centroid-gated engine (core.prefilter) when any shard carries a
    summary; weighted rounds rank by ``min_dist * weight``, which the
    distance-only triangle bound cannot cap, so they always take the full
    path.

    ``state`` (a ``core.selection.KCenterState`` prepared by the session's
    ``KCenterStateCache``) replaces the warm-start fold on the warm path:
    the persisted pool-level min-dists are gathered down to the view rows
    instead of streaming every row against every labeled center. Same
    floats (slice-invariant distances + exact min fold), O(delta) cost.
    Ignored on the seeded path — there is no warm fold to save."""
    from repro.core import selection
    from repro.kernels.pairwise import ops
    warm = init_centers is not None and init_centers.shape[0] > 0
    if prefilter is not None and weights_list is None \
            and any(s.summary is not None for s in shards):
        from repro.core import prefilter as pf
        return pf.gated_greedy_select(
            rng, budget, shards, init_centers=init_centers,
            slack=prefilter.slack, executor=executor, impl=impl,
            state=state if warm else None)
    N = selection.replica_total(shards)
    emb_list = [jnp.asarray(s.feats, jnp.float32) for s in shards]
    sel = np.zeros((budget,), np.int64)
    if weights_list is None:
        def weight_for_slot(slot, i):
            return None
    else:
        def weight_for_slot(slot, i):
            return weights_list[i]
    capture = None
    if warm:
        if state is not None:
            mind = state.view_minds(shards)
            capture = state.capture
        else:
            init = jnp.asarray(init_centers, jnp.float32)
            mind = [ops.warm_start_min_dist(emb_list[i], init, impl=impl)
                    if s.n else None for i, s in enumerate(shards)]
        start = 0
    else:
        # the random seed IS the first returned center, as in the single
        # path (same rng call, same N -> same draw)
        first = int(jax.random.randint(rng, (), 0, N))
        mind = selection.replica_seed_min_dist(shards, emb_list, first)
        sel[0] = first
        start = 1
    return selection.replica_greedy_select(
        shards, emb_list, budget, mind_list=mind, sel=sel, start=start,
        weight_for_slot=weight_for_slot, executor=executor, impl=impl,
        capture=capture)


def _kcg_sharded(rng, budget, shards, *, labeled_embeddings=None,
                 executor=None, prefilter=None, state=None):
    # kcg never warm-starts (no init centers), so the persisted min-dist
    # state has nothing to save it; accepted and ignored
    return sharded_k_center(rng, budget, shards, executor=executor,
                            prefilter=prefilter)


def _coreset_sharded(rng, budget, shards, *, labeled_embeddings=None,
                     executor=None, prefilter=None, state=None):
    return sharded_k_center(rng, budget, shards,
                            init_centers=labeled_embeddings,
                            executor=executor, prefilter=prefilter,
                            state=state)


def _dbal_sharded(rng, budget, shards, *, labeled_embeddings=None,
                  executor=None, beta: int = 10, prefilter=None, state=None):
    """Sharded DBAL: shards propose their local LC top-(beta*budget), the
    merged prefilter subset is gathered to the coordinator, and the k-means
    + weighted matching tail is the exact single-pool code over it."""
    from repro.core import selection
    from repro.core.strategies.base import unit_weights_parts
    scores = selection.replica_map(
        lambda s: lc_scores(jnp.asarray(s.probs)), shards, executor)
    N = selection.replica_total(shards)
    m = min(beta * budget, N)
    top_idx, top_scores = selection.replica_top_k(shards, scores, m,
                                                  executor)
    x = jnp.asarray(selection.gather_rows(shards, top_idx), jnp.float32)
    mw = jnp.asarray(selection.gather_rows(
        shards, top_idx, arrays=unit_weights_parts(scores)), jnp.float32)
    return np.asarray(_dbal_match(rng, budget, x, jnp.asarray(top_scores),
                                  jnp.asarray(top_idx), match_weights=mw))


def _random_sharded(rng, budget, shards, *, labeled_embeddings=None,
                    executor=None, prefilter=None, state=None):
    from repro.core import selection
    n = selection.replica_total(shards)
    return np.asarray(jax.random.permutation(rng, n)[:budget])


k_center = Strategy("kcg", ("embeddings",), _kcg_select, _kcg_sharded)
core_set = Strategy("coreset", ("embeddings",), _coreset_select,
                    _coreset_sharded)
dbal = Strategy("dbal", ("probs", "embeddings"), _dbal_select, _dbal_sharded)
random_sampling = Strategy("random", ("probs",), _random_select,
                           _random_sharded)
