"""Diversity-based strategies: KCG, Core-Set, DBAL (+ Random baseline).

K-center greedy is the paper's heaviest strategy (Fig. 4b: lowest
throughput); every greedy round is ONE fused Pallas pass
(repro/kernels/pairwise.greedy_round_pallas): the pool is read once per
selected center, with the min-dist update, selected-index masking, and the
next argmax folded into that read. The Core-Set warm start folds labeled
centers in chunks via the same kernel (ops.warm_start_min_dist).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.strategies.base import Strategy
from repro.core.strategies.uncertainty import lc_scores


def k_center_greedy(rng, budget: int, embeddings, init_centers=None,
                    impl: str = "auto", weights=None):
    """2-approx k-center: repeatedly take the point farthest from all
    centers. init_centers: (M,d) existing (labeled) centers or None.

    ``weights`` (optional (N,) non-negative f32) turns each round into the
    *weighted* fused pass: the next center maximizes ``min_dist * weight``
    while the min-dist fold itself stays unweighted — uncertainty decides
    among the far points, distance still defines "far". ``weights=None``
    takes the identical unweighted path as before (regression anchor)."""
    from repro.kernels.pairwise import ops
    N, _ = embeddings.shape
    emb = embeddings.astype(jnp.float32)
    w = None if weights is None else weights.astype(jnp.float32)
    selected = jnp.zeros((budget,), jnp.int32)
    start = 0
    if init_centers is not None and init_centers.shape[0] > 0:
        mindist = ops.warm_start_min_dist(emb,
                                          init_centers.astype(jnp.float32),
                                          impl=impl)
    else:
        # the seed IS the first returned center (otherwise its cluster can
        # be silently dropped from the returned set)
        first = jax.random.randint(rng, (), 0, N).astype(jnp.int32)
        selected = selected.at[0].set(first)
        mindist = ops.sq_dist_to_center(emb, emb[first]).at[first].set(-1.0)
        start = 1
    if w is None:
        nxt = jnp.argmax(mindist).astype(jnp.int32)
    else:
        # same masked-score rule as the kernel: selected rows never win
        nxt = jnp.argmax(ops.masked_weighted_score(mindist, w)).astype(
            jnp.int32)

    def body(i, carry):
        mindist, selected, nxt = carry
        selected = selected.at[i].set(nxt)
        # one fused pool pass: fold the new center in, mask it, get the
        # following round's (weighted) argmax
        mindist, nxt, _ = ops.greedy_round(emb, mindist, emb[nxt][None, :],
                                           nxt[None], weights=w, impl=impl)
        return mindist, selected, nxt

    _, selected, _ = jax.lax.fori_loop(start, budget, body,
                                       (mindist, selected, nxt))
    return selected


def _kcg_select(rng, budget, *, embeddings, labeled_embeddings=None):
    return k_center_greedy(rng, budget, embeddings, init_centers=None)


def _coreset_select(rng, budget, *, embeddings, labeled_embeddings=None):
    return k_center_greedy(rng, budget, embeddings,
                           init_centers=labeled_embeddings)


def _kmeans(rng, x, k: int, iters: int = 10, weights=None):
    """Weighted Lloyd's with kmeans++-style seeding. x: (N,d) f32."""
    from repro.kernels.pairwise import ops
    N, d = x.shape
    w = jnp.ones((N,), jnp.float32) if weights is None else weights
    keys = jax.random.split(rng, 2)
    # seeding: weighted random first, then farthest-point (cheap ++ variant).
    # The running min-dist only ever sees FILLED centroid rows — recomputing
    # against the whole (k, d) buffer would let zero-initialized rows act as
    # phantom centers at the origin.
    first = jax.random.categorical(keys[0], jnp.log(w + 1e-9))
    cent0 = jnp.zeros((k, d), jnp.float32).at[0].set(x[first])
    mind0 = ops.sq_dist_to_center(x, x[first])
    no_mask = jnp.full((1,), -1, jnp.int32)
    nxt0 = jnp.argmax(mind0 * w).astype(jnp.int32)

    def seed_body(i, carry):
        cents, mind, nxt = carry
        cents = cents.at[i].set(x[nxt])
        mind, nxt, _ = ops.greedy_round(x, mind, x[nxt][None, :], no_mask,
                                        weights=w)
        return cents, mind, nxt

    cents, _, _ = jax.lax.fori_loop(1, k, seed_body, (cent0, mind0, nxt0))

    def lloyd(_, cents):
        assign = ops.pairwise_argmin(x, cents)           # (N,)
        one = jax.nn.one_hot(assign, k, dtype=jnp.float32) * w[:, None]
        num = one.T @ x                                   # (k,d)
        den = jnp.maximum(one.sum(0)[:, None], 1e-9)
        return num / den

    cents = jax.lax.fori_loop(0, iters, lloyd, cents)
    return cents


def diverse_mini_batch(rng, budget: int, probs, embeddings, beta: int = 10):
    """DBAL [55]: prefilter beta*budget by LC, weighted k-means, then pick
    the nearest pool point to each centroid (unique via masking)."""
    from repro.kernels.pairwise import ops
    scores = lc_scores(probs)
    m = min(beta * budget, scores.shape[0])
    top_scores, top_idx = jax.lax.top_k(scores, m)
    x = embeddings[top_idx].astype(jnp.float32)
    cents = _kmeans(rng, x, budget, weights=jnp.maximum(top_scores, 1e-6))

    # nearest point to each centroid without duplicates
    d2 = ops.pairwise_sq_dists(cents, x)                  # (k, m)

    def body(i, carry):
        taken_mask, sel = carry
        row = jnp.where(taken_mask, jnp.inf, d2[i])
        j = jnp.argmin(row)
        return taken_mask.at[j].set(True), sel.at[i].set(top_idx[j])

    sel = jnp.zeros((budget,), jnp.int32)
    _, sel = jax.lax.fori_loop(0, budget, body,
                               (jnp.zeros((m,), bool), sel))
    return sel


def _dbal_select(rng, budget, *, probs, embeddings, labeled_embeddings=None):
    return diverse_mini_batch(rng, budget, probs, embeddings)


def _random_select(rng, budget, *, probs=None):
    n = probs.shape[0]
    return jax.random.permutation(rng, n)[:budget].astype(jnp.int32)


k_center = Strategy("kcg", ("embeddings",), _kcg_select)
core_set = Strategy("coreset", ("embeddings",), _coreset_select)
dbal = Strategy("dbal", ("probs", "embeddings"), _dbal_select)
random_sampling = Strategy("random", ("probs",), _random_select)
