"""Uncertainty-based strategies: LC, MC, RC, ES (paper Fig. 4 set).

Score conventions follow Settles' survey [46] / the paper's references:
  LC  least confidence      1 - max_c p(c)            (higher = pick)
  MC  margin confidence     -(p(1) - p(2))            (small margin = pick)
  RC  ratio confidence      p(2) / p(1)               (ratio near 1 = pick)
  ES  entropy sampling      -sum p log p

``*_scores_from_logits`` are the fused paths the Pallas kernel implements
(repro/kernels/uncertainty): one streaming pass over the class/vocab axis,
no materialized softmax — this is the serving hot-spot when the scorer is an
LLM with a 100k-256k vocab.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.strategies.base import Strategy, top_k_select


def lc_scores(probs):
    return 1.0 - jnp.max(probs, axis=-1)


def mc_scores(probs):
    top2 = jax.lax.top_k(probs, 2)[0]
    return -(top2[..., 0] - top2[..., 1])


def rc_scores(probs):
    top2 = jax.lax.top_k(probs, 2)[0]
    return top2[..., 1] / jnp.maximum(top2[..., 0], 1e-12)


def es_scores(probs):
    p = jnp.clip(probs, 1e-12, 1.0)
    return -jnp.sum(p * jnp.log(p), axis=-1)


SCORE_FNS = {"lc": lc_scores, "mc": mc_scores, "rc": rc_scores,
             "es": es_scores}


def scores_from_logits(logits, kind: str, impl: str = "auto"):
    """Fused logits->score (kernel or reference; see kernels/uncertainty)."""
    from repro.kernels.uncertainty import ops
    return ops.uncertainty_scores(logits, kind, impl=impl)


def _make(kind: str) -> Strategy:
    def select_fn(rng, budget, *, probs):
        from repro.kernels.pairwise import ops
        ops.record_pool_rows(int(probs.shape[0]))
        return top_k_select(SCORE_FNS[kind](probs), budget)

    def sharded_fn(rng, budget, shards, *, labeled_embeddings=None,
                   executor=None, prefilter=None, state=None):
        # ``state`` (persisted k-center min-dists) accepted and ignored:
        # uncertainty scoring is stateless per row
        from repro.core import selection
        if prefilter is not None:
            # cap-gated cluster scan: bit-identical to the full scan by
            # the strictly-below stopping rule (core.prefilter)
            from repro.core import prefilter as pf
            idx, _ = pf.gated_top_k(shards, kind, budget, executor)
            return idx
        # per-shard scoring (scores are per-row, so shard slices produce the
        # exact floats of the full matrix) + partial top-k merge
        from repro.kernels.pairwise import ops

        def score(s):
            ops.record_pool_rows(s.n)
            return SCORE_FNS[kind](jnp.asarray(s.probs))

        scores = selection.replica_map(score, shards, executor)
        idx, _ = selection.replica_top_k(shards, scores, budget, executor)
        return idx

    return Strategy(kind, ("probs",), select_fn, sharded_fn)


least_confidence = _make("lc")
margin_confidence = _make("mc")
ratio_confidence = _make("rc")
entropy_sampling = _make("es")
