"""Negative-exponential accuracy forecaster (paper §3.3, ref [25]).

Model: acc(r) = a - b * exp(-c * r). Fit by grid search over the rate c with
closed-form linear least squares for (a, b) at each c — robust for the 2-8
point histories PSHEA works with, no optimizer dependencies.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class NegExpFit:
    a: float
    b: float
    c: float
    sse: float

    def predict(self, r) -> np.ndarray:
        r = np.asarray(r, np.float64)
        return self.a - self.b * np.exp(-self.c * r)


def fit_neg_exp(rounds: Sequence[float], accs: Sequence[float],
                c_grid: np.ndarray | None = None) -> NegExpFit:
    r = np.asarray(rounds, np.float64)
    y = np.asarray(accs, np.float64)
    assert r.shape == y.shape and r.size >= 2
    if c_grid is None:
        c_grid = np.logspace(-3, 1.2, 120)
    best = None
    for c in c_grid:
        basis = np.stack([np.ones_like(r), -np.exp(-c * r)], axis=1)
        coef, *_ = np.linalg.lstsq(basis, y, rcond=None)
        a, b = float(coef[0]), float(coef[1])
        pred = a - b * np.exp(-c * r)
        sse = float(np.sum((pred - y) ** 2))
        # monotone-increasing saturating curves only (b, c > 0)
        if b <= 0:
            sse += 1e3
        if best is None or sse < best.sse:
            best = NegExpFit(a, b, float(c), sse)
    return best


def predict_next(rounds: Sequence[float], accs: Sequence[float],
                 next_round: float) -> float:
    """One-shot helper: fit history, forecast accuracy at ``next_round``.

    With fewer than 3 points, falls back to last-value (no reliable fit)."""
    if len(accs) < 3:
        return float(accs[-1])
    fit = fit_neg_exp(rounds, accs)
    return float(np.clip(fit.predict(next_round), 0.0, 1.0))
