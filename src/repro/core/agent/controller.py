"""PSHEA — Predictive-based Successive Halving Early-stop (paper Alg. 1).

The loop controller launches all candidate strategies, advances each by one
AL round per iteration (select -> label -> update -> eval), fits the
negative-exponential forecaster on each history, and eliminates the strategy
with the lowest *predicted* next-round accuracy while more than one remains.
Stops on: target accuracy reached, budget exhausted, or convergence.

With ``max_workers > 1`` the surviving candidates advance concurrently on a
thread pool, so a round costs max(candidate) wall clock instead of
sum(candidate). All cross-strategy state (budget accounting, history,
forecasts, elimination) is aggregated AFTER the fan-out in the fixed
candidate order, so a parallel run is bit-identical to the serial schedule —
provided the task derives any randomness from (strategy, round) rather than
shared mutable state (the ALServer task does).

The controller is generic over an ``ALTask`` — anything that can select,
label and train/eval. Concrete tasks: synthetic CIFAR-like (benchmarks),
LLM-pool scoring (examples/al_train_loop.py).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence

import numpy as np

from repro.core.agent.predictor import predict_next


class ALTask(Protocol):
    """One independent AL line per strategy (paper Alg. 1 keeps per-strategy
    labeled sets d^l)."""

    def select_and_label(self, strategy: str, round_budget: int) -> int:
        """Run one selection round for ``strategy``; returns budget spent."""
        ...

    def train_and_eval(self, strategy: str) -> float:
        """Update the model on the strategy's labeled set; returns accuracy."""
        ...

    def initial_accuracy(self) -> float:
        ...


@dataclasses.dataclass
class PSHEAResult:
    best_strategy: str
    best_accuracy: float
    stop_reason: str
    rounds: int
    budget_spent: int
    history: Dict[str, List[float]]
    predictions: Dict[str, List[float]]
    eliminated: List[str]          # elimination order (earliest first)


def run_pshea(task: ALTask, strategies: Sequence[str], *,
              target_accuracy: float, budget_max: int, round_budget: int,
              max_rounds: int = 32, converge_eps: float = 1e-3,
              converge_patience: int = 2,
              max_workers: Optional[int] = None) -> PSHEAResult:
    a0 = task.initial_accuracy()                      # line 5
    a_max = a0                                        # line 6
    live = list(strategies)
    history = {s: [a0] for s in live}                 # per-strategy a_l
    predictions: Dict[str, List[float]] = {s: [] for s in live}
    eliminated: List[str] = []
    b_total = 0                                       # line 9
    r = 0
    stall = 0
    stop = "max_rounds"

    def advance(s):
        spent = task.select_and_label(s, round_budget)
        return spent, task.train_and_eval(s)

    pool = None
    if max_workers and max_workers > 1 and len(live) > 1:
        pool = cf.ThreadPoolExecutor(
            max_workers=min(max_workers, len(live)),
            thread_name_prefix="pshea")
    try:
        while r < max_rounds:                         # line 10
            if a_max >= target_accuracy:              # line 11
                stop = "target_accuracy"
                break
            if b_total >= budget_max:                 # line 12
                stop = "budget_exhausted"
                break
            if stall >= converge_patience:            # line 13
                stop = "converged"
                break

            if pool is not None and len(live) > 1:    # lines 14-19
                results = list(pool.map(advance, live))
            else:
                results = [advance(s) for s in live]
            preds = {}
            for s, (spent, acc) in zip(live, results):
                b_total += spent
                history[s].append(acc)
                nxt = predict_next(range(len(history[s])), history[s],
                                   len(history[s]))   # line 17-18
                preds[s] = nxt
                predictions[s].append(nxt)

            r += 1                                    # line 21
            new_max = max(h[-1] for h in history.values())  # line 22
            stall = stall + 1 if new_max - a_max < converge_eps else 0
            a_max = max(a_max, new_max)

            if len(live) > 1:                         # lines 23-24
                worst = min(live, key=lambda s: preds[s])
                live.remove(worst)
                eliminated.append(worst)
    finally:
        if pool is not None:
            pool.shutdown(wait=True)

    best = max(history, key=lambda s: history[s][-1])
    return PSHEAResult(
        best_strategy=best,
        best_accuracy=history[best][-1],
        stop_reason=stop,
        rounds=r,
        budget_spent=b_total,
        history=history,
        predictions=predictions,
        eliminated=eliminated,
    )
