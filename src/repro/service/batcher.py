"""Dynamic batching (paper §3.3; Clipper-style [10], TPU-adapted).

Requests accumulate until ``max_batch`` or ``timeout_s``; batches are padded
up to power-of-two *buckets* so the jitted scorer sees a small closed set of
shapes — on TPU every new shape is an XLA recompile, so bucketing is the
batching adaptation that actually matters on this hardware.
"""
from __future__ import annotations

import concurrent.futures as cf
import threading
import time
from typing import Any, Callable, List, Sequence

import numpy as np


def bucket_size(n: int, max_batch: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return min(b, max_batch)


class DynamicBatcher:
    """batch_fn: (stacked np.ndarray, n_valid) -> per-item results list."""

    def __init__(self, batch_fn: Callable[[np.ndarray, int], Sequence[Any]],
                 max_batch: int = 64, timeout_s: float = 0.005,
                 pad_to_max: bool = False):
        self.batch_fn = batch_fn
        self.max_batch = max_batch
        self.timeout_s = timeout_s
        # pad_to_max gives the scorer ONE canonical shape (max_batch) instead
        # of pow-2 buckets: the embedding path needs it so a row's features
        # never depend on how many neighbours happened to share its batch
        # (shape-canonical + row-local forward => bitwise batch-insensitive).
        self.pad_to_max = pad_to_max
        self._pending: List = []
        self._lock = threading.Condition()
        self._stop = False
        self.batches = 0
        self.items = 0
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, item: np.ndarray) -> "cf.Future":
        fut: cf.Future = cf.Future()
        with self._lock:
            self._pending.append((item, fut))
            self._lock.notify()
        return fut

    def score(self, items: Sequence[np.ndarray]) -> List[Any]:
        futs = [self.submit(it) for it in items]
        return [f.result() for f in futs]

    def _loop(self):
        while True:
            with self._lock:
                if not self._pending and not self._stop:
                    self._lock.wait(timeout=0.05)
                if self._stop and not self._pending:
                    return
                if not self._pending:
                    continue
                deadline = time.perf_counter() + self.timeout_s
                while (len(self._pending) < self.max_batch
                       and time.perf_counter() < deadline):
                    self._lock.wait(timeout=max(
                        deadline - time.perf_counter(), 0.0))
                batch = self._pending[: self.max_batch]
                self._pending = self._pending[self.max_batch:]
            items = [b[0] for b in batch]
            futs = [b[1] for b in batch]
            n = len(items)
            b = self.max_batch if self.pad_to_max else bucket_size(
                n, self.max_batch)
            stacked = np.stack(items + [np.zeros_like(items[0])] * (b - n))
            try:
                results = self.batch_fn(stacked, n)
                for f, r in zip(futs, results):
                    f.set_result(r)
            except BaseException as e:
                for f in futs:
                    f.set_exception(e)
            self.batches += 1
            self.items += n

    def close(self):
        with self._lock:
            self._stop = True
            self._lock.notify_all()
        self._thread.join(timeout=5)

    def stats(self) -> dict:
        return {"batches": self.batches, "items": self.items,
                "mean_batch": self.items / max(self.batches, 1)}
