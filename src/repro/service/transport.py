"""msgpack-over-TCP transport (offline stand-in for the paper's gRPC).

Framing: 4-byte big-endian length + msgpack blob. numpy arrays are encoded
as {"__nd__": True, "d": dtype, "s": shape, "b": bytes}.
"""
from __future__ import annotations

import socket
import struct
import threading
from typing import Any, Callable, Dict

import msgpack
import numpy as np


def _default(obj):
    if isinstance(obj, np.ndarray):
        return {"__nd__": True, "d": str(obj.dtype), "s": list(obj.shape),
                "b": obj.tobytes()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    raise TypeError(f"unserializable: {type(obj)}")


def _object_hook(obj):
    if obj.get("__nd__"):
        return np.frombuffer(obj["b"], dtype=obj["d"]).reshape(obj["s"])
    return obj


def send_msg(sock: socket.socket, obj: Any) -> None:
    blob = msgpack.packb(obj, default=_default, use_bin_type=True)
    sock.sendall(struct.pack(">I", len(blob)) + blob)


def recv_msg(sock: socket.socket) -> Any:
    hdr = _recv_exact(sock, 4)
    if hdr is None:
        return None
    (n,) = struct.unpack(">I", hdr)
    blob = _recv_exact(sock, n)
    if blob is None:
        return None
    return msgpack.unpackb(blob, object_hook=_object_hook, raw=False)


def _recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


class RPCServer:
    """Serve a dict of op -> handler(payload) over TCP."""

    def __init__(self, handlers: Dict[str, Callable], host: str, port: int):
        self.handlers = handlers
        self.host, self.port = host, port
        self._sock: socket.socket = None
        self._stop = threading.Event()
        self._thread: threading.Thread = None

    def start(self) -> int:
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((self.host, self.port))
        self.port = self._sock.getsockname()[1]
        self._sock.listen(16)
        self._sock.settimeout(0.2)
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self.port

    def _loop(self):
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            threading.Thread(target=self._handle, args=(conn,),
                             daemon=True).start()
        self._sock.close()

    def _handle(self, conn):
        with conn:
            while True:
                msg = recv_msg(conn)
                if msg is None:
                    return
                op = msg.get("op")
                try:
                    fn = self.handlers[op]
                    result = fn(msg.get("payload") or {})
                    send_msg(conn, {"ok": True, "result": result})
                except Exception as e:
                    send_msg(conn, {"ok": False, "error": repr(e)})

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)


class RPCClient:
    def __init__(self, host: str, port: int, timeout: float = 60.0):
        self.sock = socket.create_connection((host, port), timeout=timeout)

    def call(self, op: str, payload: Any = None):
        send_msg(self.sock, {"op": op, "payload": payload})
        resp = recv_msg(self.sock)
        if resp is None:
            raise ConnectionError("server closed connection")
        if not resp["ok"]:
            raise RuntimeError(f"server error: {resp['error']}")
        return resp["result"]

    def close(self):
        self.sock.close()
