"""msgpack-over-TCP transport (offline stand-in for the paper's gRPC).

Framing: 4-byte big-endian length + msgpack blob. numpy arrays are encoded
as {"__nd__": True, "d": dtype, "s": shape, "b": bytes}. Every request may
carry a ``session`` id, delivered to the handler as its second argument —
the multi-tenant hook the AL service uses to address per-client pools; a
per-connection ``ctx`` dict (third argument) lets handlers park state that
must be reclaimed when the connection dies (``on_close(ctx)``).

Dispatch is FRAME-level, not connection-level: one selector event loop
reads every socket and feeds complete frames through a
``FrameScheduler`` (service.admission) to a shared pool of ``max_workers``
handler threads. Per-connection ordering is preserved (at most one frame
of a connection is in flight at a time), idle connections cost nothing,
and frames are scheduled across tenants by weighted fair queueing — a
heavy tenant cannot starve light ones. With admission enabled, a frame
past the inflight bound or its tenant's token bucket is answered with a
structured ``overloaded`` rejection carrying ``retry_after_s`` instead of
queueing without bound, and a frame whose ``deadline`` already passed is
shed before dispatch and re-checked at queue-head.

Overload/robustness semantics:
  * ``send_timeout_s``: a stopped-reading client cannot wedge a worker —
    a send that makes no progress for that long closes the connection.
  * ``idle_timeout_s`` (0 = off): a silent/half-open client with nothing
    queued is closed and its ``on_close`` cleanup fired.
  * ``stop()`` is deterministic: stop admitting, answer every queued-not-
    started frame with a ``shutdown`` rejection, drain in-flight handlers,
    then close every connection (firing ``on_close`` exactly once each).

Responses echo the request's ``id``, and ``RPCClient.call`` poisons the
connection on a mid-call timeout: a late response frame from a timed-out
request can never be mistaken for the answer to a later call. Structured
error codes (``overloaded`` / ``deadline`` / ``timeout``) re-raise
client-side as the typed exceptions in service.errors, so ``except
ServerOverloaded`` works identically in-process and across the wire.
"""
from __future__ import annotations

import itertools
import select
import selectors
import socket
import struct
import threading
import time
from typing import Any, Callable, Dict, Optional

import msgpack
import numpy as np

from repro.service.admission import (AdmissionConfig, FrameScheduler,
                                     attach_stream)
from repro.service.errors import DeadlineExceeded, ServerOverloaded


def _default(obj):
    if isinstance(obj, np.ndarray):
        return {"__nd__": True, "d": str(obj.dtype), "s": list(obj.shape),
                "b": obj.tobytes()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    raise TypeError(f"unserializable: {type(obj)}")


def _object_hook(obj):
    if obj.get("__nd__"):
        # frombuffer returns a READ-ONLY view of the msgpack blob; decoded
        # payloads must be mutable (backends preprocess in place), so copy
        return np.frombuffer(obj["b"], dtype=obj["d"]).reshape(obj["s"]).copy()
    return obj


def encode_msg(obj: Any) -> bytes:
    blob = msgpack.packb(obj, default=_default, use_bin_type=True)
    return struct.pack(">I", len(blob)) + blob


def send_msg(sock: socket.socket, obj: Any) -> None:
    sock.sendall(encode_msg(obj))


def recv_msg(sock: socket.socket) -> Any:
    hdr = _recv_exact(sock, 4)
    if hdr is None:
        return None
    (n,) = struct.unpack(">I", hdr)
    blob = _recv_exact(sock, n)
    if blob is None:
        return None
    return msgpack.unpackb(blob, object_hook=_object_hook, raw=False)


def _recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


class _Conn:
    """One accepted connection: its parse buffer, per-connection handler
    ctx, send lock, liveness stamps — plus the scheduler-owned stream
    attributes (``attach_stream``)."""

    _ids = itertools.count(1)

    def __init__(self, sock: socket.socket, addr):
        self.sock = sock
        self.addr = addr
        self.cid = next(self._ids)
        self.ctx: dict = {}
        self.buf = bytearray()
        self.send_lock = threading.Lock()
        self.last_recv = time.monotonic()
        self.eof = False          # peer closed (or socket error): drain+die
        self.finalized = False    # closed + on_close fired (exactly once)
        attach_stream(self)


class RPCServer:
    """Serve a dict of op -> handler(payload, session, ctx) over TCP.

    ``max_workers`` bounds the handler threads shared across ALL
    connections (frame-level dispatch); the accept backlog is a fixed 128,
    so clients beyond the worker pool queue instead of being refused.
    ``admission``/``fairness_weights`` wire the overload layer; both
    default to off/uniform, which preserves unbounded-FIFO behaviour."""

    def __init__(self, handlers: Dict[str, Callable], host: str, port: int,
                 max_workers: int = 16,
                 on_close: Callable[[dict], None] = None,
                 admission: Optional[AdmissionConfig] = None,
                 fairness_weights: Optional[Dict[str, float]] = None,
                 idle_timeout_s: float = 0.0,
                 send_timeout_s: float = 30.0):
        self.handlers = handlers
        self.host, self.port = host, port
        self.max_workers = max(int(max_workers), 1)
        self.on_close = on_close
        self.idle_timeout_s = float(idle_timeout_s)
        self.send_timeout_s = float(send_timeout_s)
        self._sched = FrameScheduler(admission, weights=fairness_weights,
                                     workers=self.max_workers)
        self._sock: socket.socket = None
        self._sel: selectors.BaseSelector = None
        self._stop = threading.Event()
        self._stopped = False
        self._thread: threading.Thread = None
        self._workers: list = []
        self._conns: set = set()
        self._conns_lock = threading.Lock()
        self._wake_r: socket.socket = None
        self._wake_w: socket.socket = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> int:
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((self.host, self.port))
        self.port = self._sock.getsockname()[1]
        # fixed backlog, decoupled from the worker pool: clients beyond
        # max_workers must queue at accept, not get connection-refused
        self._sock.listen(128)
        self._sock.setblocking(False)
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._sel = selectors.DefaultSelector()
        self._sel.register(self._sock, selectors.EVENT_READ, "listen")
        self._sel.register(self._wake_r, selectors.EVENT_READ, "wake")
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="rpc-loop")
        self._thread.start()
        self._workers = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"rpc-w{i}")
            for i in range(self.max_workers)]
        for w in self._workers:
            w.start()
        return self.port

    def _wake(self) -> None:
        try:
            self._wake_w.send(b"x")
        except OSError:
            pass

    def stop(self):
        """Deterministic shutdown: stop accepting and admitting, answer
        every queued-not-started frame with a ``shutdown`` rejection,
        drain in-flight handlers (their responses still send), then close
        every connection — ``on_close`` fires exactly once per
        connection, with no socket-close race against live handlers."""
        if self._stopped:
            return
        self._stopped = True
        self._stop.set()
        self._wake()
        if self._thread:
            self._thread.join(timeout=5)
        # 1) every admitted-but-unstarted frame gets a shutdown answer
        #    (nothing ran server-side, so the client may safely retry
        #    elsewhere); queued control responses still flush
        for stream, _, payload, control in self._sched.cancel_pending():
            resp = (payload if control else
                    {"ok": False, "id": payload.get("id"),
                     "code": "shutdown", "error": "server stopped"})
            try:
                self._send(stream, resp)
            except OSError:
                pass
        # 2) drain: workers finish executing frames (and any follow-up
        #    frames those streams had admitted), then exit on the closed,
        #    empty scheduler
        self._sched.close()
        for w in self._workers:
            w.join(timeout=10)
        # 3) close every connection, firing on_close exactly once each
        with self._conns_lock:
            conns = list(self._conns)
        for conn in conns:
            self._finalize(conn)
        for s in (self._wake_r, self._wake_w):
            try:
                s.close()
            except OSError:
                pass
        if self._sel is not None:
            self._sel.close()

    def stats(self) -> dict:
        """Scheduler/admission counters + live connection count."""
        with self._conns_lock:
            n = len(self._conns)
        return {"connections": n, **self._sched.stats()}

    # ----------------------------------------------------------- event loop
    def _loop(self):
        while not self._stop.is_set():
            try:
                events = self._sel.select(timeout=0.2)
            except OSError:
                break
            for key, _ in events:
                if key.data == "listen":
                    self._accept()
                elif key.data == "wake":
                    try:
                        while self._wake_r.recv(4096):
                            pass
                    except (BlockingIOError, OSError):
                        pass
                else:
                    self._readable(key.data)
            self._tick()
        try:
            self._sock.close()
        except OSError:
            pass

    def _accept(self):
        while True:
            try:
                sock, addr = self._sock.accept()
            except (BlockingIOError, OSError):
                return
            sock.setblocking(False)
            conn = _Conn(sock, addr)
            with self._conns_lock:
                self._conns.add(conn)
            self._sel.register(sock, selectors.EVENT_READ, conn)

    def _readable(self, conn: _Conn):
        if conn.finalized:
            return
        try:
            while True:
                chunk = conn.sock.recv(65536)
                if not chunk:
                    conn.eof = True
                    break
                conn.buf += chunk
                conn.last_recv = time.monotonic()
        except (BlockingIOError, InterruptedError):
            pass
        except OSError:
            conn.eof = True
        self._parse_frames(conn)
        self._maybe_finalize(conn)

    def _parse_frames(self, conn: _Conn):
        while not conn.eof or conn.buf:
            if len(conn.buf) < 4:
                return
            (n,) = struct.unpack(">I", bytes(conn.buf[:4]))
            if len(conn.buf) < 4 + n:
                return
            blob = bytes(conn.buf[4:4 + n])
            del conn.buf[:4 + n]
            try:
                msg = msgpack.unpackb(blob, object_hook=_object_hook,
                                      raw=False)
                if not isinstance(msg, dict):
                    raise ValueError("frame is not a request map")
            except Exception:
                conn.eof = True       # garbage on the wire: drop the conn
                conn.buf.clear()
                return
            self._submit(conn, msg)

    def _submit(self, conn: _Conn, msg: dict):
        # the tenant is the frame's session id; session-less frames fall
        # back to a per-connection tenant so WFQ still spreads them
        tenant = msg.get("session") or f"conn-{conn.cid}"
        verdict, code, retry = self._sched.submit(conn, tenant, msg)
        if verdict == "shed":
            resp = self._shed_response(msg.get("id"), code, retry)
            # the rejection rides the stream's FIFO like any response (it
            # must not overtake an earlier admitted frame's answer)
            self._sched.submit_control(conn, tenant, resp)

    @staticmethod
    def _shed_response(rid, code: str, retry_after_s: float) -> dict:
        if code == "overloaded":
            return {"ok": False, "id": rid, "code": "overloaded",
                    "retry_after_s": float(retry_after_s),
                    "error": "server overloaded (admission control); "
                             "the request did not run"}
        if code == "deadline":
            return {"ok": False, "id": rid, "code": "deadline",
                    "error": "deadline expired before dispatch"}
        return {"ok": False, "id": rid, "code": "shutdown",
                "error": "server shutting down"}

    def _tick(self):
        """Periodic sweep: finalize drained-EOF connections and enforce
        the idle timeout on silent/half-open clients."""
        now = time.monotonic()
        with self._conns_lock:
            conns = list(self._conns)
        for conn in conns:
            if conn.eof:
                self._maybe_finalize(conn)
            elif (self.idle_timeout_s > 0 and not conn.pending
                  and not conn.inflight
                  and now - conn.last_recv > self.idle_timeout_s):
                self._finalize(conn)

    def _maybe_finalize(self, conn: _Conn):
        """EOF semantics: frames already received keep being served (their
        responses may still reach a half-closed peer); the connection dies
        once nothing of it remains queued or executing."""
        if conn.eof and not conn.pending and not conn.inflight:
            self._finalize(conn)

    def _finalize(self, conn: _Conn):
        """Close exactly once: unregister, drop queued frames, close the
        socket, fire on_close. Called from the event loop and stop()
        (never concurrently with each other for the same conn thanks to
        the ``finalized`` flag under the conns lock)."""
        with self._conns_lock:
            if conn.finalized:
                return
            conn.finalized = True
            self._conns.discard(conn)
        self._sched.drop_stream(conn)
        if self._sel is not None:
            try:
                self._sel.unregister(conn.sock)
            except (KeyError, ValueError, OSError):
                pass
        try:
            conn.sock.close()
        except OSError:
            pass
        if self.on_close:
            try:
                self.on_close(conn.ctx)
            except Exception:
                pass

    # -------------------------------------------------------------- workers
    def _worker(self):
        while True:
            item = self._sched.next(timeout=0.2)
            if item is None:
                if self._sched.closed:
                    return
                continue
            conn, tenant, payload, control = item
            t0 = time.perf_counter()
            try:
                if control:
                    self._send(conn, payload)
                else:
                    self._serve(conn, tenant, payload)
            finally:
                self._sched.done(conn, time.perf_counter() - t0,
                                 control=control)

    def _serve(self, conn: _Conn, tenant: str, msg: dict):
        rid = msg.get("id")
        deadline = msg.get("deadline")
        if deadline is not None and time.time() > float(deadline):
            # queue-head shed: the client stopped waiting while this frame
            # sat in the dispatch queue — don't burn shard-pool time on it
            self._sched.count(tenant, "expired")
            self._send(conn, {"ok": False, "id": rid, "code": "deadline",
                              "error": "deadline expired at queue head"})
            return
        try:
            fn = self.handlers[msg.get("op")]
            result = fn(msg.get("payload") or {}, msg.get("session"),
                        conn.ctx)
            self._send(conn, {"ok": True, "id": rid, "result": result})
        except ServerOverloaded as e:
            self._send(conn, {"ok": False, "id": rid, "code": "overloaded",
                              "retry_after_s": e.retry_after_s,
                              "error": repr(e)})
        except DeadlineExceeded as e:
            self._send(conn, {"ok": False, "id": rid, "code": "deadline",
                              "error": repr(e)})
        except TimeoutError as e:
            self._send(conn, {"ok": False, "id": rid, "code": "timeout",
                              "error": repr(e)})
        except Exception as e:
            self._send(conn, {"ok": False, "id": rid, "error": repr(e)})

    def _send(self, conn: _Conn, obj: Any):
        """Serialize + send under the connection's send lock. A send that
        stalls past ``send_timeout_s`` (stopped-reading client) or fails
        marks the connection dead — the event loop finalizes it — so no
        worker is ever wedged in a blocking send."""
        if conn.finalized:
            return
        data = encode_msg(obj)
        try:
            with conn.send_lock:
                self._sendall(conn.sock, data)
        except OSError:
            conn.eof = True
            self._wake()

    def _sendall(self, sock: socket.socket, data: bytes):
        t = self.send_timeout_s
        view = memoryview(data)
        off = 0
        stalled = time.monotonic()
        while off < len(view):
            try:
                off += sock.send(view[off:])
                stalled = time.monotonic()
            except (BlockingIOError, InterruptedError):
                if t > 0:
                    waited = time.monotonic() - stalled
                    if waited >= t:
                        raise socket.timeout(
                            f"send stalled {waited:.1f}s (client not "
                            f"reading)") from None
                    select.select([], [sock], [], min(t - waited, 0.2))
                else:
                    select.select([], [sock], [], 0.2)


class RPCClient:
    """One connection, serial request/response pairs. ``call`` holds a lock
    around the send+recv pair so multiple threads (e.g. the ALClient's
    async-push I/O thread and the caller's thread) can share the
    connection without interleaving frames.

    Requests carry a monotone ``id`` the server echoes, plus an optional
    absolute ``deadline`` (epoch seconds) the server sheds expired work
    by, and an ``attempt`` counter so server-side per-tenant retry
    accounting works. A ``call`` that times out mid-recv leaves its
    response frame in flight — the next recv on this socket would read
    THAT frame, a silent wrong answer — so a timeout POISONS the
    connection: the socket is closed, the call raises ``ConnectionError``,
    and every later call fails fast instead of desyncing. Mismatched ids
    (defense in depth) are dropped, never returned.

    Structured server rejections re-raise as typed exceptions:
    ``overloaded`` -> ServerOverloaded (carrying ``retry_after_s``; the op
    never ran, retrying is safe), ``deadline`` -> DeadlineExceeded,
    ``timeout`` -> TimeoutError, ``shutdown`` -> ConnectionError."""

    def __init__(self, host: str, port: int, timeout: float = 60.0):
        self.sock = socket.create_connection((host, port), timeout=timeout)
        self._lock = threading.Lock()
        self._req_id = 0
        self._poisoned: str = ""

    def call(self, op: str, payload: Any = None, session: Any = None,
             deadline: Optional[float] = None, attempt: int = 0):
        with self._lock:
            if self._poisoned:
                raise ConnectionError(self._poisoned)
            self._req_id += 1
            rid = self._req_id
            req = {"op": op, "payload": payload, "session": session,
                   "id": rid}
            if deadline is not None:
                req["deadline"] = float(deadline)
            if attempt:
                req["attempt"] = int(attempt)
            try:
                send_msg(self.sock, req)
                resp = recv_msg(self.sock)
                # a frame tagged for an OLDER request can only appear if a
                # past timeout somehow didn't poison us; drop it
                while resp is not None and resp.get("id") not in (None, rid):
                    resp = recv_msg(self.sock)
            except socket.timeout:
                self._poison(f"request {rid} ({op}) timed out mid-call; "
                             "connection closed to avoid response desync")
                raise ConnectionError(self._poisoned) from None
            except OSError as e:
                self._poison(f"connection broken during {op}: {e!r}")
                raise ConnectionError(self._poisoned) from e
        if resp is None:
            raise ConnectionError("server closed connection")
        if not resp["ok"]:
            code = resp.get("code")
            if code == "overloaded":
                raise ServerOverloaded(
                    float(resp.get("retry_after_s", 0.05)),
                    resp.get("error", "server overloaded"))
            if code == "deadline":
                raise DeadlineExceeded(resp.get("error",
                                                "deadline exceeded"))
            if code == "timeout":
                raise TimeoutError(resp.get("error", "server-side timeout"))
            if code == "shutdown":
                raise ConnectionError(
                    f"server shutting down: {resp.get('error')}")
            raise RuntimeError(f"server error: {resp['error']}")
        return resp["result"]

    def _poison(self, reason: str) -> None:
        self._poisoned = reason
        try:
            self.sock.close()
        except OSError:
            pass

    def close(self):
        self.sock.close()
