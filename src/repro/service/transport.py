"""msgpack-over-TCP transport (offline stand-in for the paper's gRPC).

Framing: 4-byte big-endian length + msgpack blob. numpy arrays are encoded
as {"__nd__": True, "d": dtype, "s": shape, "b": bytes}. Every request may
carry a ``session`` id, delivered to the handler as its second argument —
the multi-tenant hook the AL service uses to address per-client pools; a
per-connection ``ctx`` dict (third argument) lets handlers park state that
must be reclaimed when the connection dies (``on_close(ctx)``).

Connections are served from a bounded thread pool: one worker per LIVE
connection, so ``max_workers`` is a hard cap on concurrently SERVED
clients — client max_workers+1 is accepted (the listen backlog is a fixed
128, independent of the pool size) and queues until another disconnects,
it is not interleaved per-request. Size the pool for the expected tenant
count.

Responses echo the request's ``id``, and ``RPCClient.call`` poisons the
connection on a mid-call timeout: a late response frame from a timed-out
request can never be mistaken for the answer to a later call.
"""
from __future__ import annotations

import concurrent.futures as cf
import socket
import struct
import threading
from typing import Any, Callable, Dict

import msgpack
import numpy as np


def _default(obj):
    if isinstance(obj, np.ndarray):
        return {"__nd__": True, "d": str(obj.dtype), "s": list(obj.shape),
                "b": obj.tobytes()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    raise TypeError(f"unserializable: {type(obj)}")


def _object_hook(obj):
    if obj.get("__nd__"):
        # frombuffer returns a READ-ONLY view of the msgpack blob; decoded
        # payloads must be mutable (backends preprocess in place), so copy
        return np.frombuffer(obj["b"], dtype=obj["d"]).reshape(obj["s"]).copy()
    return obj


def send_msg(sock: socket.socket, obj: Any) -> None:
    blob = msgpack.packb(obj, default=_default, use_bin_type=True)
    sock.sendall(struct.pack(">I", len(blob)) + blob)


def recv_msg(sock: socket.socket) -> Any:
    hdr = _recv_exact(sock, 4)
    if hdr is None:
        return None
    (n,) = struct.unpack(">I", hdr)
    blob = _recv_exact(sock, n)
    if blob is None:
        return None
    return msgpack.unpackb(blob, object_hook=_object_hook, raw=False)


def _recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


class RPCServer:
    """Serve a dict of op -> handler(payload, session, ctx) over TCP."""

    def __init__(self, handlers: Dict[str, Callable], host: str, port: int,
                 max_workers: int = 16,
                 on_close: Callable[[dict], None] = None):
        self.handlers = handlers
        self.host, self.port = host, port
        self.max_workers = max(int(max_workers), 1)
        self.on_close = on_close
        self._sock: socket.socket = None
        self._stop = threading.Event()
        self._thread: threading.Thread = None
        self._pool: cf.ThreadPoolExecutor = None
        self._conns: set = set()
        self._conns_lock = threading.Lock()

    def start(self) -> int:
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((self.host, self.port))
        self.port = self._sock.getsockname()[1]
        # fixed backlog, decoupled from the worker pool: clients beyond
        # max_workers must queue at accept, not get connection-refused
        self._sock.listen(128)
        self._sock.settimeout(0.2)
        self._pool = cf.ThreadPoolExecutor(max_workers=self.max_workers,
                                           thread_name_prefix="rpc")
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self.port

    def _loop(self):
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            with self._conns_lock:
                self._conns.add(conn)
            self._pool.submit(self._handle, conn)
        self._sock.close()

    def _handle(self, conn):
        # one pool worker per live connection; requests on a connection are
        # served in order, different connections run concurrently. ctx is
        # per-connection state (e.g. sessions opened here) handed to
        # on_close so a vanished client cannot leak server-side resources.
        ctx: dict = {}
        try:
            with conn:
                while not self._stop.is_set():
                    try:
                        msg = recv_msg(conn)
                    except OSError:   # socket torn down under us (stop())
                        return
                    if msg is None:
                        return
                    op = msg.get("op")
                    rid = msg.get("id")
                    try:
                        fn = self.handlers[op]
                        result = fn(msg.get("payload") or {},
                                    msg.get("session"), ctx)
                        send_msg(conn, {"ok": True, "id": rid,
                                        "result": result})
                    except Exception as e:
                        send_msg(conn, {"ok": False, "id": rid,
                                        "error": repr(e)})
        finally:
            with self._conns_lock:
                self._conns.discard(conn)
            if self.on_close:
                self.on_close(ctx)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)
        # workers block in recv_msg on live connections; closing the
        # sockets unblocks them so shutdown() below can actually complete
        # (otherwise concurrent.futures' atexit join hangs the process)
        with self._conns_lock:
            conns = list(self._conns)
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            conn.close()
        if self._pool:
            self._pool.shutdown(wait=True)


class RPCClient:
    """One connection, serial request/response pairs. ``call`` holds a lock
    around the send+recv pair so multiple threads (e.g. the ALClient's
    async-push I/O thread and the caller's thread) can share the
    connection without interleaving frames.

    Requests carry a monotone ``id`` the server echoes. A ``call`` that
    times out mid-recv leaves its response frame in flight — the next recv
    on this socket would read THAT frame, a silent wrong answer — so a
    timeout POISONS the connection: the socket is closed, the call raises
    ``ConnectionError``, and every later call fails fast instead of
    desyncing. Mismatched ids (defense in depth) are dropped, never
    returned."""

    def __init__(self, host: str, port: int, timeout: float = 60.0):
        self.sock = socket.create_connection((host, port), timeout=timeout)
        self._lock = threading.Lock()
        self._req_id = 0
        self._poisoned: str = ""

    def call(self, op: str, payload: Any = None, session: Any = None):
        with self._lock:
            if self._poisoned:
                raise ConnectionError(self._poisoned)
            self._req_id += 1
            rid = self._req_id
            try:
                send_msg(self.sock, {"op": op, "payload": payload,
                                     "session": session, "id": rid})
                resp = recv_msg(self.sock)
                # a frame tagged for an OLDER request can only appear if a
                # past timeout somehow didn't poison us; drop it
                while resp is not None and resp.get("id") not in (None, rid):
                    resp = recv_msg(self.sock)
            except socket.timeout:
                self._poison(f"request {rid} ({op}) timed out mid-call; "
                             "connection closed to avoid response desync")
                raise ConnectionError(self._poisoned) from None
            except OSError as e:
                self._poison(f"connection broken during {op}: {e!r}")
                raise ConnectionError(self._poisoned) from e
        if resp is None:
            raise ConnectionError("server closed connection")
        if not resp["ok"]:
            raise RuntimeError(f"server error: {resp['error']}")
        return resp["result"]

    def _poison(self, reason: str) -> None:
        self._poisoned = reason
        try:
            self.sock.close()
        except OSError:
            pass

    def close(self):
        self.sock.close()
