"""ALServer — the paper's AL-as-a-service backend (Fig. 1).

Data path (stage-level pipeline, Fig. 3c):
  fetch (URI/bytes -> raw)  ->  preprocess  ->  infer (batched features via
  DynamicBatcher)  ->  EmbeddingCache

Query path:
  strategy != "auto": run one zoo strategy over the pooled artifacts.
  strategy == "auto": run the PSHEA agent (performance predictor + successive
  halving) against the attached oracle, per paper Alg. 1.

The server is usable in-process (ALClient(local=server)) or over the msgpack
TCP transport in transport.py (gRPC stand-in; see DESIGN.md).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.agent.controller import run_pshea
from repro.core.strategies.zoo import HYBRIDS, PAPER_SEVEN, get_strategy
from repro.service.backends import FeatureBackend, HeadState, make_backend
from repro.service.batcher import DynamicBatcher
from repro.service.cache import EmbeddingCache, content_key
from repro.service.config import ALServiceConfig
from repro.service.pipeline import Stage, StagePipeline


class ALServer:
    def __init__(self, config: Optional[ALServiceConfig] = None,
                 config_path: Optional[str] = None,
                 backend: Optional[FeatureBackend] = None,
                 fetch_fn: Optional[Callable] = None,
                 fetch_latency_s: float = 0.0):
        if config is None:
            config = (ALServiceConfig.from_yaml(config_path)
                      if config_path else ALServiceConfig())
        self.config = config
        self.backend = backend or make_backend(config.model_name)
        self.cache = EmbeddingCache(config.cache_bytes,
                                    config.cache_spill_dir)
        self.fetch_fn = fetch_fn or (lambda x: x)
        self.fetch_latency_s = fetch_latency_s
        self._keys: List[str] = []
        self._raw: Dict[str, np.ndarray] = {}
        self._labels: Dict[str, int] = {}
        self._labeled_keys: List[str] = []
        self._head: Optional[HeadState] = None
        self._eval_set: Optional[tuple] = None
        self._oracle: Optional[Callable[[Sequence[str]], Sequence[int]]] = None
        self._lock = threading.Lock()
        self.last_pipeline_stats = None

    # ------------------------------------------------------------- data --
    def push_data(self, items: Sequence[np.ndarray],
                  pipelined: bool = True) -> List[str]:
        """Ingest unlabeled pool items through the stage pipeline; returns
        content keys. Cached items skip preprocessing+inference entirely."""
        keys = [content_key(np.asarray(it)) for it in items]
        todo = [(k, it) for k, it in zip(keys, items) if k not in self.cache]
        with self._lock:
            for k, it in zip(keys, items):
                if k not in self._raw:
                    self._raw[k] = np.asarray(it)
                    self._keys.append(k)
        if todo:
            self._process(todo, pipelined=pipelined)
        return keys

    def _process(self, todo, *, pipelined: bool, chunk: int = 64):
        bs = max(self.config.batch_size, 1)
        batcher = DynamicBatcher(self._infer_batch, max_batch=bs)

        def fetch(chunk_items):
            if self.fetch_latency_s:
                time.sleep(self.fetch_latency_s)
            return [(k, self.fetch_fn(v)) for k, v in chunk_items]

        def preprocess(chunk_items):
            ks = [k for k, _ in chunk_items]
            raw = np.stack([np.asarray(v) for _, v in chunk_items])
            return ks, self.backend.preprocess(raw)

        def infer(args):
            ks, batch = args
            feats = batcher.score(list(batch))
            return list(zip(ks, feats))

        stages = [Stage("fetch", fetch), Stage("preprocess", preprocess),
                  Stage("infer", infer)]
        pipe = StagePipeline(stages)
        chunks = [todo[i:i + chunk] for i in range(0, len(todo), chunk)]
        runner = pipe.run if pipelined else pipe.run_serial
        for out in runner(chunks):
            for k, f in out:
                self.cache.put(k, np.asarray(f))
        self.last_pipeline_stats = pipe.stats()
        batcher.close()

    def _infer_batch(self, stacked: np.ndarray, n_valid: int):
        feats = self.backend.features(stacked)
        return [feats[i] for i in range(n_valid)]

    # ------------------------------------------------------- label/oracle --
    def attach_oracle(self, oracle: Callable[[Sequence[str]], Sequence[int]],
                      eval_x: np.ndarray, eval_y: np.ndarray):
        """Oracle = the paper's human annotator; eval set scores rounds."""
        self._oracle = oracle
        ex = self.backend.preprocess(np.asarray(eval_x))
        self._eval_set = (self.backend.features(ex), np.asarray(eval_y))

    def label(self, keys: Sequence[str], labels: Sequence[int]):
        with self._lock:
            for k, y in zip(keys, labels):
                if k not in self._labels:
                    self._labels[k] = int(y)
                    self._labeled_keys.append(k)

    # --------------------------------------------------------- artifacts --
    def _pool_artifacts(self, keys: Sequence[str]):
        feats = np.stack([self.cache.get(k) for k in keys])
        head = self._head or self.backend.init_head()
        probs = self.backend.probs(feats, head)
        return feats, probs

    def train_and_eval(self) -> float:
        keys = list(self._labeled_keys)
        if not keys:
            return 0.0
        feats = np.stack([self.cache.get(k) for k in keys])
        labels = np.asarray([self._labels[k] for k in keys])
        self._head = self.backend.fit_head(feats, labels, head=None)
        if self._eval_set is None:  # no eval set: train-set accuracy proxy
            return self.backend.evaluate(feats, labels, self._head)
        return self.backend.evaluate(*self._eval_set, self._head)

    # ------------------------------------------------------------- query --
    def query(self, budget: int, strategy: Optional[str] = None,
              target_accuracy: Optional[float] = None,
              rng_seed: int = 0) -> dict:
        strategy = strategy or self.config.strategy
        unlabeled = [k for k in self._keys if k not in self._labels]
        if strategy != "auto":
            return self._query_one(unlabeled, budget, strategy, rng_seed)
        return self._query_auto(budget, target_accuracy
                                or self.config.target_accuracy)

    def _query_one(self, unlabeled, budget, strategy, rng_seed) -> dict:
        budget = min(budget, len(unlabeled))
        strat = get_strategy(strategy)
        feats, probs = self._pool_artifacts(unlabeled)
        labeled_emb = None
        if self._labeled_keys:
            labeled_emb = np.stack(
                [self.cache.get(k) for k in self._labeled_keys])
        import jax.numpy as jnp
        idx = strat.select(
            jax.random.PRNGKey(rng_seed), budget,
            probs=jnp.asarray(probs) if "probs" in strat.needs else None,
            embeddings=jnp.asarray(feats) if "embeddings" in strat.needs else None,
            labeled_embeddings=(jnp.asarray(labeled_emb)
                                if labeled_emb is not None else None))
        idx = np.asarray(idx)
        return {"keys": [unlabeled[i] for i in idx],
                "indices": idx.tolist(), "strategy": strategy,
                "cache": self.cache.stats()}

    def _auto_candidates(self) -> List[str]:
        """The PSHEA agent's strategy registry: the paper's 7, plus the
        weighted fused-round hybrids when configured ("hybrid")."""
        mode = self.config.auto_candidates
        if mode == "hybrid":
            return PAPER_SEVEN + HYBRIDS
        if mode != "paper":
            # a typo must not silently degrade to the default set
            raise ValueError(f"auto_candidates must be 'paper' or 'hybrid', "
                             f"got {mode!r}")
        return list(PAPER_SEVEN)

    def _query_auto(self, budget: int, target_accuracy: float) -> dict:
        """PSHEA (paper Alg. 1) — needs an attached oracle."""
        assert self._oracle is not None, "PSHEA needs attach_oracle(...)"
        server = self
        candidates = self._auto_candidates()

        class Task:
            def __init__(self):
                self.labeled: Dict[str, List[str]] = {s: [] for s in candidates}
                self.rng = 0

            def initial_accuracy(self):
                return server.train_and_eval() if server._labeled_keys else 0.1

            def select_and_label(self, strategy, round_budget):
                self.rng += 1
                pool = [k for k in server._keys
                        if k not in self.labeled[strategy]]
                res = server._query_one(pool, round_budget, strategy, self.rng)
                keys = res["keys"]
                self.labeled[strategy].extend(keys)
                return len(keys)

            def train_and_eval(self, strategy):
                keys = self.labeled[strategy]
                labels = server._oracle(keys)
                feats = np.stack([server.cache.get(k) for k in keys])
                head = server.backend.fit_head(feats, np.asarray(labels))
                return server.backend.evaluate(*server._eval_set, head)

        n_strats = len(candidates)
        round_budget = max(budget // (2 * n_strats), 1)
        result = run_pshea(Task(), candidates,
                           target_accuracy=target_accuracy,
                           budget_max=budget, round_budget=round_budget)
        return {"strategy": result.best_strategy,
                "accuracy": result.best_accuracy,
                "stop_reason": result.stop_reason,
                "eliminated": result.eliminated,
                "history": result.history,
                "budget_spent": result.budget_spent}

    # -------------------------------------------------------------- misc --
    def stats(self) -> dict:
        return {"pool": len(self._keys), "labeled": len(self._labeled_keys),
                "cache": self.cache.stats(),
                "pipeline": self.last_pipeline_stats}
