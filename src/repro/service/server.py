"""ALServer — the paper's AL-as-a-service backend (Fig. 1), multi-tenant.

One ``ALServer`` owns the *shared* resources — scorer backend, the
content-addressed ``EmbeddingCache``, config — and hosts many independent
``ALSession`` objects (one per client/tenant). A session carries everything
that used to be server-global: the pool key list, raw copies, labels, the
trained head, the oracle, and a versioned pool-artifact cache. Content
addressing makes sharing the embedding cache across sessions safe: two
tenants pushing the same sample compute its features once.

Data path (stage-level pipeline, Fig. 3c):
  fetch (URI/bytes -> raw)  ->  preprocess  ->  infer (batched features via
  DynamicBatcher)  ->  EmbeddingCache

Query path:
  strategy != "auto": run one zoo strategy over the pooled artifacts.
  strategy == "auto": run the PSHEA agent (performance predictor + successive
  halving) against the attached oracle, per paper Alg. 1. With
  ``pshea_workers > 1`` the surviving candidates race on a thread pool, so a
  round costs max(candidate) instead of sum(candidate); per-(strategy, round)
  rng streams keep the parallel schedule bit-identical to the serial one.

Pool artifacts are INCREMENTAL, per shard and per column
(core.selection.ShardColumns): every shard carries its own ``rows_epoch``,
so a push invalidates only the shards it touched and the refresh embeds
only the appended rows, extending the shard's growable ``feats`` buffer in
place; ``feats`` and ``probs`` have decoupled lifetimes, so
``train_and_eval`` re-runs just the head forward over the cached feats
(zero re-embeds) and ``label`` invalidates nothing at all (the unlabeled
set is a separately-versioned mask applied at query time). Steady-state
query cost after a data change is O(delta) embed work, not O(pool) — and
PSHEA's 7-10 candidates still share ONE refresh per version instead of
re-stacking the pool per candidate.

Replica sharding (config ``replicas: N``): each session's pool is
hash-partitioned by content key across N shards. Artifacts are built per
shard in parallel, every query strategy runs its replica-sharded path
(local propose, global merge — core.selection), and selections are
bit-identical to ``replicas=1``. ``push_data(asynchronous=True)`` enqueues
onto a per-session ingest queue whose worker embeds drained batches per
shard and bumps pool_version once per batch; ``flush()`` is the barrier
label/query/sync-push take so they linearize after pending ingests.

The server is usable in-process (ALClient(local=server)) or over the msgpack
TCP transport in transport.py (gRPC stand-in; see DESIGN.md).
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import shutil
import tempfile
import threading
import time
import uuid
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

import dataclasses

from repro.core.agent.controller import run_pshea
from repro.distributed.worker import (PhaseFailureInjector, ShardWorkerPool)
from repro.core.prefilter import PrefilterConfig, maintain_summary
from repro.core.selection import (ColumnSpill, KCenterStateCache,
                                  ShardColumns, ShardView, grow_append,
                                  replica_map, replica_of)
from repro.core.strategies.zoo import HYBRIDS, PAPER_SEVEN, get_strategy
from repro.service.backends import FeatureBackend, HeadState, make_backend
from repro.service.batcher import DynamicBatcher
from repro.service.cache import EmbeddingCache, content_key
from repro.service.config import ALServiceConfig
from repro.service.errors import ServerOverloaded
from repro.service.pipeline import Stage, StagePipeline

DEFAULT_SESSION = "default"

# strategies whose sharded path starts from a warm (labeled-centers)
# min-dist fold — the ones the persisted KCenterStateCache can feed
_WARM_STATE_STRATEGIES = frozenset({"coreset", "weighted_kcenter"})


def _strategy_seed(strategy: str, round_index: int) -> int:
    """Deterministic per-(strategy, round) rng stream. Independent of how
    many candidates are live and of the order they execute in — the property
    that makes parallel PSHEA bit-identical to the serial schedule."""
    return zlib.crc32(f"{strategy}/{round_index}".encode())


class PushTicket:
    """Client-side future for ``push_data(asynchronous=True)``.

    ``keys`` (content hashes) are known at enqueue time. ``result()``
    blocks until the session's ingest worker has embedded and appended the
    batch (in-process mode) or until the server acknowledged the enqueue
    (TCP mode — the enqueue ack is what the connection returns); either
    way ``flush()`` on the client/session is the hard integration barrier.

    ``result(timeout=...)`` raises ``TimeoutError`` once the deadline
    passes — and raises it immediately, deadline or not, if the ingest
    worker serving this push has died without resolving it (``worker_alive``
    probe), instead of hanging the client forever.
    """

    _POLL_S = 0.1     # liveness re-check cadence while blocked on result()

    def __init__(self, keys: Sequence[str], future: "cf.Future",
                 worker_alive: Optional[Callable[[], bool]] = None):
        self.keys = list(keys)
        self._future = future
        self._worker_alive = worker_alive

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: Optional[float] = None) -> List[str]:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            wait = self._POLL_S
            if deadline is not None:
                wait = max(0.0, min(wait, deadline - time.monotonic()))
            try:
                self._future.result(wait)
                return self.keys
            except cf.TimeoutError:
                # on >=3.11 cf.TimeoutError IS TimeoutError: a future that
                # FAILED with one must propagate, not be mistaken for a poll
                if self._future.done():
                    raise
            # a dead worker can never resolve this future: fail fast even
            # with timeout=None rather than blocking forever
            if self._worker_alive is not None and not self._worker_alive():
                raise TimeoutError(
                    "ingest worker died before integrating this push; "
                    "the session is unusable for async ingest") from None
            if deadline is not None and time.monotonic() >= deadline:
                raise TimeoutError(
                    f"push not integrated within {timeout}s (ingest queue "
                    f"busy or stalled); flush() is the hard barrier"
                ) from None


class StandingQuery:
    """One registered ``(budget, strategy)`` subscription on a session.

    Every emit is the EXACT selection a one-shot ``query()`` would return
    over the pool at that moment (provisional/replace semantics — emits
    carry added/removed diffs against the previous emit), so the final
    emit after the stream settles is bit-identical to a one-shot query
    over the final pool. Between emits the replay engine (see
    ``ALSession._standing_replay``) stores the previous selection plus the
    per-slot merged winner scores captured by ``replica_greedy_select``;
    when no delta row beats any recorded winner, the selection is provably
    unchanged and the emit streams only the delta rows.

    All mutable fields are guarded by ``lock``; an emit holds it end to
    end, so concurrent triggers (ingest worker + a poll) serialize and the
    second sees fresh versions and no-ops.
    """

    def __init__(self, qid: str, budget: int, strategy: str, rng_seed: int):
        self.qid = qid
        self.budget = int(budget)
        self.strategy = strategy
        self.rng_seed = int(rng_seed)
        self.lock = threading.RLock()
        self.emits: List[dict] = []
        self.seq = 0
        self.cancelled: Optional[str] = None      # cancellation reason
        self.error: Optional[BaseException] = None
        # -- replay state (valid when the last emit used the full budget) --
        self.keys: Optional[List[str]] = None     # last emitted selection
        self.values: Optional[List[float]] = None  # per-slot winner scores
        self.n_unlabeled = 0      # unlabeled-list length at the last emit
        self.pool_version = -1
        self.labels_version = -1
        self.head_version = -1


class ALSession:
    """Per-tenant AL state: pool, labels, head, oracle, artifact cache."""

    def __init__(self, server: "ALServer", session_id: str):
        self.server = server
        self.session_id = session_id
        self.replicas = max(int(server.config.replicas), 1)
        self._keys: List[str] = []
        self._raw: Dict[str, np.ndarray] = {}
        self._labels: Dict[str, int] = {}
        self._labeled_keys: List[str] = []
        self._head: Optional[HeadState] = None
        self._eval_set: Optional[tuple] = None
        self._oracle: Optional[Callable[[Sequence[str]], Sequence[int]]] = None
        self._lock = threading.RLock()
        self.last_pipeline_stats = None
        # -- incremental pool-artifact engine ---------------------------
        # One ShardColumns per replica shard (ONE shard at replicas=1 —
        # the unsharded query path is just its 1-shard case). Columns are
        # epoch-stamped and refreshed incrementally:
        #   rows appended -> only the touched shards' rows_epoch moves;
        #     refresh embeds ONLY the appended rows (growable buffers);
        #   train_and_eval -> head_version moves; refresh re-runs the head
        #     over cached feats, ZERO re-embeds;
        #   label -> labels_version moves; artifacts untouched (the
        #     unlabeled set is a mask applied at query time).
        # pool_version stays the coarse monotone row-append counter the
        # ingest contract is specified against (one bump per appending
        # push/drained batch).
        self.pool_version = 0
        self.head_version = 0
        self.labels_version = 0
        self.artifact_builds = 0     # refresh/build events that did work
        self.full_builds = 0         # shard feats columns built from empty
        self.delta_builds = 0        # shard feats columns extended in place
        self.probs_refreshes = 0     # head-only prob recomputes (0 embeds)
        # mmap spill for the artifact columns (shard_ram_bytes > 0): column
        # buffers past the RAM budget land in per-session spill files that
        # close() removes; None = RAM-only columns (the default)
        cfg = server.config
        self._spill: Optional[ColumnSpill] = None
        if int(cfg.shard_ram_bytes) > 0:
            base = cfg.shard_spill_dir or os.path.join(
                tempfile.gettempdir(), "repro-shard-spill")
            self._spill = ColumnSpill(
                os.path.join(base, f"{os.getpid()}-{uuid.uuid4().hex[:8]}"),
                int(cfg.shard_ram_bytes))
        # centroid prefilter (core.prefilter): summaries are maintained
        # alongside the columns when enabled; None = ungated full scans
        self._prefilter_cfg: Optional[PrefilterConfig] = None
        if cfg.prefilter:
            self._prefilter_cfg = PrefilterConfig(
                slack=float(cfg.prefilter_slack),
                clusters=int(cfg.prefilter_clusters),
                min_rows=int(cfg.prefilter_min_rows))
        self._columns = [ShardColumns(self._spill)
                         for _ in range(self.replicas)]
        self._index: Dict[str, Tuple[int, int]] = {}  # key -> (shard, row)
        # RLock: the worker runtime's on_death recovery hook resets a
        # shard's columns from INSIDE a refresh (which already holds the
        # lock on the supervising thread) as well as from query threads
        self._artifact_lock = threading.RLock()
        # shard recoveries: worker deaths whose on_death hook reset this
        # session's columns (the re-embed-from-raw path ran)
        self.shard_recoveries = 0
        # persisted k-center strategy state (strategy_state_cache): per-
        # shard min-dist vectors delta-extended on push, dropped on retrain
        self._kstate = KCenterStateCache()
        # -- standing queries -------------------------------------------
        # qid -> StandingQuery; the ingest worker emits after every
        # integrated batch, polls emit lazily for sync mutations
        self._standing: Dict[str, StandingQuery] = {}
        self._standing_lock = threading.Lock()
        self.standing_emits = 0
        self.standing_replay_emits = 0
        self.standing_full_emits = 0
        # -- async ingest queue -----------------------------------------
        # push_data(asynchronous=True) enqueues; a per-session worker
        # drains batches, embeds per shard, and bumps pool_version ONCE
        # per drained batch. flush() is the barrier label/query/sync-push
        # take so they linearize after every pending ingest.
        self._ingest_queue: List[tuple] = []
        self._ingest_cv = threading.Condition()
        self._ingest_busy = False
        self._ingest_thread: Optional[threading.Thread] = None
        self._ingest_stop = False
        self._ingest_error: Optional[BaseException] = None
        # bounded-ingest accounting (config ingest_max_rows/_bytes; 0 =
        # unbounded). rows/bytes span enqueue -> batch INTEGRATED, so the
        # cap bounds worker-held memory too, not just the queue
        self._ingest_rows = 0
        self._ingest_bytes = 0
        self._ingest_rows_hw = 0
        self._ingest_bytes_hw = 0
        self._ingest_depth_hw = 0
        self._ingest_shed = 0
        self._ingest_drain_ema_s = 0.05   # smoothed per-batch drain time
        # drained batches; pool_version bumps once per drained batch THAT
        # APPENDS NEW ROWS (all-duplicate or failed batches drain without
        # a bump), so pool_version <= ingest_batches always
        self.ingest_batches = 0

    # ------------------------------------------------------------- data --
    def push_data(self, items: Sequence[np.ndarray], pipelined: bool = True,
                  asynchronous: bool = False):
        """Synchronous: embed + append now, return keys. Asynchronous:
        enqueue for the ingest worker and return a ``PushTicket`` whose
        ``keys`` are immediately known (content hashes)."""
        if asynchronous:
            return self._push_async(items)
        self.flush()     # sync pushes order AFTER every pending async push
        # sync embedding stays on ONE pipeline even at replicas>1 (per-
        # shard parallel embedding is the ingest queue's job). The feature
        # path itself is batch-insensitive — row-local forward + one
        # canonical batch shape (DynamicBatcher pad_to_max) — so any
        # chunking of the same rows lands the identical feature bytes
        keys = [content_key(np.asarray(it)) for it in items]
        todo = [(k, it) for k, it in zip(keys, items)
                if k not in self.server.cache]
        with self._lock:
            self._append_rows(keys, [np.asarray(it) for it in items])
        if todo:
            self.last_pipeline_stats = self.server._process(
                todo, pipelined=pipelined)
        return keys

    def _append_rows(self, keys: Sequence[str],
                     items: Sequence[np.ndarray]) -> None:
        """Append the new (key, raw) rows to the pool and stamp the shards
        they land on: ONE rows_epoch tick per touched shard and ONE
        pool_version tick per appending event — untouched shards keep their
        artifact columns fresh. Caller holds ``self._lock``."""
        touched = set()
        for k, it in zip(keys, items):
            if k in self._raw:
                continue
            self._raw[k] = it
            self._keys.append(k)
            si = 0 if self.replicas == 1 else replica_of(k, self.replicas)
            col = self._columns[si]
            self._index[k] = (si, len(col.keys))
            col.keys.append(k)
            touched.add(si)
        if touched:
            for si in touched:
                self._columns[si].rows_epoch += 1
            self.pool_version += 1

    # ----------------------------------------------------- async ingest --
    def _push_async(self, items: Sequence[np.ndarray]) -> PushTicket:
        items = [np.asarray(it) for it in items]
        keys = [content_key(it) for it in items]
        rows = len(items)
        nbytes = sum(int(it.nbytes) for it in items)
        cfg = self.server.config
        policy = cfg.ingest_policy
        if policy not in ("block", "shed"):
            raise ValueError(f"ingest_policy must be 'block' or 'shed', "
                             f"got {policy!r}")
        fut: cf.Future = cf.Future()
        with self._ingest_cv:
            if self._ingest_stop:
                raise RuntimeError(f"session {self.session_id!r} is closed")
            while self._ingest_over_cap(rows, nbytes):
                if policy == "shed":
                    # nothing was enqueued: the push is cleanly retryable
                    self._ingest_shed += 1
                    raise ServerOverloaded(
                        self._ingest_retry_after(),
                        f"ingest queue full ({self._ingest_rows} rows / "
                        f"{self._ingest_bytes} bytes outstanding); "
                        f"retry after the worker drains")
                # block: backpressure the producer until the worker drains
                t = self._ingest_thread
                if t is not None and not t.is_alive():
                    raise RuntimeError(
                        "ingest worker died with the queue at capacity; "
                        "the session cannot drain")
                self._ingest_cv.wait(timeout=0.1)
                if self._ingest_stop:
                    raise RuntimeError(
                        f"session {self.session_id!r} is closed")
            self._ingest_rows += rows
            self._ingest_bytes += nbytes
            self._ingest_rows_hw = max(self._ingest_rows_hw,
                                       self._ingest_rows)
            self._ingest_bytes_hw = max(self._ingest_bytes_hw,
                                        self._ingest_bytes)
            self._ingest_queue.append((keys, items, fut))
            self._ingest_depth_hw = max(self._ingest_depth_hw,
                                        len(self._ingest_queue))
            if self._ingest_thread is None:
                self._ingest_thread = threading.Thread(
                    target=self._ingest_loop, daemon=True,
                    name=f"ingest-{self.session_id}")
                self._ingest_thread.start()
            self._ingest_cv.notify_all()
        return PushTicket(keys, fut, worker_alive=self._ingest_alive)

    def _ingest_over_cap(self, rows: int, nbytes: int) -> bool:
        """True when admitting (rows, nbytes) would exceed a configured
        cap. An oversize single push is still admitted once nothing is
        outstanding — it could otherwise never run. Caller holds
        ``_ingest_cv``."""
        if self._ingest_rows == 0 and self._ingest_bytes == 0:
            return False
        cfg = self.server.config
        max_rows = int(cfg.ingest_max_rows)
        max_bytes = int(cfg.ingest_max_bytes)
        return ((max_rows > 0 and self._ingest_rows + rows > max_rows)
                or (max_bytes > 0
                    and self._ingest_bytes + nbytes > max_bytes))

    def _ingest_retry_after(self) -> float:
        """Shed-push retry hint: time for the worker to drain the current
        backlog, from the smoothed per-batch drain time. Caller holds
        ``_ingest_cv``."""
        batches = (len(self._ingest_queue)
                   / max(self.server.config.ingest_batch, 1)
                   + (1 if self._ingest_busy else 0))
        return min(max(self._ingest_drain_ema_s * (batches + 1.0), 0.01),
                   5.0)

    def _ingest_alive(self) -> bool:
        """Liveness probe for PushTicket: a worker that exited with this
        push still queued/unresolved can never complete it."""
        t = self._ingest_thread
        return t is not None and t.is_alive()

    def _ingest_loop(self):
        while True:
            with self._ingest_cv:
                while not self._ingest_queue and not self._ingest_stop:
                    self._ingest_cv.wait()
                if not self._ingest_queue:   # stop requested, queue drained
                    return
                batch = self._ingest_queue[:self.server.config.ingest_batch]
                del self._ingest_queue[:len(batch)]
                self._ingest_busy = True
            t_drain = time.monotonic()
            err: Optional[BaseException] = None
            try:
                self._integrate(batch)
                for keys, _, fut in batch:
                    fut.set_result(keys)
            except BaseException as batch_err:
                if len(batch) == 1:
                    err = batch_err
                    batch[0][2].set_exception(batch_err)
                else:
                    # isolate the blast radius: re-integrate each
                    # coalesced push on its own, so one malformed push
                    # cannot drop the rows of valid pushes drained in the
                    # same batch
                    for entry in batch:
                        keys, _, fut = entry
                        try:
                            self._integrate([entry])
                            fut.set_result(keys)
                        except BaseException as one_err:
                            err = one_err
                            fut.set_exception(one_err)
            # standing-query emits ride the ingest worker: every integrated
            # batch re-emits for each live subscription (still marked busy,
            # so flush()-takers observe the emit as part of the drain).
            # _standing_refresh never raises — an emit failure parks on the
            # query's ticket for the next poll to surface
            self._notify_standing()
            with self._ingest_cv:
                self._ingest_busy = False
                self.ingest_batches += 1
                # batch fully integrated (or failed): release its rows/
                # bytes from the cap and wake any blocked producer
                self._ingest_rows = max(
                    self._ingest_rows
                    - sum(len(keys) for keys, _, _ in batch), 0)
                self._ingest_bytes = max(
                    self._ingest_bytes
                    - sum(int(it.nbytes) for _, items, _ in batch
                          for it in items), 0)
                dt = time.monotonic() - t_drain
                self._ingest_drain_ema_s += 0.2 * (dt
                                                   - self._ingest_drain_ema_s)
                if err is not None:
                    self._ingest_error = err
                self._ingest_cv.notify_all()

    def _integrate(self, batch: List[tuple]) -> None:
        """Embed + append ONE drained ingest batch: the un-cached items of
        every queued push are grouped by replica shard and embedded in
        parallel; pool_version bumps once for the whole batch."""
        todo, seen = [], set()
        for keys, items, _ in batch:
            for k, it in zip(keys, items):
                if k in seen or k in self.server.cache:
                    continue
                seen.add(k)
                todo.append((k, it))
        if todo:
            self.last_pipeline_stats = self.server._process_replicated(todo)
        with self._lock:
            # ONE _append_rows call for the whole drained batch: one
            # pool_version bump, one rows_epoch tick per touched shard
            self._append_rows(
                [k for keys, _, _ in batch for k in keys],
                [it for _, items, _ in batch for it in items])

    def flush(self, timeout: Optional[float] = None) -> None:
        """Ingest barrier: returns once every previously queued async push
        has been embedded and appended to the pool. label/query/sync-push
        call this on entry, so they linearize after pending ingests. A
        failed ingest re-raises here (once), and a DEAD worker with work
        still pending raises instead of waiting on a drain that can never
        happen (same fail-fast contract as ``PushTicket.result``).

        ``timeout`` bounds the wait: a queue not drained within it raises
        ``TimeoutError`` (like ``PushTicket.result``) with the backlog
        still intact — flush again to keep waiting; no rows are lost."""
        if self._ingest_thread is None:
            return
        deadline = (None if timeout is None
                    else time.monotonic() + float(timeout))
        with self._ingest_cv:
            while self._ingest_queue or self._ingest_busy:
                if not self._ingest_thread.is_alive():
                    raise RuntimeError(
                        "ingest worker died with pushes pending; the "
                        "session cannot drain its queue")
                wait = 0.1
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError(
                            f"flush(): ingest queue not drained within "
                            f"{timeout}s ({len(self._ingest_queue)} pushes "
                            f"still pending)")
                    wait = min(wait, remaining)
                self._ingest_cv.wait(timeout=wait)
            if self._ingest_error is not None:
                err, self._ingest_error = self._ingest_error, None
                raise RuntimeError("asynchronous ingest failed") from err

    def close(self) -> None:
        """Stop the ingest worker (drains what is already queued) and
        remove the session's spill directory, if any. Standing queries are
        cancelled FIRST, so the draining worker integrates the remaining
        pushes without emitting to a subscription whose owner is gone."""
        with self._standing_lock:
            for sq in self._standing.values():
                if sq.cancelled is None:
                    sq.cancelled = "session closed"
        with self._ingest_cv:
            self._ingest_stop = True
            self._ingest_cv.notify_all()
        if self._spill is not None:
            t = self._ingest_thread
            if t is not None:
                t.join(timeout=5.0)     # let the drain finish its appends
            shutil.rmtree(self._spill.directory, ignore_errors=True)

    # ------------------------------------------------------ label/oracle --
    def attach_oracle(self, oracle: Callable[[Sequence[str]], Sequence[int]],
                      eval_x: np.ndarray, eval_y: np.ndarray):
        """Oracle = the paper's human annotator; eval set scores rounds."""
        backend = self.server.backend
        self._oracle = oracle
        ex = backend.preprocess(np.asarray(eval_x))
        self._eval_set = (backend.features(ex), np.asarray(eval_y))

    def label(self, keys: Sequence[str], labels: Sequence[int]):
        """Labeling moves rows across the labeled/unlabeled boundary but
        changes NO pool content: it bumps only ``labels_version`` (the
        unlabeled set is a mask applied at query time), so the artifact
        columns survive every labeling round untouched."""
        self.flush()     # linearize after pending async ingests
        with self._lock:
            changed = False
            for k, y in zip(keys, labels):
                if k not in self._labels:
                    self._labels[k] = int(y)
                    self._labeled_keys.append(k)
                    changed = True
            if changed:
                self.labels_version += 1

    # --------------------------------------------------------- artifacts --
    def _recover_shard(self, si: int) -> None:
        """Worker-death recovery hook (distributed.worker ``on_death``):
        the shard's in-flight state died with its worker, so drop the
        shard's artifact columns entirely. The retried round then rebuilds
        them through ``_feats_for`` — re-embedding from raw + content keys
        in canonical batches, so the rebuilt bytes (and every later
        selection) are bit-identical to the no-failure run. The lineage
        bump ``reset()`` performs also invalidates any persisted k-center
        state derived from the lost columns."""
        with self._artifact_lock:
            self._columns[si % self.replicas].reset()
            self.shard_recoveries += 1

    def _feats_for(self, keys: Sequence[str]) -> np.ndarray:
        """Features for ``keys``, recomputing entries the EmbeddingCache
        evicted (tiny cache_bytes + no spill_dir) from the session's raw
        copies instead of feeding None into np.stack.

        Recompute runs in the CANONICAL batch shape: ``batch_size``-row
        chunks zero-padded to exactly ``batch_size`` — the same single
        shape the ingest pipeline's ``DynamicBatcher(pad_to_max=True)``
        feeds the jitted extractor. One shape + a row-local forward means
        a recomputed row reproduces the ingest-time feature bytes no
        matter how the pool was chunked when it was pushed or which
        neighbours shared the original batch."""
        cache = self.server.cache
        out: Dict[str, np.ndarray] = {}
        missing: List[str] = []
        for k in keys:
            v = cache.get(k)
            if v is None:
                missing.append(k)
            else:
                out[k] = v
        if missing:
            for k in missing:
                if k not in self._raw:
                    cache.require(k)   # no raw copy: canonical KeyError
            backend = self.server.backend
            bs = max(int(self.server.config.batch_size), 1)
            for s in range(0, len(missing), bs):
                grp = missing[s:s + bs]
                raw = np.stack([np.asarray(self._raw[k]) for k in grp])
                feats = self.server._embed_chunk(
                    raw, bs, shard_hint=(replica_of(grp[0], self.replicas)
                                         if self.replicas > 1 else 0),
                    backend=backend)
                for k, f in zip(grp, feats):
                    f = np.asarray(f)
                    cache.put(k, f)
                    out[k] = f
            self.server.count_embeds(len(missing))
        return np.stack([out[k] for k in keys])

    def _refresh_artifacts(self):
        """Bring every shard's (feats, probs) columns up to date, touched
        shards in parallel on the shard pool. Caller holds _artifact_lock.

        Per shard, the refresh is column-local and O(change):
          * rows appended since the last refresh -> gather/embed ONLY
            ``keys[feats_rows:]`` and extend the growable feats buffer in
            place (``delta_builds``; a cold column is a ``full_builds``);
          * head_version moved -> recompute probs from the cached feats
            into a fresh buffer, zero re-embeds (``probs_refreshes``);
          * rows appended at an unchanged head -> append probs for just
            the new rows (probs are row-local, so chunked computation is
            bitwise identical to the full-matrix forward).
        An untouched shard is a pure cache hit: no work, no tick.
        """
        backend = self.server.backend
        incremental = self.server.config.incremental_artifacts
        with self._lock:   # consistent (row count, epoch) per shard
            targets = [(len(c.keys), c.rows_epoch) for c in self._columns]
            head = self._head
            head_v = self.head_version
        if head is None:
            head = backend.init_head()
        # staleness is judged by the epoch stamps: a shard whose feats were
        # stamped at an older rows_epoch (rows appended since), or whose
        # probs were stamped at an older head epoch, needs a refresh
        work = [(si, rows, epoch) for si, (rows, epoch) in enumerate(targets)
                if self._columns[si].feats_epoch != epoch
                or self._columns[si].probs_head_epoch != head_v]
        if not work:
            return

        def refresh(item):
            si, rows, epoch = item
            col = self._columns[si]
            if not incremental:
                col.reset()          # debugging fallback: O(shard) rebuilds
            kind = None
            if col.feats_epoch != epoch:
                if col.feats_rows < rows:    # every epoch tick appends rows
                    kind = "full" if col.feats_rows == 0 else "delta"
                    new = self._feats_for(col.keys[col.feats_rows:rows])
                    col.feats, col.feats_rows = grow_append(
                        col.feats, col.feats_rows, new, col.spill)
                col.feats_epoch = epoch
            if col.probs_head_epoch != head_v:
                # head-only refresh: fresh buffer (pinned snapshots keep
                # their rows), computed from cached feats — zero embeds
                old = col.probs
                newp = (np.asarray(backend.probs(
                    col.feats[:col.feats_rows], head))
                    if col.feats_rows else None)
                if newp is not None and col.spill is not None:
                    newp = col.spill.adopt(newp)
                col.probs = newp
                if col.spill is not None and old is not None:
                    col.spill.release(old)
                col.probs_rows = col.feats_rows
                col.probs_head_epoch = head_v
                kind = kind or "probs"
            elif col.probs_rows < col.feats_rows:
                newp = np.asarray(backend.probs(
                    col.feats[col.probs_rows:col.feats_rows], head))
                col.probs, col.probs_rows = grow_append(
                    col.probs, col.probs_rows, newp, col.spill)
            if self._prefilter_cfg is not None:
                # centroid summary rides the same epoch discipline: rebuilt
                # only when the tail outgrows the covered prefix, caps
                # refreshed per head bump (copy-on-write — pinned queries
                # keep their (probs, caps) pair)
                col.summary = maintain_summary(
                    col.summary,
                    col.feats[:col.feats_rows] if col.feats_rows else None,
                    col.probs[:col.probs_rows] if col.probs_rows else None,
                    head_epoch=head_v, cfg=self._prefilter_cfg,
                    spill=col.spill, salt=f"{self.session_id}/{si}")
            col.builds += 1
            return kind

        kinds = replica_map(
            refresh, work,
            self.server.shard_scoped("embed", on_death=self._recover_shard,
                                     shard_of=lambda i, it: it[0]))
        self.full_builds += sum(k == "full" for k in kinds)
        self.delta_builds += sum(k == "delta" for k in kinds)
        self.probs_refreshes += sum(k == "probs" for k in kinds)
        self.artifact_builds += 1

    def _artifact_snapshot(self):
        """(feats_l, probs_l, rows_l, key->(shard, row) index) over the
        pool — per-shard immutable row-range views of the incremental
        columns (``artifact_cache: true``, refreshed under a lock so
        racing PSHEA candidates share one refresh) or a from-scratch
        O(pool) build (``artifact_cache: false``, the bit-identity
        oracle). Rows appended after the snapshot is pinned sit beyond
        ``rows_l`` and are invisible to it."""
        return self._artifact_snapshot_ex()[:4]

    def _artifact_snapshot_ex(self):
        """``_artifact_snapshot`` plus the prefilter context pinned under
        the SAME lock hold: per-shard summary refs and the probs head
        epoch the snapshot is consistent at. Summaries are copy-on-write
        (core.prefilter), so a ref pinned here stays a consistent
        (geometry, caps) pair no matter what later refreshes publish."""
        backend = self.server.backend
        if not self.server.config.artifact_cache:
            f, p, r, i = self._build_from_scratch()
            return (f, p, r, i, [None] * self.replicas,
                    [-1] * self.replicas, [0] * self.replicas)
        with self._artifact_lock:
            self._refresh_artifacts()
            feats_l = [c.feats_view(backend.feat_dim) for c in self._columns]
            probs_l = [c.probs_view(backend.num_classes)
                       for c in self._columns]
            summaries = [c.summary for c in self._columns]
            epochs = [c.probs_head_epoch for c in self._columns]
            lineages = [c.lineage for c in self._columns]
            return feats_l, probs_l, \
                [c.feats_rows for c in self._columns], self._index, \
                summaries, epochs, lineages

    def _build_from_scratch(self):
        """The O(pool) reference engine: re-gather + re-forward every shard
        on every call, no incremental state consulted — what
        ``artifact_cache: false`` runs and what the incremental columns
        must stay bit-identical to."""
        backend = self.server.backend
        with self._lock:
            shard_keys = [list(c.keys) for c in self._columns]
            head = self._head
        head = head or backend.init_head()

        def build(ks):
            if not ks:
                return (np.zeros((0, backend.feat_dim), np.float32),
                        np.zeros((0, backend.num_classes), np.float32))
            feats = self._feats_for(ks)
            return feats, backend.probs(feats, head)

        parts = replica_map(
            build, shard_keys,
            self.server.shard_scoped("embed", on_death=self._recover_shard))
        index: Dict[str, Tuple[int, int]] = {}
        for si, ks in enumerate(shard_keys):
            for li, k in enumerate(ks):
                index[k] = (si, li)
        self.artifact_builds += 1
        return ([p[0] for p in parts], [p[1] for p in parts],
                [len(ks) for ks in shard_keys], index)

    def train_and_eval(self) -> float:
        self.flush()     # linearize after pending async ingests
        keys = list(self._labeled_keys)
        if not keys:
            return 0.0
        backend = self.server.backend
        feats = self._feats_for(keys)
        labels = np.asarray([self._labels[k] for k in keys])
        with self._lock:
            self._head = backend.fit_head(feats, labels, head=None)
            self.head_version += 1
        # the spec's invalidation matrix: a retrain drops the persisted
        # min-dist vectors on every shard (feats columns are untouched, so
        # the NEXT warm query re-folds but re-embeds nothing)
        self._kstate.invalidate()
        if self._eval_set is None:  # no eval set: train-set accuracy proxy
            return backend.evaluate(feats, labels, self._head)
        return backend.evaluate(*self._eval_set, self._head)

    # ------------------------------------------------------------- query --
    def query(self, budget: int, strategy: Optional[str] = None,
              target_accuracy: Optional[float] = None, rng_seed: int = 0,
              pshea_workers: Optional[int] = None) -> dict:
        config = self.server.config
        strategy = strategy or config.strategy
        self.flush()       # linearize after pending async ingests
        with self._lock:   # consistent (pool, labels) snapshot
            unlabeled = [k for k in self._keys if k not in self._labels]
        if strategy != "auto":
            return self._query_one(unlabeled, budget, strategy, rng_seed)
        workers = (config.pshea_workers
                   if pshea_workers is None else pshea_workers)
        return self._query_auto(budget,
                                target_accuracy or config.target_accuracy,
                                workers)

    def _query_one(self, unlabeled, budget, strategy, rng_seed,
                   _capture=None) -> dict:
        if (self.replicas > 1 or self._prefilter_cfg is not None
                or self._use_kstate(strategy)):
            # the prefilter and the persisted k-center state live in the
            # sharded paths (their engines ARE the per-shard propose
            # step), so either feature routes through them even at
            # replicas=1 — the 1-shard case of the same bit-identical
            # merge
            return self._query_one_sharded(unlabeled, budget, strategy,
                                           rng_seed, _capture=_capture)
        strat = get_strategy(strategy)
        feats_l, probs_l, rows_l, index = self._artifact_snapshot()
        feats_all, probs_all, n_rows = feats_l[0], probs_l[0], rows_l[0]
        # a concurrent push_data may have appended keys after this query's
        # snapshot was pinned; score only the rows the snapshot covers
        # (the query ordered before the push)
        unlabeled = [k for k in unlabeled
                     if k in index and index[k][1] < n_rows]
        budget = min(budget, len(unlabeled))
        if budget == 0:    # fully-labeled pool: strategies need >= 1 row
            return {"keys": [], "indices": [], "strategy": strategy,
                    "cache": self.server.cache.stats()}
        rows = np.asarray([index[k][1] for k in unlabeled], np.int64)
        feats = feats_all[rows]
        probs = probs_all[rows]
        labeled_emb = None
        if self._labeled_keys:
            lab_rows = [index[k][1] for k in self._labeled_keys
                        if k in index and index[k][1] < n_rows]
            if lab_rows:
                labeled_emb = feats_all[np.asarray(lab_rows, np.int64)]
        import jax.numpy as jnp
        idx = strat.select(
            jax.random.PRNGKey(rng_seed), budget,
            probs=jnp.asarray(probs) if "probs" in strat.needs else None,
            embeddings=jnp.asarray(feats) if "embeddings" in strat.needs else None,
            labeled_embeddings=(jnp.asarray(labeled_emb)
                                if labeled_emb is not None else None))
        idx = np.asarray(idx)
        return {"keys": [unlabeled[i] for i in idx],
                "indices": idx.tolist(), "strategy": strategy,
                "cache": self.server.cache.stats()}

    def _use_kstate(self, strategy: str) -> bool:
        """Whether this query should run with the persisted k-center
        min-dist state. Requires the incremental artifact columns — their
        lineage stamps are what proves a cached vector is still an
        append-extension of the shard's feats."""
        cfg = self.server.config
        return bool(cfg.strategy_state_cache and cfg.artifact_cache
                    and strategy in _WARM_STATE_STRATEGIES)

    def _query_one_sharded(self, unlabeled, budget, strategy,
                           rng_seed, _capture=None) -> dict:
        """One strategy over the replica-sharded pool: per-shard views of
        the unlabeled rows (global order preserved inside each shard) feed
        the strategy's sharded path — selections bit-identical to
        ``replicas=1`` by construction (tests/test_sharding.py)."""
        strat = get_strategy(strategy)
        feats_l, probs_l, rows_l, index, summaries, epochs, lineages = \
            self._artifact_snapshot_ex()

        def covered(k):   # pinned-snapshot bound, per shard
            e = index.get(k)
            return e is not None and e[1] < rows_l[e[0]]

        unlabeled = [k for k in unlabeled if covered(k)]
        budget = min(budget, len(unlabeled))
        if budget == 0:
            return {"keys": [], "indices": [], "strategy": strategy,
                    "cache": self.server.cache.stats()}
        rows: List[List[int]] = [[] for _ in range(self.replicas)]
        gpos: List[List[int]] = [[] for _ in range(self.replicas)]
        for g, k in enumerate(unlabeled):
            si, li = index[k]
            rows[si].append(li)
            gpos[si].append(g)
        pf_cfg = self._prefilter_cfg
        shards = []
        for si in range(self.replicas):
            r = np.asarray(rows[si], np.int64)
            summ = summaries[si]
            # a summary older than the pinned view is fine (its tail is
            # scanned in full); one COVERING MORE rows than the view — a
            # racing refresh that rebuilt past our pin — is not usable
            if summ is not None and summ.covered > rows_l[si]:
                summ = None
            shards.append(ShardView(
                feats=feats_l[si][r] if r.size else feats_l[si][:0],
                probs=probs_l[si][r] if r.size else probs_l[si][:0],
                gidx=np.asarray(gpos[si], np.int64),
                summary=summ if pf_cfg is not None else None,
                # pool-level context: the prefilter engines and the
                # persisted-state gather both address rows by their
                # shard-local pool position (cheap views, always set)
                pool_rows=r,
                pool_feats=feats_l[si],
                probs_epoch=epochs[si]))
        labeled_emb = None
        lab: List[Tuple[int, int]] = []
        if self._labeled_keys:
            lab = [index[k] for k in self._labeled_keys if covered(k)]
            if lab:
                import jax.numpy as jnp
                labeled_emb = jnp.asarray(
                    np.stack([feats_l[si][li] for si, li in lab]))
        state = None
        if self._use_kstate(strategy) and labeled_emb is not None:
            state = self._kstate.prepare(
                feats_l=feats_l, rows_l=rows_l, lineages=lineages,
                head_version=self.head_version, locs=lab,
                centers=np.asarray(labeled_emb), capture=_capture)
        idx = np.asarray(strat.select_sharded(
            jax.random.PRNGKey(rng_seed), budget, shards,
            labeled_embeddings=labeled_emb,
            executor=self.server.shard_scoped(
                "propose", on_death=self._recover_shard),
            prefilter=pf_cfg, state=state))
        return {"keys": [unlabeled[i] for i in idx],
                "indices": idx.tolist(), "strategy": strategy,
                "cache": self.server.cache.stats()}

    def _query_auto(self, budget: int, target_accuracy: float,
                    workers: int) -> dict:
        """PSHEA (paper Alg. 1) — needs an attached oracle."""
        assert self._oracle is not None, "PSHEA needs attach_oracle(...)"
        session = self
        candidates = self.server._auto_candidates()

        class Task:
            """One independent AL line per strategy. Thread-safe: each
            strategy only touches its own labeled list + round counter, and
            the rng stream is a pure function of (strategy, round)."""

            def __init__(self):
                self.labeled: Dict[str, List[str]] = {s: [] for s in candidates}
                self.round: Dict[str, int] = {s: 0 for s in candidates}

            def initial_accuracy(self):
                return (session.train_and_eval()
                        if session._labeled_keys else 0.1)

            def select_and_label(self, strategy, round_budget):
                self.round[strategy] += 1
                pool = [k for k in session._keys
                        if k not in self.labeled[strategy]]
                res = session._query_one(
                    pool, round_budget, strategy,
                    _strategy_seed(strategy, self.round[strategy]))
                keys = res["keys"]
                self.labeled[strategy].extend(keys)
                return len(keys)

            def train_and_eval(self, strategy):
                keys = self.labeled[strategy]
                labels = session._oracle(keys)
                feats = session._feats_for(keys)
                head = session.server.backend.fit_head(
                    feats, np.asarray(labels))
                return session.server.backend.evaluate(
                    *session._eval_set, head)

        n_strats = len(candidates)
        round_budget = max(budget // (2 * n_strats), 1)
        result = run_pshea(Task(), candidates,
                           target_accuracy=target_accuracy,
                           budget_max=budget, round_budget=round_budget,
                           max_workers=workers)
        return {"strategy": result.best_strategy,
                "accuracy": result.best_accuracy,
                "stop_reason": result.stop_reason,
                "rounds": result.rounds,
                "eliminated": result.eliminated,
                "history": result.history,
                "budget_spent": result.budget_spent}

    # --------------------------------------------------- standing queries --
    def standing_register(self, budget: int, strategy: Optional[str] = None,
                          rng_seed: int = 0) -> dict:
        """Register a ``(budget, strategy)`` subscription: one initial emit
        now, then the ingest worker re-emits after every integrated batch
        and ``standing_poll`` re-emits lazily after sync mutations. Every
        emit is the exact one-shot ``query()`` selection at that moment."""
        config = self.server.config
        strategy = strategy or config.strategy
        if strategy == "auto":
            raise ValueError(
                "standing queries need a concrete strategy (the PSHEA "
                "auto agent consumes oracle labels per round)")
        get_strategy(strategy)            # unknown names fail at register
        if int(budget) < 1:
            raise ValueError("standing query budget must be >= 1")
        self.flush()
        sq = StandingQuery(uuid.uuid4().hex[:12], budget, strategy,
                           rng_seed)
        with self._standing_lock:
            self._standing[sq.qid] = sq
        self._standing_refresh(sq)
        with sq.lock:
            if sq.error is not None:
                err = sq.error
                with self._standing_lock:
                    self._standing.pop(sq.qid, None)
                raise RuntimeError(
                    "standing query initial emit failed") from err
            return {"query_id": sq.qid, "seq": sq.seq,
                    "keys": list(sq.keys or [])}

    def standing_cancel(self, query_id: str,
                        reason: str = "cancelled by client") -> None:
        """Cancel a subscription: later emits are suppressed (including
        from an ingest worker mid-drain) and polls raise."""
        with self._standing_lock:
            sq = self._standing.get(query_id)
        if sq is None:
            raise KeyError(f"unknown standing query {query_id!r}")
        with sq.lock:
            if sq.cancelled is None:
                sq.cancelled = reason

    def standing_poll(self, query_id: str, since: int = 0) -> dict:
        """Emits with ``seq > since`` plus the current cumulative
        selection. Takes the flush() barrier FIRST, so a dead ingest
        worker or a failed async push raises here ticket-style instead of
        the poll serving a stale selection; sync mutations since the last
        emit trigger a fresh emit on this thread."""
        with self._standing_lock:
            sq = self._standing.get(query_id)
        if sq is None:
            raise KeyError(f"unknown standing query {query_id!r}")
        if sq.cancelled is not None:
            raise RuntimeError(
                f"standing query {query_id} cancelled: {sq.cancelled}")
        self.flush()
        self._standing_refresh(sq)
        with sq.lock:
            if sq.error is not None:
                raise RuntimeError(
                    "standing query emit failed") from sq.error
            emits = [dict(e) for e in sq.emits if e["seq"] > int(since)]
            return {"query_id": query_id, "seq": sq.seq,
                    "keys": list(sq.keys or []), "emits": emits,
                    "pool_version": sq.pool_version,
                    "labels_version": sq.labels_version,
                    "head_version": sq.head_version}

    def _notify_standing(self) -> None:
        """Ingest-worker hook: re-emit every live subscription after an
        integrated batch. Swallows nothing it shouldn't — emit failures
        park on the query's ticket (``sq.error``), never kill the
        worker."""
        with self._standing_lock:
            sqs = [sq for sq in self._standing.values()
                   if sq.cancelled is None]
        for sq in sqs:
            self._standing_refresh(sq)

    def _standing_refresh(self, sq: StandingQuery) -> None:
        """Emit iff the session moved since ``sq``'s last emit. Never
        raises: failures park on ``sq.error`` for the next poll."""
        if sq.cancelled is not None:
            return
        with sq.lock:
            if sq.cancelled is not None:
                return
            try:
                self._standing_emit_locked(sq)
                sq.error = None
            except BaseException as e:
                sq.error = e

    def _standing_emit_locked(self, sq: StandingQuery) -> None:
        """One emit attempt; caller holds ``sq.lock``. Replays the stored
        selection against just the delta rows when provably unchanged,
        otherwise runs the full (bit-identical to ``query()``) path."""
        with self._lock:
            unlabeled = [k for k in self._keys if k not in self._labels]
            pv, lv, hv = (self.pool_version, self.labels_version,
                          self.head_version)
        if sq.keys is not None and (pv, lv, hv) == (
                sq.pool_version, sq.labels_version, sq.head_version):
            return                           # nothing moved: stay quiet
        keys = self._standing_replay(sq, unlabeled, lv, hv)
        if keys is not None:
            mode, values = "replay", sq.values
        else:
            cap: List[float] = []
            res = self._query_one(unlabeled, sq.budget, sq.strategy,
                                  sq.rng_seed, _capture=cap)
            keys, mode = res["keys"], "full"
            values = (cap if len(cap) == sq.budget
                      and len(keys) == sq.budget else None)
        prev = sq.keys or []
        prev_set, new_set = set(prev), set(keys)
        sq.seq += 1
        sq.emits.append({
            "seq": sq.seq, "mode": mode,
            "pool_version": pv, "labels_version": lv, "head_version": hv,
            "keys": list(keys),
            "added": [k for k in keys if k not in prev_set],
            "removed": [k for k in prev if k not in new_set]})
        sq.keys = list(keys)
        sq.values = values
        sq.n_unlabeled = len(unlabeled)
        sq.pool_version, sq.labels_version, sq.head_version = pv, lv, hv
        with self._standing_lock:
            self.standing_emits += 1
            if mode == "replay":
                self.standing_replay_emits += 1
            else:
                self.standing_full_emits += 1

    def _standing_replay(self, sq: StandingQuery, unlabeled, lv,
                         hv) -> Optional[List[str]]:
        """O(delta) emit: prove the stored selection is unchanged over the
        grown pool by streaming ONLY the delta rows, or return None for an
        honest full recompute.

        Eligibility: unweighted warm-started coreset with a full-budget
        previous emit and unchanged labels/head — then the previous
        unlabeled list is an exact prefix of the current one (append-only
        keys), every old row's min-dist trajectory is unchanged, and the
        stored per-slot winner scores remain the max over all old rows.
        A delta row displaces slot j iff its score after folding centers
        0..j-1 STRICTLY beats the stored winner score (ties lose on the
        higher global index every appended row has), so ``budget`` fused
        rounds over the delta rows decide the whole emit."""
        cfg = self.server.config
        if not (cfg.standing_replay and cfg.strategy_state_cache
                and cfg.artifact_cache):
            return None
        if sq.strategy != "coreset" or self._prefilter_cfg is not None:
            return None
        if sq.keys is None or sq.values is None:
            return None
        if (sq.labels_version, sq.head_version) != (lv, hv):
            return None
        if len(sq.keys) != sq.budget or len(sq.values) != sq.budget:
            return None
        n_prev = sq.n_unlabeled
        if len(unlabeled) < n_prev:
            return None
        delta = unlabeled[n_prev:]
        if not delta:
            return list(sq.keys)
        feats_l, probs_l, rows_l, index, summaries, epochs, lineages = \
            self._artifact_snapshot_ex()

        def covered(k):
            e = index.get(k)
            return e is not None and e[1] < rows_l[e[0]]

        if not all(covered(k) for k in delta):
            return None                      # racing snapshot: full path
        lab = [index[k] for k in self._labeled_keys if covered(k)]
        if not lab:
            return None
        centers = np.stack([feats_l[si][li] for si, li in lab])
        state = self._kstate.prepare(
            feats_l=feats_l, rows_l=rows_l, lineages=lineages,
            head_version=self.head_version, locs=lab, centers=centers)
        if state is None:
            return None
        sel_centers = []
        for k in sq.keys:
            if not covered(k):
                return None
            si, li = index[k]
            sel_centers.append(feats_l[si][li])
        drows = [index[k] for k in delta]
        import jax.numpy as jnp
        from repro.kernels.pairwise import ops
        # delta rows' persisted min-dists vs the labeled centers + their
        # embeddings — O(delta) gathers, no pool stream
        mj = jnp.asarray(np.asarray(
            [state.minds[si][li] for si, li in drows], np.float32))
        ej = jnp.asarray(np.stack([feats_l[si][li] for si, li in drows]),
                         jnp.float32)
        no_mask = jnp.full((1,), -1, jnp.int32)
        best = float(jnp.max(ops.masked_weighted_score(mj)))
        for j in range(sq.budget):
            if best > sq.values[j]:
                return None                  # slot j displaced: diverge
            if j + 1 < sq.budget:
                mj, _, lv_ = ops.greedy_round(
                    ej, mj, jnp.asarray(sel_centers[j],
                                        jnp.float32)[None, :], no_mask)
                best = float(lv_)
        return list(sq.keys)

    # -------------------------------------------------------------- misc --
    def stats(self) -> dict:
        with self._ingest_cv:
            pending = len(self._ingest_queue) + (1 if self._ingest_busy
                                                 else 0)
            ingest = {
                "pending": pending,
                "rows": self._ingest_rows,
                "bytes": self._ingest_bytes,
                "rows_hw": self._ingest_rows_hw,
                "bytes_hw": self._ingest_bytes_hw,
                "depth_hw": self._ingest_depth_hw,
                "shed": self._ingest_shed,
                "policy": self.server.config.ingest_policy,
                "max_rows": self.server.config.ingest_max_rows,
                "max_bytes": self.server.config.ingest_max_bytes,
            }
        return {"pool": len(self._keys), "labeled": len(self._labeled_keys),
                "pool_version": self.pool_version,
                "head_version": self.head_version,
                "labels_version": self.labels_version,
                "artifact_builds": self.artifact_builds,
                # incremental-artifact observability: build-kind tallies +
                # the per-shard epoch/row state a delta build is judged by
                "artifacts": {
                    "builds": self.artifact_builds,
                    "full_builds": self.full_builds,
                    "delta_builds": self.delta_builds,
                    "probs_refreshes": self.probs_refreshes,
                    "shard_builds": [c.builds for c in self._columns],
                    "rows_epoch": [c.rows_epoch for c in self._columns],
                    "feats_rows": [c.feats_rows for c in self._columns],
                    "head_epoch": self.head_version,
                    # shard-spill counters (0s when shard_ram_bytes == 0)
                    "spill_events": (self._spill.spill_events
                                     if self._spill else 0),
                    "spilled_bytes": (self._spill.spilled_bytes
                                      if self._spill else 0),
                    # centroid-prefilter summaries per shard (None = that
                    # shard full-scans: below min_rows or prefilter off)
                    "summary_builds": [
                        (c.summary.builds if c.summary is not None else 0)
                        for c in self._columns],
                    "summary_covered": [
                        (c.summary.covered if c.summary is not None else 0)
                        for c in self._columns],
                },
                "replicas": self.replicas,
                # worker deaths recovered by resetting this session's shard
                # columns (re-embed from raw + content keys on retry)
                "worker_recoveries": self.shard_recoveries,
                "ingest_pending": pending,
                "ingest_batches": self.ingest_batches,
                # bounded-ingest observability: outstanding rows/bytes,
                # high-waters, and the shed counter (policy == "shed")
                "ingest": ingest,
                # persisted k-center min-dist state (KCenterStateCache):
                # rebuilds = from-scratch folds, extends = O(delta-row)
                # appends, center_extends = O(new-center) folds over old
                # rows, invalidations = drops (retrain/lineage/center-
                # prefix breaks), rows_reused vs rows_extended = the
                # incremental win
                "strategy_state": {
                    "enabled": self.server.config.strategy_state_cache,
                    **self._kstate.stats()},
                "standing_queries": self._standing_stats(),
                "pipeline": self.last_pipeline_stats}

    def _standing_stats(self) -> dict:
        with self._standing_lock:
            live = sum(1 for sq in self._standing.values()
                       if sq.cancelled is None)
            return {"registered": len(self._standing), "live": live,
                    "emits": self.standing_emits,
                    "replay_emits": self.standing_replay_emits,
                    "full_emits": self.standing_full_emits}


class ALServer:
    """Hosts many ``ALSession`` tenants over one backend + embedding cache.

    All per-pool methods take ``session=`` (a session id from
    ``create_session``); omitted, they address the always-present default
    session — the original single-tenant API keeps working verbatim."""

    def __init__(self, config: Optional[ALServiceConfig] = None,
                 config_path: Optional[str] = None,
                 backend: Optional[FeatureBackend] = None,
                 fetch_fn: Optional[Callable] = None,
                 fetch_latency_s: float = 0.0,
                 failure_injector: Optional[PhaseFailureInjector] = None):
        if config is None:
            config = (ALServiceConfig.from_yaml(config_path)
                      if config_path else ALServiceConfig())
        self.config = config
        # process-backed embed jobs rebuild the backend from config in the
        # worker process; only valid when OUR backend came from the same
        # config (a hand-constructed backend object can't be reproduced)
        self._backend_from_config = backend is None
        self.backend = (backend if backend is not None
                        else make_backend(config.model_name, config=config))
        self.cache = EmbeddingCache(config.cache_bytes,
                                    config.cache_spill_dir)
        self.fetch_fn = fetch_fn or (lambda x: x)
        self.fetch_latency_s = fetch_latency_s
        self.failure_injector = failure_injector
        self._sessions: Dict[str, ALSession] = {}
        self._sessions_lock = threading.Lock()
        self._shard_runtime: Optional[ShardWorkerPool] = None
        self._shard_pool_lock = threading.Lock()
        # op accounting: pool rows run through the feature extractor
        # (pipeline ingest + evicted-entry recompute; batcher padding rows
        # excluded). The incremental-artifact contract is stated in these
        # units: push B rows == B embeds, train_and_eval == 0, query after
        # a push == 0 (delta rows come out of the EmbeddingCache).
        self.embed_rows = 0
        self.embed_calls = 0
        self._embed_lock = threading.Lock()
        # serve_tcp points this at RPCServer.stats so stats() can report
        # admission/fairness counters; None when served in-process
        self._transport_stats: Optional[Callable[[], dict]] = None
        self.create_session(DEFAULT_SESSION)

    def count_embeds(self, rows: int) -> None:
        with self._embed_lock:
            self.embed_rows += int(rows)
            self.embed_calls += 1

    def shard_runtime(self) -> Optional[ShardWorkerPool]:
        """The shard-worker runtime (distributed.worker): one supervised
        lane per replica shard — straggler-timed, failure-injectable,
        restartable, device-pinned on multi-device hosts. Lazy; None at
        replicas=1 (the serial path needs no workers)."""
        if self.config.replicas <= 1:
            return None
        with self._shard_pool_lock:
            if self._shard_runtime is None:
                cfg = self.config
                self._shard_runtime = ShardWorkerPool(
                    cfg.replicas, kind=cfg.worker_backend,
                    timeout_s=cfg.worker_timeout_s,
                    max_retries=cfg.worker_retries,
                    backoff_s=cfg.worker_backoff_s,
                    injector=self.failure_injector)
            return self._shard_runtime

    def shard_executor(self):
        """Back-compat seam: the worker pool duck-types ``executor.map``,
        so callers that predate the runtime keep working (default phase,
        no recovery hook)."""
        return self.shard_runtime()

    def shard_scoped(self, phase: str, on_death: Optional[Callable] = None,
                     shard_of: Optional[Callable] = None):
        """Phase-scoped executor facade for ``replica_map`` fan-outs: a
        worker death during ``phase`` triggers ``on_death(shard)`` (e.g.
        the session's column-reset recovery) before the bounded retry.
        None at replicas=1."""
        rt = self.shard_runtime()
        if rt is None:
            return None
        return rt.scoped(phase, on_death=on_death, shard_of=shard_of)

    # ---------------------------------------------------------- sessions --
    def create_session(self, session_id: Optional[str] = None) -> str:
        sid = session_id or uuid.uuid4().hex[:16]
        with self._sessions_lock:
            if sid in self._sessions:
                raise ValueError(f"session {sid!r} already exists")
            self._sessions[sid] = ALSession(self, sid)
        return sid

    def session(self, session_id: Optional[str] = None) -> ALSession:
        sid = session_id or DEFAULT_SESSION
        with self._sessions_lock:
            try:
                return self._sessions[sid]
            except KeyError:
                raise KeyError(f"unknown session {sid!r}; call "
                               f"create_session() first") from None

    def close_session(self, session_id: str) -> None:
        if session_id == DEFAULT_SESSION:
            raise ValueError("the default session cannot be closed")
        with self._sessions_lock:
            sess = self._sessions.pop(session_id, None)
        if sess is not None:
            sess.close()     # stop its ingest worker

    def session_ids(self) -> List[str]:
        with self._sessions_lock:
            return list(self._sessions)

    # -------------------------------------------- shared feature pipeline --
    def _process(self, todo, *, pipelined: bool, chunk: int = 64):
        bs = max(self.config.batch_size, 1)
        # pad_to_max: every inference batch is padded to the one canonical
        # (bs, ...) shape, so with a row-local backend forward each row's
        # features are bitwise independent of how pushes were chunked or
        # interleaved (the batch-insensitivity contract standing queries
        # and the content-addressed cache rely on)
        batcher = DynamicBatcher(self._infer_batch, max_batch=bs,
                                 pad_to_max=True)

        def fetch(chunk_items):
            if self.fetch_latency_s:
                time.sleep(self.fetch_latency_s)
            return [(k, self.fetch_fn(v)) for k, v in chunk_items]

        def preprocess(chunk_items):
            ks = [k for k, _ in chunk_items]
            raw = np.stack([np.asarray(v) for _, v in chunk_items])
            return ks, self.backend.preprocess(raw)

        def infer(args):
            ks, batch = args
            feats = batcher.score(list(batch))
            return list(zip(ks, feats))

        stages = [Stage("fetch", fetch), Stage("preprocess", preprocess),
                  Stage("infer", infer)]
        pipe = StagePipeline(stages)
        chunks = [todo[i:i + chunk] for i in range(0, len(todo), chunk)]
        runner = pipe.run if pipelined else pipe.run_serial
        try:
            for out in runner(chunks):
                for k, f in out:
                    self.cache.put(k, np.asarray(f))
        finally:
            batcher.close()
        return pipe.stats()

    def _embed_chunk(self, raw: np.ndarray, bs: int, *, shard_hint: int,
                     backend: FeatureBackend) -> np.ndarray:
        """One canonical embed chunk (preprocess, zero-pad to the one
        ``bs``-row shape, feature forward). On a process-backed worker
        runtime the chunk ships to the shard's paired worker process as
        the registered ``embed_batch`` job — the backend there is rebuilt
        from the SAME config, so the bytes match the in-process path bit
        for bit; any other configuration computes inline."""
        rt = self.shard_runtime()
        if (rt is not None and rt.kind == "process"
                and self._backend_from_config):
            feats = rt.run_job(shard_hint, "embed_batch", {
                "config": dataclasses.asdict(self.config),
                "raw": raw, "bs": bs})
            return np.asarray(feats)
        x = np.asarray(backend.preprocess(raw))
        n = x.shape[0]
        if n < bs:           # zero-pad to the one canonical shape
            x = np.concatenate(
                [x, np.zeros((bs - n,) + x.shape[1:], x.dtype)])
        return np.asarray(backend.features(x))[:n]

    def _infer_batch(self, stacked: np.ndarray, n_valid: int):
        feats = self.backend.features(stacked)
        self.count_embeds(n_valid)
        return [feats[i] for i in range(n_valid)]

    def _process_replicated(self, todo):
        """Embed a drained ingest batch: group items by replica shard and
        run the stage pipeline per shard in parallel (each group rides its
        own DynamicBatcher). Falls back to one pipeline at replicas=1."""
        replicas = max(self.config.replicas, 1)
        if replicas == 1:
            return self._process(todo, pipelined=True)
        groups = [[] for _ in range(replicas)]
        for k, it in todo:
            groups[replica_of(k, replicas)].append((k, it))
        groups = [g for g in groups if g]
        if len(groups) == 1:
            return self._process(groups[0], pipelined=True)
        # ingest-phase fan-out: a worker killed mid-drain restarts and the
        # group's pipeline retries — cache puts are content-addressed and
        # idempotent, and the rows append only after every group lands, so
        # a recovered kill loses nothing
        executor = self.shard_scoped("ingest")
        per_group = list(executor.map(
            lambda g: self._process(g, pipelined=True), groups))
        # keep the single-pipeline stats shape (one dict per stage): sum
        # each stage's counters across the parallel per-shard pipelines
        merged = [dict(stage) for stage in per_group[0]]
        for stats in per_group[1:]:
            for agg, stage in zip(merged, stats):
                for field in ("items", "busy_s", "wait_s"):
                    agg[field] += stage[field]
        return merged

    def _auto_candidates(self) -> List[str]:
        """The PSHEA agent's strategy registry: the paper's 7, plus the
        weighted fused-round hybrids when configured ("hybrid")."""
        mode = self.config.auto_candidates
        if mode == "hybrid":
            return PAPER_SEVEN + HYBRIDS
        if mode != "paper":
            # a typo must not silently degrade to the default set
            raise ValueError(f"auto_candidates must be 'paper' or 'hybrid', "
                             f"got {mode!r}")
        return list(PAPER_SEVEN)

    # --------------------------------------- single-tenant facade (compat) --
    def push_data(self, items: Sequence[np.ndarray], pipelined: bool = True,
                  session: Optional[str] = None,
                  asynchronous: bool = False):
        return self.session(session).push_data(items, pipelined=pipelined,
                                               asynchronous=asynchronous)

    def flush(self, session: Optional[str] = None,
              timeout: Optional[float] = None) -> None:
        return self.session(session).flush(timeout=timeout)

    def attach_oracle(self, oracle: Callable[[Sequence[str]], Sequence[int]],
                      eval_x: np.ndarray, eval_y: np.ndarray,
                      session: Optional[str] = None):
        return self.session(session).attach_oracle(oracle, eval_x, eval_y)

    def label(self, keys: Sequence[str], labels: Sequence[int],
              session: Optional[str] = None):
        return self.session(session).label(keys, labels)

    def train_and_eval(self, session: Optional[str] = None) -> float:
        return self.session(session).train_and_eval()

    def query(self, budget: int, strategy: Optional[str] = None,
              target_accuracy: Optional[float] = None, rng_seed: int = 0,
              session: Optional[str] = None,
              pshea_workers: Optional[int] = None) -> dict:
        return self.session(session).query(budget, strategy, target_accuracy,
                                           rng_seed, pshea_workers)

    def standing_register(self, budget: int, strategy: Optional[str] = None,
                          rng_seed: int = 0,
                          session: Optional[str] = None) -> dict:
        return self.session(session).standing_register(
            budget, strategy, rng_seed)

    def standing_cancel(self, query_id: str,
                        reason: str = "cancelled by client",
                        session: Optional[str] = None) -> None:
        return self.session(session).standing_cancel(query_id, reason)

    def standing_poll(self, query_id: str, since: int = 0,
                      session: Optional[str] = None) -> dict:
        return self.session(session).standing_poll(query_id, since)

    @property
    def last_pipeline_stats(self):
        return self.session().last_pipeline_stats

    def stats(self, session: Optional[str] = None) -> dict:
        s = self.session(session).stats()
        s["cache"] = self.cache.stats()
        s["embeds"] = {"rows": self.embed_rows, "calls": self.embed_calls}
        s["sessions"] = len(self.session_ids())
        rt = self._shard_runtime       # no lazy spin-up just for stats
        s["workers"] = (rt.stats() if rt is not None else {
            "backend": "inline", "lanes": 0, "tasks": 0, "restarts": 0,
            "straggler_events": 0})
        # transport admission/fairness counters (serve_tcp wires this;
        # absent/in-process -> a disabled placeholder, same shape)
        ts = self._transport_stats
        s["admission"] = (ts() if ts is not None else {"enabled": False})
        return s
