"""Scorer backends for the AL service.

A backend = frozen feature extractor + trainable linear head (the paper's
'fine-tune ResNet-18's last layer' protocol), exposing exactly the artifacts
the strategy zoo needs: probs + embeddings.

Every backend obeys the batch-insensitivity contract the content-addressed
EmbeddingCache depends on: ``preprocess`` makes per-sample decisions only
(never whole-batch statistics) and ``features`` is row-local, so a sample's
feature bytes are identical no matter which neighbours shared its batch or
how the pool was chunked at push time. TransformerBackend extends the same
contract to the sequence axis: its blockwise-chunked forward
(models/blockwise.py) produces bit-identical features at any block size.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import blockwise as blockwise_lib
from repro.models import resnet as resnet_lib


@dataclasses.dataclass
class HeadState:
    w: jax.Array
    b: jax.Array


class FeatureBackend:
    """Shared logic: fit/eval a softmax head on frozen features."""

    num_classes: int
    feat_dim: int

    def preprocess(self, raw: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def features(self, batch: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    # -- head -------------------------------------------------------------
    def init_head(self, rng=None) -> HeadState:
        # `rng or PRNGKey(0)` would bool() an explicit uint32[2] key and
        # raise "truth value of an array is ambiguous"
        if rng is None:
            rng = jax.random.PRNGKey(0)
        w = jax.random.normal(rng, (self.feat_dim, self.num_classes),
                              jnp.float32) * 0.01
        return HeadState(w=w, b=jnp.zeros((self.num_classes,), jnp.float32))

    def fit_head(self, feats: np.ndarray, labels: np.ndarray,
                 steps: int = 200, lr: float = 0.5,
                 head: Optional[HeadState] = None) -> HeadState:
        x = jnp.asarray(feats, jnp.float32)
        y = jnp.asarray(labels, jnp.int32)
        if head is None:
            head = self.init_head()

        def loss_fn(p):
            logits = x @ p["w"] + p["b"]
            lp = jax.nn.log_softmax(logits)
            nll = -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))
            return nll + 1e-4 * jnp.sum(p["w"] ** 2)

        @jax.jit
        def step(p, _):
            g = jax.grad(loss_fn)(p)
            return jax.tree.map(lambda a, b: a - lr * b, p, g), None

        p = {"w": head.w, "b": head.b}
        p, _ = jax.lax.scan(step, p, None, length=steps)
        return HeadState(w=p["w"], b=p["b"])

    def probs(self, feats: np.ndarray, head: HeadState) -> np.ndarray:
        logits = jnp.asarray(feats, jnp.float32) @ head.w + head.b
        return np.asarray(jax.nn.softmax(logits, axis=-1))

    def evaluate(self, feats: np.ndarray, labels: np.ndarray,
                 head: HeadState) -> float:
        p = self.probs(feats, head)
        return float(np.mean(p.argmax(-1) == np.asarray(labels)))


class ResNetBackend(FeatureBackend):
    """Paper-faithful image scorer (resnet-18 or the tiny CPU variant)."""

    def __init__(self, cfg: Optional[resnet_lib.ResNetConfig] = None,
                 rng=None, num_classes: int = 10):
        self.cfg = cfg or resnet_lib.tiny_config(num_classes)
        self.num_classes = self.cfg.num_classes
        self.feat_dim = self.cfg.widths[-1]
        if rng is None:
            rng = jax.random.PRNGKey(42)
        self.params = resnet_lib.init_resnet(self.cfg, rng)
        self._feat = jax.jit(
            lambda x: resnet_lib.resnet_features(self.params, self.cfg, x))

    def preprocess(self, raw: np.ndarray) -> np.ndarray:
        x = np.asarray(raw, np.float32)
        # uint8-range detection is PER SAMPLE: a whole-batch x.max() would
        # rescale a [0,1] sample differently depending on its batchmates,
        # breaking the content-addressed cache (same bytes, different
        # features). Each sample's scale depends on that sample alone.
        axes = tuple(range(1, x.ndim))
        mx = x.max(axis=axes, keepdims=True) if axes else x
        return np.where(mx > 1.5, x / 255.0, x)

    def features(self, batch: np.ndarray) -> np.ndarray:
        return np.asarray(self._feat(jnp.asarray(batch)))


class MLPBackend(FeatureBackend):
    """Cheap random-projection feature backend for tests/property checks."""

    def __init__(self, in_dim: int, feat_dim: int = 64, num_classes: int = 10,
                 rng=None):
        if rng is None:
            rng = jax.random.PRNGKey(7)
        k1, k2 = jax.random.split(rng)
        self.in_dim = in_dim
        self.w1 = jax.random.normal(k1, (in_dim, 128)) / np.sqrt(in_dim)
        self.w2 = jax.random.normal(k2, (128, feat_dim)) / np.sqrt(128)
        self.num_classes = num_classes
        self.feat_dim = feat_dim
        self._feat = jax.jit(
            lambda x: jnp.tanh(jnp.tanh(x @ self.w1) @ self.w2))

    def preprocess(self, raw: np.ndarray) -> np.ndarray:
        x = np.asarray(raw, np.float32)
        if x.ndim < 2:
            raise ValueError(
                f"MLPBackend.preprocess expects a batch of samples "
                f"(N, features...); got shape {x.shape} — a 1-D payload "
                f"has no batch axis to flatten over")
        x = x.reshape(x.shape[0], -1)
        if x.shape[1] != self.in_dim:
            raise ValueError(
                f"MLPBackend.preprocess: sample flattens to {x.shape[1]} "
                f"features, backend was built with in_dim={self.in_dim}")
        return x

    def features(self, batch: np.ndarray) -> np.ndarray:
        return np.asarray(self._feat(jnp.asarray(batch, jnp.float32)))


class TransformerBackend(FeatureBackend):
    """Text/audio scorer: frozen blockwise-chunked transformer encoder.

    The forward (models/blockwise.py) processes the sequence in fixed-size
    blocks through the standard transformer layers — flash-attention Pallas
    kernel on TPU, chunked online-softmax elsewhere, remat per block — so
    peak activation memory is flat in sequence length, and the block size
    is bitwise-invisible in the feature bytes (chunked == unchunked at any
    ``block_size``).

    ``modality="text"``: raw items are int token rows, -1 = right-padding;
    ``modality="audio"``: raw items are (frames, input_dim) float frames.
    ``preprocess`` pads/truncates every sample to ``seq_len`` per-sample
    (no cross-sample statistics), giving the DynamicBatcher one canonical
    item shape. ``kv_chunk`` is clamped to ``seq_len`` so the online-softmax
    KV grid never varies with block padding (the bitwise contract).
    """

    def __init__(self, cfg: Optional[ArchConfig] = None, rng=None,
                 num_classes: int = 10, block_size: int = 64,
                 seq_len: int = 128, pooling: str = "mean",
                 modality: str = "text", input_dim: int = 0,
                 kv_chunk: int = 128, attention_impl: Optional[str] = None):
        if modality not in ("text", "audio"):
            raise ValueError(f"unknown modality {modality!r}")
        if pooling not in ("mean", "last"):
            raise ValueError(f"unknown pooling {pooling!r}")
        if modality == "audio" and not input_dim:
            raise ValueError("audio modality needs input_dim (frame features)")
        self.cfg = cfg or blockwise_lib.tiny_encoder_config()
        self.num_classes = num_classes
        self.feat_dim = self.cfg.d_model
        self.block_size = max(1, int(block_size))
        self.seq_len = max(1, int(seq_len))
        self.pooling = pooling
        self.modality = modality
        self.input_dim = int(input_dim)
        self.kv_chunk = max(1, min(int(kv_chunk), self.seq_len))
        self.impl = attention_impl or self.cfg.attention_impl
        if rng is None:
            rng = jax.random.PRNGKey(11)
        self.params = blockwise_lib.init_encoder(
            self.cfg, rng, self.input_dim if modality == "audio" else None)

        def forward(batch):
            if self.modality == "text":
                x = blockwise_lib.embed_tokens(self.cfg, self.params, batch)
                mask = batch >= 0
            else:
                x = blockwise_lib.embed_frames(self.params, batch)
                mask = jnp.ones(batch.shape[:2], bool)
            h = blockwise_lib.blockwise_encode(
                self.cfg, self.params, x, block=self.block_size,
                kv_chunk=self.kv_chunk, impl=self.impl)
            return blockwise_lib.pool_hidden(h, mask, self.pooling)

        self._feat = jax.jit(forward)

    def preprocess(self, raw: np.ndarray) -> np.ndarray:
        x = np.asarray(raw)
        if self.modality == "text":
            if x.ndim != 2:
                raise ValueError(
                    f"text preprocess expects (N, tokens) int rows; got "
                    f"shape {x.shape}")
            if not np.issubdtype(x.dtype, np.integer):
                raise ValueError(
                    f"text preprocess expects integer tokens; got {x.dtype}")
            if x.size and int(x.max()) >= self.cfg.vocab:
                raise ValueError(
                    f"token id {int(x.max())} out of range for vocab "
                    f"{self.cfg.vocab}")
            out = np.full((x.shape[0], self.seq_len), -1, np.int32)
            L = min(x.shape[1], self.seq_len)
            out[:, :L] = x[:, :L]
            return out
        if x.ndim != 3 or x.shape[-1] != self.input_dim:
            raise ValueError(
                f"audio preprocess expects (N, frames, {self.input_dim}) "
                f"float frames; got shape {x.shape}")
        out = np.zeros((x.shape[0], self.seq_len, self.input_dim), np.float32)
        L = min(x.shape[1], self.seq_len)
        out[:, :L] = x[:, :L]
        return out

    def features(self, batch: np.ndarray) -> np.ndarray:
        return np.asarray(self._feat(jnp.asarray(batch)))

    def activation_accounting(self, batch: int,
                              seq_len: Optional[int] = None) -> dict:
        return blockwise_lib.activation_accounting(
            self.cfg, batch, seq_len or self.seq_len, self.block_size,
            self.kv_chunk)


BACKENDS = {
    "resnet18": lambda **kw: ResNetBackend(resnet_lib.resnet18_config(), **kw),
    "synthetic_cnn": lambda **kw: ResNetBackend(**kw),
    "transformer": lambda **kw: TransformerBackend(**kw),
}


def make_backend(name: str, config=None, **kw) -> FeatureBackend:
    """Build a registered backend; ``config`` (ALServiceConfig) supplies
    the transformer knobs (block/seq-len/pooling/modality) when given."""
    if name not in BACKENDS:
        raise KeyError(f"unknown backend {name!r}")
    if config is not None and name == "transformer":
        kw.setdefault("block_size", config.model_block_size)
        kw.setdefault("seq_len", config.model_seq_len)
        kw.setdefault("pooling", config.model_pooling)
        kw.setdefault("modality", config.model_modality)
        kw.setdefault("input_dim", config.model_input_dim)
    return BACKENDS[name](**kw)
