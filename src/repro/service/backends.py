"""Scorer backends for the AL service.

A backend = frozen feature extractor + trainable linear head (the paper's
'fine-tune ResNet-18's last layer' protocol), exposing exactly the artifacts
the strategy zoo needs: probs + embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import resnet as resnet_lib


@dataclasses.dataclass
class HeadState:
    w: jax.Array
    b: jax.Array


class FeatureBackend:
    """Shared logic: fit/eval a softmax head on frozen features."""

    num_classes: int
    feat_dim: int

    def preprocess(self, raw: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def features(self, batch: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    # -- head -------------------------------------------------------------
    def init_head(self, rng=None) -> HeadState:
        rng = rng or jax.random.PRNGKey(0)
        w = jax.random.normal(rng, (self.feat_dim, self.num_classes),
                              jnp.float32) * 0.01
        return HeadState(w=w, b=jnp.zeros((self.num_classes,), jnp.float32))

    def fit_head(self, feats: np.ndarray, labels: np.ndarray,
                 steps: int = 200, lr: float = 0.5,
                 head: Optional[HeadState] = None) -> HeadState:
        x = jnp.asarray(feats, jnp.float32)
        y = jnp.asarray(labels, jnp.int32)
        head = head or self.init_head()

        def loss_fn(p):
            logits = x @ p["w"] + p["b"]
            lp = jax.nn.log_softmax(logits)
            nll = -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))
            return nll + 1e-4 * jnp.sum(p["w"] ** 2)

        @jax.jit
        def step(p, _):
            g = jax.grad(loss_fn)(p)
            return jax.tree.map(lambda a, b: a - lr * b, p, g), None

        p = {"w": head.w, "b": head.b}
        p, _ = jax.lax.scan(step, p, None, length=steps)
        return HeadState(w=p["w"], b=p["b"])

    def probs(self, feats: np.ndarray, head: HeadState) -> np.ndarray:
        logits = jnp.asarray(feats, jnp.float32) @ head.w + head.b
        return np.asarray(jax.nn.softmax(logits, axis=-1))

    def evaluate(self, feats: np.ndarray, labels: np.ndarray,
                 head: HeadState) -> float:
        p = self.probs(feats, head)
        return float(np.mean(p.argmax(-1) == np.asarray(labels)))


class ResNetBackend(FeatureBackend):
    """Paper-faithful image scorer (resnet-18 or the tiny CPU variant)."""

    def __init__(self, cfg: Optional[resnet_lib.ResNetConfig] = None,
                 rng=None, num_classes: int = 10):
        self.cfg = cfg or resnet_lib.tiny_config(num_classes)
        self.num_classes = self.cfg.num_classes
        self.feat_dim = self.cfg.widths[-1]
        self.params = resnet_lib.init_resnet(
            self.cfg, rng or jax.random.PRNGKey(42))
        self._feat = jax.jit(
            lambda x: resnet_lib.resnet_features(self.params, self.cfg, x))

    def preprocess(self, raw: np.ndarray) -> np.ndarray:
        x = np.asarray(raw, np.float32)
        if x.max() > 1.5:
            x = x / 255.0
        return x

    def features(self, batch: np.ndarray) -> np.ndarray:
        return np.asarray(self._feat(jnp.asarray(batch)))


class MLPBackend(FeatureBackend):
    """Cheap random-projection feature backend for tests/property checks."""

    def __init__(self, in_dim: int, feat_dim: int = 64, num_classes: int = 10,
                 rng=None):
        rng = rng or jax.random.PRNGKey(7)
        k1, k2 = jax.random.split(rng)
        self.w1 = jax.random.normal(k1, (in_dim, 128)) / np.sqrt(in_dim)
        self.w2 = jax.random.normal(k2, (128, feat_dim)) / np.sqrt(128)
        self.num_classes = num_classes
        self.feat_dim = feat_dim
        self._feat = jax.jit(
            lambda x: jnp.tanh(jnp.tanh(x @ self.w1) @ self.w2))

    def preprocess(self, raw: np.ndarray) -> np.ndarray:
        return np.asarray(raw, np.float32).reshape(raw.shape[0], -1) \
            if raw.ndim > 2 else np.asarray(raw, np.float32)

    def features(self, batch: np.ndarray) -> np.ndarray:
        return np.asarray(self._feat(jnp.asarray(batch, jnp.float32)))


BACKENDS = {
    "resnet18": lambda **kw: ResNetBackend(resnet_lib.resnet18_config(), **kw),
    "synthetic_cnn": lambda **kw: ResNetBackend(**kw),
}


def make_backend(name: str, **kw) -> FeatureBackend:
    if name not in BACKENDS:
        raise KeyError(f"unknown backend {name!r}")
    return BACKENDS[name](**kw)
