"""Typed service errors shared by transport, server, and client.

These are the wire-visible failure modes of the overload-safe serving
layer. They live in their own leaf module so ``transport`` (which must
not import the server) and ``server``/``client`` can all raise and catch
the same types without an import cycle.

Over TCP each maps to a structured error ``code`` in the response frame
(``overloaded`` / ``deadline`` / ``timeout``) and is re-raised as the
same type client-side, so a caller's ``except ServerOverloaded`` works
identically in-process and across the wire.
"""
from __future__ import annotations


class ServerOverloaded(RuntimeError):
    """The request was REJECTED before any work ran — admission control
    (inflight bound / per-tenant token bucket) or a full ingest queue
    shed it. Carries ``retry_after_s``, the server's estimate of when
    capacity frees up.

    By construction the rejected op never executed, so retrying it is
    always safe — this is the one error ``ALClient``'s bounded
    retry-with-jitter acts on. A ``ConnectionError`` (poisoned
    connection) is NOT retried: the op may have executed server-side.
    """

    def __init__(self, retry_after_s: float = 0.05,
                 message: str = "server overloaded"):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


class DeadlineExceeded(RuntimeError):
    """The frame's absolute deadline passed before (or while) the server
    could serve it — shed at admission or at queue-head, so abandoned
    requests stop burning shard-pool time. The op did not run."""
