"""Stage-level pipeline parallelism (paper Fig. 3c).

A ``StagePipeline`` chains stages through bounded queues, one worker thread
per stage, so download / pre-process / AL-inference overlap instead of
running serially per round (Fig. 3a/b). Per-stage busy and wait times are
recorded — the Table-2 benchmark derives its pipeline-vs-serial comparison
from exactly these counters.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Iterable, List, Optional, Sequence

_SENTINEL = object()


@dataclasses.dataclass
class StageStats:
    name: str
    items: int = 0
    busy_s: float = 0.0
    wait_s: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


class Stage:
    def __init__(self, name: str, fn: Callable[[Any], Any]):
        self.name = name
        self.fn = fn
        self.stats = StageStats(name)


class StagePipeline:
    """run(items): push items through all stages with overlap."""

    def __init__(self, stages: Sequence[Stage], max_queue: int = 8):
        self.stages = list(stages)
        self.max_queue = max_queue

    def run(self, items: Iterable[Any]) -> List[Any]:
        qs = [queue.Queue(maxsize=self.max_queue)
              for _ in range(len(self.stages) + 1)]
        out: List[Any] = []
        errors: List[BaseException] = []
        # A mid-stage exception must tear the WHOLE pipeline down: stages
        # upstream of the failed one would otherwise block forever on their
        # bounded output queue (the dead stage no longer drains it) and
        # join() would deadlock. Every blocking put/get is therefore a
        # short-timeout poll that aborts once the flag is set.
        abort = threading.Event()

        def put(q: queue.Queue, item) -> bool:
            while not abort.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def get(q: queue.Queue):
            while not abort.is_set():
                try:
                    return q.get(timeout=0.05)
                except queue.Empty:
                    continue
            return _SENTINEL

        def worker(stage: Stage, qin: queue.Queue, qout: queue.Queue):
            while True:
                t0 = time.perf_counter()
                item = get(qin)
                stage.stats.wait_s += time.perf_counter() - t0
                if item is _SENTINEL:
                    put(qout, _SENTINEL)
                    return
                t0 = time.perf_counter()
                try:
                    res = stage.fn(item)
                except BaseException as e:  # propagate to caller
                    errors.append(e)
                    abort.set()
                    put(qout, _SENTINEL)
                    return
                stage.stats.busy_s += time.perf_counter() - t0
                stage.stats.items += 1
                if not put(qout, res):
                    return
                if abort.is_set():
                    return

        threads = [
            threading.Thread(target=worker, args=(s, qs[i], qs[i + 1]),
                             daemon=True)
            for i, s in enumerate(self.stages)
        ]
        for t in threads:
            t.start()

        def feeder():
            # the items iterable itself may raise (lazy loaders): that must
            # abort the pipeline like a stage error, not strand the workers
            try:
                for it in items:
                    if not put(qs[0], it):
                        return
            except BaseException as e:
                errors.append(e)
                abort.set()
                return
            put(qs[0], _SENTINEL)

        tf = threading.Thread(target=feeder, daemon=True)
        tf.start()
        while True:
            item = get(qs[-1])
            if item is _SENTINEL:
                break
            out.append(item)
        abort_was_set = abort.is_set()
        abort.set()        # release any worker still parked on a full queue
        for t in threads:
            t.join()
        tf.join()
        if errors:
            raise errors[0]
        if abort_was_set:  # aborted without a recorded error (defensive)
            raise RuntimeError("pipeline aborted")
        return out

    def run_serial(self, items: Iterable[Any]) -> List[Any]:
        """Paper Fig. 3a baseline: stages strictly one after another."""
        out = []
        for item in items:
            for s in self.stages:
                t0 = time.perf_counter()
                item = s.fn(item)
                s.stats.busy_s += time.perf_counter() - t0
                s.stats.items += 1
            out.append(item)
        return out

    def stats(self):
        return [s.stats.as_dict() for s in self.stages]
