"""ALClient — the paper's few-LoC client API (Fig. 2), session-aware:

    client = ALClient(local=server)            # in-process
    client = ALClient(url="host:port")         # msgpack TCP
    client.push_data(data_list)
    selected = client.query(budget=10)

Multi-tenant: every client may claim its own server-side session — an
isolated pool/labels/head — so many clients share one server (and its
content-addressed embedding cache) without seeing each other's data:

    a = ALClient(url=u, session="new")         # fresh isolated session
    b = ALClient(url=u, session="new")
    a.push_data(xs)                            # invisible to b

``session=None`` (default) addresses the server's default session — the
original single-tenant behaviour.

Asynchronous ingest: ``push_data(xs, asynchronous=True)`` returns a
``PushTicket`` immediately (its ``keys`` are the content hashes, known
up front) and the server embeds in the background; ``flush()`` is the
barrier after which every prior push is visible to query/label/stats
(query and label also take it implicitly server-side). Over TCP the async
push rides a single-thread I/O executor, so requests stay strictly FIFO
on the shared connection.

Overload handling: a server running admission control (or a capped
ingest queue with ``ingest_policy: shed``) answers over-budget requests
with ``ServerOverloaded`` carrying ``retry_after_s``. Such an op never
ran server-side, so the client retries it up to ``retries`` times,
sleeping the server's hint plus deterministic jitter. A
``ConnectionError`` from a poisoned connection is NEVER retried — the op
may have executed. ``op_timeout_s`` stamps an absolute deadline into
every frame so the server sheds the op once the client has stopped
waiting (``DeadlineExceeded``).
"""
from __future__ import annotations

import concurrent.futures as cf
import random
import threading
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.service import transport
from repro.service.admission import AdmissionConfig
from repro.service.cache import content_key
from repro.service.errors import ServerOverloaded
from repro.service.server import ALServer, PushTicket


def serve_tcp(server: ALServer, host: str = "127.0.0.1",
              port: int = 0,
              max_workers: Optional[int] = None) -> transport.RPCServer:
    def open_session(p, s, ctx):
        sid = server.create_session()
        # remembered per connection: if the client vanishes without
        # close_session, on_close reclaims the session (and its raw copies)
        ctx.setdefault("sessions", set()).add(sid)
        return {"session": sid}

    def close_session(p, s, ctx):
        server.close_session(s)
        ctx.get("sessions", set()).discard(s)
        return {}

    def on_close(ctx):
        for sid in ctx.get("sessions", ()):
            server.close_session(sid)

    handlers = {
        "push_data": lambda p, s, c: {
            "keys": server.push_data(list(p["items"]), session=s)},
        # async: enqueue on the session's ingest queue and ack immediately
        # with the content keys; "flush" is the integration barrier
        "push_data_async": lambda p, s, c: {
            "keys": server.push_data(list(p["items"]), session=s,
                                     asynchronous=True).keys},
        "flush": lambda p, s, c: server.flush(
            session=s, timeout=p.get("timeout")) or {},
        "query": lambda p, s, c: server.query(
            int(p["budget"]), p.get("strategy"),
            p.get("target_accuracy"), int(p.get("rng_seed") or 0),
            session=s),
        "label": lambda p, s, c: server.label(p["keys"], p["labels"],
                                              session=s) or {},
        "stats": lambda p, s, c: server.stats(session=s),
        "train_eval": lambda p, s, c: {
            "accuracy": server.train_and_eval(session=s)},
        # standing queries: register once, the server emits as the pool
        # streams in; poll returns emits since a sequence number
        "standing_register": lambda p, s, c: server.standing_register(
            int(p["budget"]), p.get("strategy"),
            int(p.get("rng_seed") or 0), session=s),
        "standing_cancel": lambda p, s, c: server.standing_cancel(
            p["query_id"], p.get("reason") or "cancelled by client",
            session=s) or {},
        "standing_poll": lambda p, s, c: server.standing_poll(
            p["query_id"], int(p.get("since") or 0), session=s),
        "open_session": open_session,
        "close_session": close_session,
    }
    if max_workers is None:
        max_workers = server.config.server_workers
    cfg = server.config
    admission = AdmissionConfig(
        enabled=bool(cfg.admission),
        max_inflight=int(cfg.admission_max_inflight),
        tenant_rate=float(cfg.admission_tenant_rate),
        tenant_burst=float(cfg.admission_tenant_burst))
    rpc = transport.RPCServer(handlers, host, port, max_workers=max_workers,
                              on_close=on_close,
                              admission=admission,
                              fairness_weights=cfg.fairness_weights,
                              idle_timeout_s=cfg.idle_timeout_s,
                              send_timeout_s=cfg.send_timeout_s)
    rpc.start()
    # let ALServer.stats() report the transport's admission counters
    server._transport_stats = rpc.stats
    return rpc


class ALClient:
    def __init__(self, local: Optional[ALServer] = None,
                 url: Optional[str] = None,
                 session: Optional[str] = None,
                 retries: int = 2,
                 retry_jitter_s: float = 0.05,
                 op_timeout_s: Optional[float] = None):
        assert (local is None) != (url is None), "pass local= xor url="
        self._local = local
        self._rpc = None
        self._io: Optional[cf.ThreadPoolExecutor] = None
        self._io_lock = threading.Lock()
        self._owns_session = False
        # bounded retry on ServerOverloaded ONLY (the op never ran; see
        # module docstring). Deterministic jitter rng: seeded, not wall-
        # clock — two same-seed runs sleep identically
        self.retries = max(int(retries), 0)
        self.retry_jitter_s = float(retry_jitter_s)
        self.op_timeout_s = op_timeout_s
        self._jitter = random.Random(0xA1AA5)
        if url:
            host, port = url.rsplit(":", 1)
            self._rpc = transport.RPCClient(host, int(port))
        if session == "new":
            session = self.open_session()
        self._session = session

    @property
    def session(self) -> Optional[str]:
        return self._session

    def _rpc_retrying(self, op: str, payload, session):
        """One logical RPC: stamp the deadline, retry ServerOverloaded
        sheds up to ``retries`` times honoring the server's
        ``retry_after_s`` hint (+ jitter). Anything else — including
        ConnectionError from a poisoned connection — propagates on the
        first raise; those ops may have executed server-side."""
        deadline = (time.time() + self.op_timeout_s
                    if self.op_timeout_s else None)
        attempt = 0
        while True:
            try:
                return self._rpc.call(op, payload, session=session,
                                      deadline=deadline, attempt=attempt)
            except ServerOverloaded as e:
                if attempt >= self.retries:
                    raise
                attempt += 1
                time.sleep(e.retry_after_s
                           + self._jitter.random() * self.retry_jitter_s)

    def _local_retrying(self, fn, *args, **kwargs):
        """Same bounded retry for the in-process path (a shed ingest
        enqueue raises ServerOverloaded there too)."""
        attempt = 0
        while True:
            try:
                return fn(*args, **kwargs)
            except ServerOverloaded as e:
                if attempt >= self.retries:
                    raise
                attempt += 1
                time.sleep(e.retry_after_s
                           + self._jitter.random() * self.retry_jitter_s)

    def _call(self, op: str, payload=None, session=None):
        """One RPC round trip. Once an async push exists, every op rides
        the same single-thread executor so the shared socket sees strictly
        FIFO request/response pairs (a flush can never overtake a push
        that was issued before it). Retries happen INSIDE the executor
        slot, so a retried push still cannot be overtaken by a later op."""
        if self._io is not None:
            return self._io.submit(self._rpc_retrying, op, payload,
                                   session).result()
        return self._rpc_retrying(op, payload, session)

    def open_session(self) -> str:
        """Claim a fresh isolated session and address it from now on."""
        if self._local is not None:
            sid = self._local.create_session()
        else:
            sid = self._call("open_session")["session"]
        self._session = sid
        self._owns_session = True
        return sid

    def close_session(self):
        if self._session is None or not self._owns_session:
            return
        if self._local is not None:
            self._local.close_session(self._session)
        else:
            self._call("close_session", session=self._session)
        self._session = None
        self._owns_session = False

    def push_data(self, data_list: Sequence[np.ndarray],
                  asynchronous: bool = False):
        """Synchronous (default): embed + append now, return the keys.
        ``asynchronous=True``: return a ``PushTicket`` immediately —
        ``ticket.keys`` are the content hashes, ``ticket.result()`` waits
        for the server's acceptance (``timeout=`` raises ``TimeoutError``
        past the deadline instead of blocking forever), and ``flush()``
        (or any query/label) is the barrier after which the rows are
        visible."""
        if self._local is not None:
            return self._local_retrying(
                self._local.push_data, data_list, session=self._session,
                asynchronous=asynchronous)
        items = [np.asarray(d) for d in data_list]
        if not asynchronous:
            return self._call("push_data", {"items": items},
                              session=self._session)["keys"]
        with self._io_lock:   # two threads' first pushes must not race
            if self._io is None:
                self._io = cf.ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="alc-io")
        # a shed enqueue retries inside the I/O slot; only after the
        # bounded retries are exhausted does the ticket fail (with
        # ServerOverloaded — retryable, nothing was enqueued)
        fut = self._io.submit(self._rpc_retrying, "push_data_async",
                              {"items": items}, self._session)
        return PushTicket([content_key(it) for it in items], fut)

    def flush(self, timeout: Optional[float] = None) -> None:
        """Barrier: every ``push_data(asynchronous=True)`` issued before
        this call is embedded and visible to query/label/stats.
        ``timeout=`` raises ``TimeoutError`` once the deadline passes with
        the backlog intact (flush again to keep waiting)."""
        if self._local is not None:
            return self._local.flush(session=self._session, timeout=timeout)
        self._call("flush", {"timeout": timeout}, session=self._session)

    def query(self, budget: int, strategy: Optional[str] = None,
              target_accuracy: Optional[float] = None,
              rng_seed: int = 0) -> dict:
        if self._local is not None:
            return self._local.query(budget, strategy, target_accuracy,
                                     rng_seed, session=self._session)
        return self._call("query", {"budget": budget,
                                    "strategy": strategy,
                                    "target_accuracy": target_accuracy,
                                    "rng_seed": rng_seed},
                          session=self._session)

    def label(self, keys: Sequence[str], labels: Sequence[int]):
        if self._local is not None:
            return self._local.label(keys, labels, session=self._session)
        return self._call("label", {"keys": list(keys),
                                    "labels": [int(x) for x in labels]},
                          session=self._session)

    def train_eval(self) -> float:
        if self._local is not None:
            return self._local.train_and_eval(session=self._session)
        return self._call("train_eval", session=self._session)["accuracy"]

    # ------------------------------------------------- standing queries --
    def standing_register(self, budget: int, strategy: Optional[str] = None,
                          rng_seed: int = 0) -> dict:
        """Register a continuous query: the server keeps a ``budget``-sized
        selection live as data streams in. Returns the initial emit
        (``query_id``, ``seq``, ``keys``)."""
        if self._local is not None:
            return self._local.standing_register(budget, strategy, rng_seed,
                                                 session=self._session)
        return self._call("standing_register",
                          {"budget": int(budget), "strategy": strategy,
                           "rng_seed": int(rng_seed)},
                          session=self._session)

    def standing_cancel(self, query_id: str,
                        reason: str = "cancelled by client") -> None:
        if self._local is not None:
            return self._local.standing_cancel(query_id, reason,
                                               session=self._session)
        self._call("standing_cancel",
                   {"query_id": query_id, "reason": reason},
                   session=self._session)

    def standing_poll(self, query_id: str, since: int = 0) -> dict:
        """Current cumulative selection + the emits with ``seq > since``
        (each carries mode/added/removed and the pool/labels/head versions
        it was computed at). Takes the server-side flush barrier first, so
        a failed or dead async ingest raises here ticket-style."""
        if self._local is not None:
            return self._local.standing_poll(query_id, since,
                                             session=self._session)
        return self._call("standing_poll",
                          {"query_id": query_id, "since": int(since)},
                          session=self._session)

    def stats(self) -> dict:
        if self._local is not None:
            return self._local.stats(session=self._session)
        return self._call("stats", session=self._session)

    def close(self):
        self.close_session()
        if self._io:
            self._io.shutdown(wait=True)
            self._io = None
        if self._rpc:
            self._rpc.close()
