"""ALClient — the paper's few-LoC client API (Fig. 2), session-aware:

    client = ALClient(local=server)            # in-process
    client = ALClient(url="host:port")         # msgpack TCP
    client.push_data(data_list)
    selected = client.query(budget=10)

Multi-tenant: every client may claim its own server-side session — an
isolated pool/labels/head — so many clients share one server (and its
content-addressed embedding cache) without seeing each other's data:

    a = ALClient(url=u, session="new")         # fresh isolated session
    b = ALClient(url=u, session="new")
    a.push_data(xs)                            # invisible to b

``session=None`` (default) addresses the server's default session — the
original single-tenant behaviour.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.service import transport
from repro.service.server import ALServer


def serve_tcp(server: ALServer, host: str = "127.0.0.1",
              port: int = 0,
              max_workers: Optional[int] = None) -> transport.RPCServer:
    def open_session(p, s, ctx):
        sid = server.create_session()
        # remembered per connection: if the client vanishes without
        # close_session, on_close reclaims the session (and its raw copies)
        ctx.setdefault("sessions", set()).add(sid)
        return {"session": sid}

    def close_session(p, s, ctx):
        server.close_session(s)
        ctx.get("sessions", set()).discard(s)
        return {}

    def on_close(ctx):
        for sid in ctx.get("sessions", ()):
            server.close_session(sid)

    handlers = {
        "push_data": lambda p, s, c: {
            "keys": server.push_data(list(p["items"]), session=s)},
        "query": lambda p, s, c: server.query(
            int(p["budget"]), p.get("strategy"),
            p.get("target_accuracy"), int(p.get("rng_seed") or 0),
            session=s),
        "label": lambda p, s, c: server.label(p["keys"], p["labels"],
                                              session=s) or {},
        "stats": lambda p, s, c: server.stats(session=s),
        "train_eval": lambda p, s, c: {
            "accuracy": server.train_and_eval(session=s)},
        "open_session": open_session,
        "close_session": close_session,
    }
    if max_workers is None:
        max_workers = server.config.server_workers
    rpc = transport.RPCServer(handlers, host, port, max_workers=max_workers,
                              on_close=on_close)
    rpc.start()
    return rpc


class ALClient:
    def __init__(self, local: Optional[ALServer] = None,
                 url: Optional[str] = None,
                 session: Optional[str] = None):
        assert (local is None) != (url is None), "pass local= xor url="
        self._local = local
        self._rpc = None
        self._owns_session = False
        if url:
            host, port = url.rsplit(":", 1)
            self._rpc = transport.RPCClient(host, int(port))
        if session == "new":
            session = self.open_session()
        self._session = session

    @property
    def session(self) -> Optional[str]:
        return self._session

    def open_session(self) -> str:
        """Claim a fresh isolated session and address it from now on."""
        if self._local is not None:
            sid = self._local.create_session()
        else:
            sid = self._rpc.call("open_session")["session"]
        self._session = sid
        self._owns_session = True
        return sid

    def close_session(self):
        if self._session is None or not self._owns_session:
            return
        if self._local is not None:
            self._local.close_session(self._session)
        else:
            self._rpc.call("close_session", session=self._session)
        self._session = None
        self._owns_session = False

    def push_data(self, data_list: Sequence[np.ndarray],
                  asynchronous: bool = False) -> List[str]:
        if self._local is not None:
            return self._local.push_data(data_list, session=self._session)
        return self._rpc.call("push_data",
                              {"items": [np.asarray(d) for d in data_list]},
                              session=self._session)["keys"]

    def query(self, budget: int, strategy: Optional[str] = None,
              target_accuracy: Optional[float] = None,
              rng_seed: int = 0) -> dict:
        if self._local is not None:
            return self._local.query(budget, strategy, target_accuracy,
                                     rng_seed, session=self._session)
        return self._rpc.call("query", {"budget": budget,
                                        "strategy": strategy,
                                        "target_accuracy": target_accuracy,
                                        "rng_seed": rng_seed},
                              session=self._session)

    def label(self, keys: Sequence[str], labels: Sequence[int]):
        if self._local is not None:
            return self._local.label(keys, labels, session=self._session)
        return self._rpc.call("label", {"keys": list(keys),
                                        "labels": [int(x) for x in labels]},
                              session=self._session)

    def train_eval(self) -> float:
        if self._local is not None:
            return self._local.train_and_eval(session=self._session)
        return self._rpc.call("train_eval",
                              session=self._session)["accuracy"]

    def stats(self) -> dict:
        if self._local is not None:
            return self._local.stats(session=self._session)
        return self._rpc.call("stats", session=self._session)

    def close(self):
        self.close_session()
        if self._rpc:
            self._rpc.close()
