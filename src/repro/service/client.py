"""ALClient — the paper's few-LoC client API (Fig. 2):

    client = ALClient(local=server)            # in-process
    client = ALClient(url="host:port")         # msgpack TCP
    client.push_data(data_list)
    selected = client.query(budget=10)
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.service import transport
from repro.service.server import ALServer


def serve_tcp(server: ALServer, host: str = "127.0.0.1",
              port: int = 0) -> transport.RPCServer:
    handlers = {
        "push_data": lambda p: {"keys": server.push_data(list(p["items"]))},
        "query": lambda p: server.query(
            int(p["budget"]), p.get("strategy"),
            p.get("target_accuracy")),
        "label": lambda p: server.label(p["keys"], p["labels"]) or {},
        "stats": lambda p: server.stats(),
        "train_eval": lambda p: {"accuracy": server.train_and_eval()},
    }
    rpc = transport.RPCServer(handlers, host, port)
    rpc.start()
    return rpc


class ALClient:
    def __init__(self, local: Optional[ALServer] = None,
                 url: Optional[str] = None):
        assert (local is None) != (url is None), "pass local= xor url="
        self._local = local
        self._rpc = None
        if url:
            host, port = url.rsplit(":", 1)
            self._rpc = transport.RPCClient(host, int(port))

    def push_data(self, data_list: Sequence[np.ndarray],
                  asynchronous: bool = False) -> List[str]:
        if self._local is not None:
            return self._local.push_data(data_list)
        return self._rpc.call("push_data",
                              {"items": [np.asarray(d) for d in data_list]}
                              )["keys"]

    def query(self, budget: int, strategy: Optional[str] = None,
              target_accuracy: Optional[float] = None) -> dict:
        if self._local is not None:
            return self._local.query(budget, strategy, target_accuracy)
        return self._rpc.call("query", {"budget": budget,
                                        "strategy": strategy,
                                        "target_accuracy": target_accuracy})

    def label(self, keys: Sequence[str], labels: Sequence[int]):
        if self._local is not None:
            return self._local.label(keys, labels)
        return self._rpc.call("label", {"keys": list(keys),
                                        "labels": [int(x) for x in labels]})

    def train_eval(self) -> float:
        if self._local is not None:
            return self._local.train_and_eval()
        return self._rpc.call("train_eval")["accuracy"]

    def stats(self) -> dict:
        if self._local is not None:
            return self._local.stats()
        return self._rpc.call("stats")

    def close(self):
        if self._rpc:
            self._rpc.close()
