"""Content-addressed embedding/logit cache (paper §3.3 'data cache').

Keyed by content hash so re-pushed samples never recompute embeddings —
public clouds separate storage and compute, so the paper keeps processed
samples close to the workers. LRU-bounded in RAM with optional zstd disk
spill (evicted entries remain retrievable).
"""
from __future__ import annotations

import collections
import hashlib
import os
import pickle
import threading
from typing import Any, Dict, Optional

import numpy as np

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover
    zstd = None


def content_key(data) -> str:
    if isinstance(data, np.ndarray):
        h = hashlib.sha1(data.tobytes())
        h.update(str(data.shape).encode())
        h.update(str(data.dtype).encode())
    else:
        h = hashlib.sha1(bytes(data))
    return h.hexdigest()


class EmbeddingCache:
    def __init__(self, max_bytes: int = 1 << 30,
                 spill_dir: Optional[str] = None):
        self.max_bytes = max_bytes
        self.spill_dir = spill_dir
        if spill_dir:
            os.makedirs(spill_dir, exist_ok=True)
        self._lru: "collections.OrderedDict[str, Any]" = collections.OrderedDict()
        self._sizes: Dict[str, int] = {}
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.spills = 0
        self.spill_bytes = 0     # compressed bytes written to spill files

    @staticmethod
    def _size(value) -> int:
        if isinstance(value, np.ndarray):
            return int(value.nbytes)
        if isinstance(value, (list, tuple)):
            return sum(int(v.nbytes) if isinstance(v, np.ndarray)
                       else len(pickle.dumps(v)) for v in value)
        if isinstance(value, dict):
            return sum(int(v.nbytes) if isinstance(v, np.ndarray)
                       else len(pickle.dumps(v)) for v in value.values())
        return len(pickle.dumps(value))

    def put(self, key: str, value) -> None:
        size = self._size(value)
        evicted = []
        with self._lock:
            if key in self._lru:
                self._bytes -= self._sizes[key]
                del self._lru[key]
            self._lru[key] = value
            self._sizes[key] = size
            self._bytes += size
            while self._bytes > self.max_bytes and len(self._lru) > 1:
                old_key, old_val = self._lru.popitem(last=False)
                self._bytes -= self._sizes.pop(old_key)
                evicted.append((old_key, old_val))
        # zstd compression + disk writes happen OUTSIDE the lock so readers
        # are never blocked behind a spill. Two racing spills of one key can
        # land in either order — safe because keys are content hashes, so
        # every spill of a key carries the same value.
        for old_key, old_val in evicted:
            self._spill(old_key, old_val)

    def get(self, key: str):
        with self._lock:
            if key in self._lru:
                self._lru.move_to_end(key)
                self.hits += 1
                return self._lru[key]
        val = self._unspill(key)
        # counted under the lock: parallel per-shard artifact builds hammer
        # get(), and the hit/miss tallies are part of the served stats now,
        # so lost increments would misreport the cache's effectiveness
        with self._lock:
            if val is not None:
                self.hits += 1
            else:
                self.misses += 1
        if val is not None:
            self.put(key, val)
            return val
        return None

    def require(self, key: str):
        """``get`` that refuses to return None: a miss (entry evicted with
        no spill_dir, or never inserted) raises a clear KeyError instead of
        letting callers feed None into np.stack and crash elsewhere."""
        val = self.get(key)
        if val is None:
            where = ("no spill file found" if self.spill_dir
                     else "no spill_dir configured")
            raise KeyError(f"cache entry {key!r} unavailable: evicted from "
                           f"RAM and {where}; raise cache_bytes or set "
                           f"cache_spill_dir")
        return val

    def __contains__(self, key: str) -> bool:
        with self._lock:
            if key in self._lru:
                return True
        return self.spill_dir is not None and os.path.exists(self._path(key))

    # -- disk spill -------------------------------------------------------
    def _path(self, key: str) -> str:
        return os.path.join(self.spill_dir, key + ".zst")

    def _spill(self, key: str, value) -> None:
        if not self.spill_dir:
            return
        blob = pickle.dumps(value, protocol=4)
        if zstd is not None:
            blob = zstd.ZstdCompressor(level=3).compress(blob)
        # write-then-rename: _unspill reads without the lock, so a spill
        # file must never be observable half-written
        tmp = self._path(key) + f".tmp.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, self._path(key))
        with self._lock:     # racing spills: counters must not lose ticks
            self.spills += 1
            self.spill_bytes += len(blob)

    def _unspill(self, key: str):
        if not self.spill_dir:
            return None
        p = self._path(key)
        if not os.path.exists(p):
            return None
        with open(p, "rb") as f:
            blob = f.read()
        if zstd is not None:
            blob = zstd.ZstdDecompressor().decompress(blob)
        return pickle.loads(blob)

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._lru), "bytes": self._bytes,
                    "hits": self.hits, "misses": self.misses,
                    "spills": self.spills,
                    "spill_bytes": self.spill_bytes,
                    "resident_bytes": self._bytes}
