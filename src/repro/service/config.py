"""Configuration-as-a-service (paper Fig. 2).

A minimal offline YAML-subset parser (nested maps, lists, scalars, comments)
so the paper's ``example.yml`` schema works verbatim without a yaml
dependency, plus the typed ``ALServiceConfig`` it loads into.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Union


def _scalar(s: str) -> Any:
    s = s.strip()
    if s in ("null", "~", ""):
        return None
    if s in ("true", "True"):
        return True
    if s in ("false", "False"):
        return False
    if (s.startswith('"') and s.endswith('"')) or \
       (s.startswith("'") and s.endswith("'")):
        return s[1:-1]
    try:
        return int(s)
    except ValueError:
        pass
    try:
        return float(s)
    except ValueError:
        pass
    return s


def parse_yaml(text: str) -> Any:
    """Indentation-based subset: maps, lists of scalars/maps, scalars."""
    lines = []
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].rstrip()
        if line.strip():
            lines.append(line)

    def parse_block(idx: int, indent: int):
        if idx >= len(lines):
            return None, idx
        first = lines[idx]
        cur_indent = len(first) - len(first.lstrip())
        if first.lstrip().startswith("- "):
            items = []
            while idx < len(lines):
                line = lines[idx]
                ind = len(line) - len(line.lstrip())
                if ind != cur_indent or not line.lstrip().startswith("- "):
                    break
                body = line.lstrip()[2:]
                if ":" in body:
                    k, _, rest = body.partition(":")
                    if rest.strip():
                        items.append({k.strip(): _scalar(rest)})
                        idx += 1
                    else:
                        sub, idx2 = parse_block(idx + 1, cur_indent + 1)
                        items.append({k.strip(): sub})
                        idx = idx2
                else:
                    items.append(_scalar(body))
                    idx += 1
            return items, idx
        out: Dict[str, Any] = {}
        while idx < len(lines):
            line = lines[idx]
            ind = len(line) - len(line.lstrip())
            if ind < cur_indent:
                break
            if ind > cur_indent:
                raise ValueError(f"bad indent: {line!r}")
            if ":" not in line:
                raise ValueError(f"expected key: {line!r}")
            key, _, rest = line.lstrip().partition(":")
            if rest.strip():
                out[key.strip()] = _scalar(rest)
                idx += 1
            else:
                nxt = idx + 1
                if nxt < len(lines):
                    nind = len(lines[nxt]) - len(lines[nxt].lstrip())
                    if nind > cur_indent:
                        sub, idx = parse_block(nxt, nind)
                        out[key.strip()] = sub
                        continue
                out[key.strip()] = None
                idx += 1
        return out, idx

    obj, _ = parse_block(0, 0)
    return obj


@dataclasses.dataclass
class ALServiceConfig:
    name: str = "AL_SERVICE"
    version: str = "0.1"
    strategy: str = "auto"              # auto -> PSHEA agent
    model_name: str = "synthetic_cnn"   # backend scorer id
    batch_size: int = 16
    # transformer backend knobs (model.name: transformer): the blockwise
    # forward's row-block size (activation-memory lever; bitwise-invisible
    # in the feature bytes), the canonical per-sample sequence length
    # preprocess pads/truncates to, the pooling reduction (mean | last),
    # the input modality (text | audio) and, for audio, the per-frame
    # feature width
    model_block_size: int = 64
    model_seq_len: int = 128
    model_pooling: str = "mean"
    model_modality: str = "text"
    model_input_dim: int = 0
    device: str = "CPU"
    protocol: str = "tcp"
    host: str = "127.0.0.1"
    port: int = 60035
    # pool shards per session: artifacts build and strategies score
    # per-shard in parallel, selections stay bit-identical to replicas=1
    replicas: int = 1
    # max queued push_data(asynchronous=True) calls folded into one drained
    # ingest batch (one pool_version bump per batch)
    ingest_batch: int = 256
    cache_bytes: int = 1 << 30
    cache_spill_dir: Optional[str] = None
    target_accuracy: float = 0.95
    budget_max: int = 10000
    # PSHEA candidate set: "paper" = the paper's 7; "hybrid" adds the
    # weighted fused-round strategies (badge/margin_density/weighted_kcenter)
    auto_candidates: str = "paper"
    # PSHEA racing: >1 fans surviving candidates across that many worker
    # threads per round (bit-identical to serial; 0/1 = serial)
    pshea_workers: int = 0
    # memoize (feats, probs) pool artifacts in per-shard epoch-stamped
    # columns; False = from-scratch O(pool) builds every query (the
    # bit-identity oracle the incremental engine is tested against)
    artifact_cache: bool = True
    # True (default): delta builds — a push refreshes only the rows it
    # appended on the shards it touched, a retrain refreshes probs only.
    # False: a stale shard column rebuilds in full (debugging fallback;
    # selections are bit-identical either way)
    incremental_artifacts: bool = True
    # centroid-gated pool prefilter (core.prefilter): selection scores only
    # the pool blocks whose cluster summary survives a bound check.
    # False = every query scans the full pool (the from-scratch oracle the
    # gated paths are tested against)
    prefilter: bool = False
    # relative slack on the triangle-inequality bound: larger = looser =
    # more rows scanned; a very large value degenerates to the exact full
    # scan bit-for-bit
    prefilter_slack: float = 0.05
    # centroids per shard summary (0 = auto: ~1 per 256 rows, capped 64)
    prefilter_clusters: int = 0
    # shards below this row count skip summaries and always full-scan
    prefilter_min_rows: int = 256
    # persist per-session k-center min-dist vectors across queries
    # (core.selection.KCenterStateCache): warm-started strategies
    # (coreset, weighted_kcenter) fold only the rows/centers appended since
    # the last query. False = every query re-folds from scratch (the
    # bit-identity oracle the cache is tested against)
    strategy_state_cache: bool = True
    # standing-query emits replay the previous selection against just the
    # delta rows (O(new rows) when no new row displaces a recorded winner).
    # False = every emit is a full re-selection (the bit-identity oracle;
    # emitted selections are identical either way)
    standing_replay: bool = True
    # RAM budget per artifact-column buffer: allocations past it go to
    # mmap-backed spill files (core.selection.ColumnSpill). 0 = unlimited
    # RAM (no spill)
    shard_ram_bytes: int = 0
    # spill file directory (default: a per-session dir under the system
    # tempdir, removed on session close)
    shard_spill_dir: Optional[str] = None
    # handler threads shared across ALL connections (frame-level dispatch:
    # idle connections cost nothing; extra clients queue, never refused)
    server_workers: int = 16
    # -- overload-safe serving (transport admission layer) ----------------
    # False (default) = admit everything: the bit-identity oracle the
    # overload drill twins against. True = enforce the inflight bound and
    # per-tenant token buckets; rejected frames carry retry_after_s
    admission: bool = False
    # server-wide bound on admitted-but-unfinished frames (queued +
    # executing across all tenants)
    admission_max_inflight: int = 64
    # per-tenant token bucket: sustained ops/s (<= 0 disables the bucket
    # check) and burst allowance
    admission_tenant_rate: float = 0.0
    admission_tenant_burst: float = 8.0
    # per-tenant WFQ weights (session id -> relative share; default 1.0)
    fairness_weights: Optional[Dict[str, float]] = None
    # close an accepted connection silent for this long with nothing
    # queued or executing (half-open client reclamation; 0 = never)
    idle_timeout_s: float = 0.0
    # a response send stalled this long (stopped-reading client) closes
    # the connection instead of wedging a handler thread (0 = never)
    send_timeout_s: float = 30.0
    # -- bounded async ingest ---------------------------------------------
    # caps on rows/bytes outstanding in a session's ingest queue (enqueue
    # until integration); 0 = unbounded. An oversize single push is still
    # admitted when nothing is outstanding
    ingest_max_rows: int = 0
    ingest_max_bytes: int = 0
    # at the cap: "block" = backpressure the producer until the worker
    # drains; "shed" = raise ServerOverloaded (retryable; the TCP
    # PushTicket fails with it, nothing was enqueued)
    ingest_policy: str = "block"
    # shard-worker runtime (distributed.worker, replicas > 1): "thread"
    # runs each shard's rounds on a dedicated supervised lane thread;
    # "process" additionally pairs each lane with an OS worker process
    # that executes the registered embed jobs (true process isolation for
    # the heavy step; closures stay on the lane thread)
    worker_backend: str = "thread"
    # a shard task past this wall-clock is presumed a dead worker: the
    # lane restarts, the shard recovers (re-embed from raw + content
    # keys), and the task retries
    worker_timeout_s: float = 30.0
    # bounded retries after a worker death before the failure propagates
    worker_retries: int = 2
    # linear backoff between retries (attempt * backoff seconds)
    worker_backoff_s: float = 0.05

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ALServiceConfig":
        al = d.get("active_learning", {}) or {}
        strat = (al.get("strategy", {}) or {})
        model = (al.get("model", {}) or {})
        worker = d.get("al_worker", {}) or {}
        adm = worker.get("admission", {}) or {}
        weights = adm.get("weights") or None
        if weights is not None:
            weights = {str(k): float(v) for k, v in weights.items()}
        return cls(
            name=d.get("name", "AL_SERVICE"),
            version=str(d.get("version", "0.1")),
            strategy=strat.get("type", "auto"),
            model_name=model.get("name", "synthetic_cnn"),
            batch_size=int(model.get("batch_size", 16)),
            model_block_size=int(model.get("block_size", 64)),
            model_seq_len=int(model.get("seq_len", 128)),
            model_pooling=model.get("pooling", "mean"),
            model_modality=model.get("modality", "text"),
            model_input_dim=int(model.get("input_dim", 0)),
            device=str(al.get("device", "CPU")),
            protocol=worker.get("protocol", "tcp"),
            host=worker.get("host", "127.0.0.1"),
            port=int(worker.get("port", 60035)),
            replicas=int(worker.get("replicas", 1)),
            ingest_batch=int(worker.get("ingest_batch", 256)),
            target_accuracy=float(al.get("target_accuracy", 0.95)),
            budget_max=int(al.get("budget_max", 10000)),
            auto_candidates=strat.get("candidates", "paper"),
            pshea_workers=int(al.get("pshea_workers", 0)),
            artifact_cache=bool(al.get("artifact_cache", True)),
            incremental_artifacts=bool(al.get("incremental_artifacts", True)),
            server_workers=int(worker.get("workers", 16)),
            strategy_state_cache=bool(al.get("strategy_state_cache", True)),
            standing_replay=bool(al.get("standing_replay", True)),
            prefilter=bool(al.get("prefilter", False)),
            prefilter_slack=float(al.get("prefilter_slack", 0.05)),
            prefilter_clusters=int(al.get("prefilter_clusters", 0)),
            prefilter_min_rows=int(al.get("prefilter_min_rows", 256)),
            shard_ram_bytes=int(worker.get("shard_ram_bytes", 0)),
            shard_spill_dir=worker.get("shard_spill_dir"),
            worker_backend=worker.get("backend", "thread"),
            worker_timeout_s=float(worker.get("timeout_s", 30.0)),
            worker_retries=int(worker.get("retries", 2)),
            worker_backoff_s=float(worker.get("backoff_s", 0.05)),
            admission=bool(adm.get("enabled", False)),
            admission_max_inflight=int(adm.get("max_inflight", 64)),
            admission_tenant_rate=float(adm.get("tenant_rate", 0.0)),
            admission_tenant_burst=float(adm.get("tenant_burst", 8.0)),
            fairness_weights=weights,
            idle_timeout_s=float(worker.get("idle_timeout_s", 0.0)),
            send_timeout_s=float(worker.get("send_timeout_s", 30.0)),
            ingest_max_rows=int(worker.get("ingest_max_rows", 0)),
            ingest_max_bytes=int(worker.get("ingest_max_bytes", 0)),
            ingest_policy=worker.get("ingest_policy", "block"),
        )

    @classmethod
    def from_yaml(cls, path_or_text: str) -> "ALServiceConfig":
        if "\n" not in path_or_text:
            with open(path_or_text) as f:
                path_or_text = f.read()
        return cls.from_dict(parse_yaml(path_or_text))
