"""Admission control + per-tenant weighted fair queueing for the RPC
transport.

Three pieces, all transport-agnostic (unit-testable without sockets):

``TokenBucket``
    Classic rate/burst bucket. ``try_take`` either takes a token or
    returns the exact wait until one accrues — that wait is the
    ``retry_after_s`` a shed response carries.

``AdmissionConfig``
    The knobs: a server-wide inflight bound (queued + executing frames)
    and a per-tenant rate/burst. ``enabled=False`` (the default) turns
    every admission check off — scheduling still runs, nothing is ever
    shed — which is the bit-identity oracle the overload drill twins
    against.

``FrameScheduler``
    The dispatch queue between the socket event loop and the worker
    pool. Frames are grouped per *stream* (one stream == one
    connection, FIFO order preserved: at most one frame of a stream is
    ever in flight) and streams are scheduled per *tenant* (the frame's
    session id) by start-time fair queueing: each tenant carries a
    virtual ``pass`` advanced by ``1/weight`` per served frame, the
    minimum-pass tenant is served next, and a tenant going active after
    idling resumes at the current virtual time (idle tenants bank no
    credit, so an idle connection costs nothing and a heavy tenant
    cannot starve light ones). Per-tenant counters
    (``admitted/shed/expired/retries``) feed ``stats()``.

Two kinds of entries ride a stream's queue: admitted frames (real
work, held to the inflight bound) and *control* entries — pre-built
responses (shed notices) the transport wants written in per-stream FIFO
order without the event loop ever blocking on a send. Control entries
bypass every admission check and don't occupy inflight slots.

A stream object must expose the attributes the scheduler owns
(``pending`` deque, ``inflight``/``queued``/``closed`` flags);
``attach_stream`` initializes them.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Dict, Optional, Tuple

__all__ = ["TokenBucket", "AdmissionConfig", "FrameScheduler",
           "attach_stream"]


class TokenBucket:
    """rate tokens/s, up to ``burst`` banked. Not thread-safe on its own —
    the scheduler serializes access under its lock."""

    def __init__(self, rate: float, burst: float,
                 clock=time.monotonic):
        self.rate = float(rate)
        self.burst = max(float(burst), 1.0)
        self.tokens = self.burst
        self._clock = clock
        self._t = clock()

    def try_take(self, n: float = 1.0) -> Tuple[bool, float]:
        """(True, 0.0) and debit on success; (False, wait_s) where
        ``wait_s`` is exactly how long until ``n`` tokens have accrued."""
        now = self._clock()
        self.tokens = min(self.burst, self.tokens + (now - self._t) * self.rate)
        self._t = now
        if self.tokens >= n:
            self.tokens -= n
            return True, 0.0
        if self.rate <= 0:
            return False, 1.0
        return False, (n - self.tokens) / self.rate


@dataclasses.dataclass
class AdmissionConfig:
    enabled: bool = False
    # server-wide bound on admitted-but-unfinished frames (queued +
    # executing); past it new frames shed with retry_after_s
    max_inflight: int = 64
    # per-tenant token bucket; rate <= 0 disables the bucket check
    tenant_rate: float = 0.0
    tenant_burst: float = 8.0


def attach_stream(stream: Any) -> Any:
    """Initialize the scheduler-owned attributes on a stream object."""
    stream.pending = deque()    # (tenant, payload, control) not yet served
    stream.inflight = False     # a worker is serving this stream's head
    stream.queued = False       # stream sits in some tenant's ready deque
    stream.closed = False       # dropped; lazily skipped when popped
    return stream


class _TenantQ:
    __slots__ = ("weight", "vpass", "streams")

    def __init__(self, weight: float):
        self.weight = max(float(weight), 1e-6)
        self.vpass = 0.0
        self.streams: deque = deque()   # ready streams, FIFO within tenant


_COUNTER_FIELDS = ("admitted", "shed", "expired", "retries")


class FrameScheduler:
    def __init__(self, cfg: Optional[AdmissionConfig] = None,
                 weights: Optional[Dict[str, float]] = None,
                 workers: int = 1,
                 clock=time.monotonic, wall=time.time):
        self.cfg = cfg or AdmissionConfig()
        self._weights = dict(weights or {})
        self._workers = max(int(workers), 1)
        self._clock = clock
        self._wall = wall
        self._cv = threading.Condition()
        self._tenants: Dict[str, _TenantQ] = {}
        self._buckets: Dict[str, TokenBucket] = {}
        self._counts: Dict[str, Dict[str, int]] = {}
        self._vtime = 0.0
        self._inflight = 0
        self.inflight_hw = 0
        self._svc_ema_s = 0.01      # smoothed per-frame service time
        self._admitting = True
        self._closed = False

    # ------------------------------------------------------------ intake --
    def submit(self, stream: Any, tenant: str,
               frame: dict) -> Tuple[str, Optional[str], float]:
        """Admit-or-shed one frame. Returns (verdict, code, retry_after_s)
        where verdict is "admitted" or "shed"; shed codes are
        "shutdown" | "deadline" | "overloaded". Admitted frames are
        queued on the stream and scheduled; the caller sends the shed
        response itself (nothing ran server-side)."""
        with self._cv:
            if not self._admitting:
                return "shed", "shutdown", 0.0
            # deadline shed-before-dispatch is independent of admission:
            # an already-expired frame is dead work whatever the load
            deadline = frame.get("deadline")
            if deadline is not None and self._wall() > float(deadline):
                self._count(tenant, "expired")
                self._count(tenant, "shed")
                return "shed", "deadline", 0.0
            if self.cfg.enabled:
                if self._inflight >= self.cfg.max_inflight:
                    self._count(tenant, "shed")
                    return "shed", "overloaded", self._retry_after()
                if self.cfg.tenant_rate > 0:
                    bucket = self._buckets.get(tenant)
                    if bucket is None:
                        bucket = self._buckets[tenant] = TokenBucket(
                            self.cfg.tenant_rate, self.cfg.tenant_burst,
                            clock=self._clock)
                    ok, wait_s = bucket.try_take(1.0)
                    if not ok:
                        self._count(tenant, "shed")
                        return "shed", "overloaded", wait_s
            self._count(tenant, "admitted")
            if frame.get("attempt"):
                self._count(tenant, "retries")
            self._inflight += 1
            self.inflight_hw = max(self.inflight_hw, self._inflight)
            stream.pending.append((tenant, frame, False))
            if not stream.inflight and not stream.queued:
                self._make_ready(stream, tenant)
            self._cv.notify()
            return "admitted", None, 0.0

    def submit_control(self, stream: Any, tenant: str,
                       payload: Any) -> bool:
        """Queue a pre-built response on the stream: rides the same
        per-stream FIFO as admitted frames (so a shed notice can never
        overtake the response of an earlier admitted frame) but bypasses
        admission and occupies no inflight slot. Returns False once the
        scheduler is closed (the connection is about to die anyway)."""
        with self._cv:
            if self._closed or stream.closed:
                return False
            stream.pending.append((tenant, payload, True))
            if not stream.inflight and not stream.queued:
                self._make_ready(stream, tenant)
            self._cv.notify()
            return True

    def _make_ready(self, stream: Any, tenant: str) -> None:
        tq = self._tenants.get(tenant)
        if tq is None:
            tq = self._tenants[tenant] = _TenantQ(
                self._weights.get(tenant, 1.0))
        if not tq.streams:
            # tenant goes active: resume at the virtual time, banking no
            # credit for the time it sat idle
            tq.vpass = max(tq.vpass, self._vtime)
        tq.streams.append(stream)
        stream.queued = True

    # ---------------------------------------------------------- dispatch --
    def next(self, timeout: float = 0.2):
        """Pop the next (stream, tenant, payload, control) in WFQ order,
        or None on timeout/close (workers loop and re-check ``closed``)."""
        with self._cv:
            item = self._pop()
            if item is not None:
                return item
            if self._closed:
                return None
            self._cv.wait(timeout)
            return self._pop()

    def _pop(self):
        while True:
            best = None
            for tq in self._tenants.values():
                if tq.streams and (best is None or tq.vpass < best.vpass):
                    best = tq
            if best is None:
                return None
            stream = best.streams.popleft()
            stream.queued = False
            if stream.closed or not stream.pending:
                continue            # dropped while queued: skip, no charge
            tenant, payload, control = stream.pending.popleft()
            stream.inflight = True
            self._vtime = max(self._vtime, best.vpass)
            best.vpass += 1.0 / best.weight
            return stream, tenant, payload, control

    def done(self, stream: Any, duration_s: float = 0.0,
             control: bool = False) -> None:
        """Entry served (or shed at queue-head): release the inflight
        slot (admitted frames only) and, if the stream has more queued
        entries, re-queue it under its new head's tenant (per-stream
        FIFO: one at a time)."""
        with self._cv:
            if not control:
                self._inflight = max(self._inflight - 1, 0)
                if duration_s > 0:
                    self._svc_ema_s += 0.2 * (duration_s - self._svc_ema_s)
            stream.inflight = False
            if stream.pending and not stream.closed and not stream.queued:
                self._make_ready(stream, stream.pending[0][0])
            self._cv.notify()

    def drop_stream(self, stream: Any) -> None:
        """Stream's connection died: discard its queued entries (their
        responses have nowhere to go) and release the admitted ones'
        inflight slots. A frame currently executing still gets its
        done() from the worker."""
        with self._cv:
            stream.closed = True
            n = sum(1 for _, _, control in stream.pending if not control)
            stream.pending.clear()
            self._inflight = max(self._inflight - n, 0)
            if n:
                self._cv.notify()

    def cancel_pending(self):
        """Deterministic stop: stop admitting, pop every queued-not-
        started entry and hand them back as (stream, tenant, payload,
        control) so the transport can answer each admitted frame with a
        "shutdown" shed (and flush pre-built responses) before closing."""
        out = []
        with self._cv:
            self._admitting = False
            for tq in self._tenants.values():
                while tq.streams:
                    stream = tq.streams.popleft()
                    stream.queued = False
                    while stream.pending:
                        tenant, payload, control = stream.pending.popleft()
                        if not control:
                            self._inflight = max(self._inflight - 1, 0)
                        out.append((stream, tenant, payload, control))
            self._cv.notify_all()
        return out

    def close(self) -> None:
        with self._cv:
            self._admitting = False
            self._closed = True
            self._cv.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed

    # ------------------------------------------------------------- stats --
    def count(self, tenant: str, field: str) -> None:
        """External counter bump (e.g. queue-head deadline expiry)."""
        with self._cv:
            self._count(tenant, field)

    def _count(self, tenant: str, field: str) -> None:
        c = self._counts.get(tenant)
        if c is None:
            c = self._counts[tenant] = dict.fromkeys(_COUNTER_FIELDS, 0)
        c[field] += 1

    def _retry_after(self) -> float:
        # how long until a worker slot frees for MY frame: smoothed
        # service time scaled by the backlog ahead of me per worker
        est = self._svc_ema_s * (self._inflight / self._workers + 1.0)
        return min(max(est, 0.01), 2.0)

    def stats(self) -> dict:
        with self._cv:
            totals = dict.fromkeys(_COUNTER_FIELDS, 0)
            for c in self._counts.values():
                for k in _COUNTER_FIELDS:
                    totals[k] += c[k]
            return {
                "enabled": self.cfg.enabled,
                "max_inflight": self.cfg.max_inflight,
                "tenant_rate": self.cfg.tenant_rate,
                "inflight": self._inflight,
                "inflight_hw": self.inflight_hw,
                "service_ema_s": self._svc_ema_s,
                "tenants": {t: dict(c) for t, c in self._counts.items()},
                **totals,
            }
