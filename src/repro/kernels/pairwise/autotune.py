"""Block-size autotuner for the fused greedy-selection kernels.

``greedy_round_pallas`` has two launch parameters that trade HBM traffic
against VMEM pressure:

``n_block``
    Rows per grid step. The (Rp, d) center tile is re-fetched once per row
    block (its BlockSpec index map is constant), so small ``n_block`` means
    ceil(N / n_block) redundant center reads; large ``n_block`` grows the
    per-step VMEM footprint (row tile + (n_block, Rp) distance matrix) and
    eventually spills.

``r_block``
    Centers folded per fused pass in ``ops.warm_start_min_dist``. M centers
    cost ceil(M / r_block) full pool reads, so bytes-per-center shrinks
    monotonically with ``r_block`` until the center tile + distance matrix
    no longer fit the VMEM budget.

The tuner sweeps both over the same op-accounted HBM model the benchmarks
use (bytes actually moved per fused round), rejects candidates whose tiles
exceed the VMEM budget (~16 MB/core on TPU; we keep half as headroom for
double buffering), and — when a TPU is attached or ``measure=True`` —
re-ranks the model's shortlist by measured wall clock. Winners are cached
per (N, d, dtype) shape key; ``report()`` exposes the cache so benchmarks
can print the chosen blocks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

N_BLOCK_CANDIDATES = (64, 128, 256, 512, 1024)
R_BLOCK_CANDIDATES = (8, 32, 64, 128, 256, 512)

# ~16 MB VMEM per core; half of it as the tile budget leaves room for the
# compiler's double buffering of streamed inputs.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class BlockChoice:
    n_block: int
    r_block: int
    hbm_bytes: float          # modeled bytes per fused round at (n, r)
    wall_s: float             # measured s/round (0.0 when model-only)
    source: str               # "model" | "measured"


_CACHE: Dict[Tuple[int, int, str], BlockChoice] = {}


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


def round_hbm_bytes(n: int, d: int, dtype_bytes: float, n_block: int,
                    r_block: int) -> float:
    """Modeled HBM bytes of ONE fused greedy round (see kernel.py ledger):
    pool read + min-dist read/write + weight read + per-block center
    re-fetch + (max, argmax) block partials."""
    nb = min(n_block, n)
    nn = -(-n // nb)
    np_ = nn * nb
    rp = _pad_to(max(r_block, 1), 8)
    pool = np_ * d * dtype_bytes
    vectors = 3 * 4 * np_                 # mind in, mind out, weights in
    centers = nn * rp * (d * 4 + 4)       # (Rp, d) tile + sel idx per block
    partials = nn * 2 * 4
    return pool + vectors + centers + partials


def tile_vmem_bytes(d: int, dtype_bytes: float, n_block: int,
                    r_block: int) -> float:
    """Per-grid-step VMEM: row tile (input dtype + f32 upcast), center tile,
    the (n_block, Rp) distance matrix, and the (N,) vector tiles."""
    rp = _pad_to(max(r_block, 1), 8)
    row = n_block * d * (dtype_bytes + 4)
    cen = rp * d * (dtype_bytes + 4)
    dist = n_block * rp * 4
    vecs = 4 * n_block * 4                # mind in/out, weights, iota masks
    return row + cen + dist + vecs


def _feasible(n: int, d: int, dtype_bytes: float, n_block: int,
              r_block: int) -> bool:
    return tile_vmem_bytes(d, dtype_bytes, n_block, r_block) \
        <= VMEM_BUDGET_BYTES


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def _measure_round(x, n_block: int, reps: int = 3) -> float:
    from repro.kernels.pairwise.kernel import greedy_round_pallas
    n = x.shape[0]
    mind = jnp.full((n,), 3.4e38, jnp.float32)
    sel = jnp.full((1,), -1, jnp.int32)
    c = x[:1]
    nm, _, _ = greedy_round_pallas(x, mind, c, sel, n_block=n_block)
    nm.block_until_ready()                # compile outside the timed region
    t0 = time.perf_counter()
    for _ in range(reps):
        nm, _, _ = greedy_round_pallas(x, nm, c, sel, n_block=n_block)
    nm.block_until_ready()
    return (time.perf_counter() - t0) / reps


def autotune_blocks(n: int, d: int, dtype=jnp.float32,
                    measure: Optional[bool] = None) -> BlockChoice:
    """Best (n_block, r_block) for an (N, d) pool of ``dtype``; cached."""
    dt = jnp.dtype(dtype)
    key = (int(n), int(d), dt.name)
    if key in _CACHE:
        return _CACHE[key]
    dtype_bytes = float(dt.itemsize)
    if measure is None:
        measure = _on_tpu()

    # n_block is scored on the single-center round (R = 1, the greedy-loop
    # hot path); ties in modeled bytes break to the LARGER block (fewer
    # grid steps and partials to reduce host-side).
    n_cands = [nb for nb in N_BLOCK_CANDIDATES
               if _feasible(n, d, dtype_bytes, nb, 8)] or \
        [N_BLOCK_CANDIDATES[0]]
    best_nb = min(n_cands,
                  key=lambda nb: (round_hbm_bytes(n, d, dtype_bytes, nb, 1),
                                  -nb))
    # r_block amortizes a warm-start pass over r centers: rank by modeled
    # bytes per folded center at the chosen n_block.
    r_cands = [rb for rb in R_BLOCK_CANDIDATES
               if _feasible(n, d, dtype_bytes, best_nb, rb)] or \
        [R_BLOCK_CANDIDATES[0]]
    best_rb = min(r_cands,
                  key=lambda rb: (round_hbm_bytes(n, d, dtype_bytes, best_nb,
                                                  rb) / rb, -rb))
    wall = 0.0
    source = "model"
    if measure:
        # re-rank the model's feasible n_block shortlist by wall clock
        import numpy as np
        x = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)), dtype)
        timed = {nb: _measure_round(x, nb) for nb in n_cands}
        best_nb = min(timed, key=timed.get)
        wall = timed[best_nb]
        source = "measured"
        # r_block feasibility depends on n_block: re-derive it at the
        # measured winner or the cached pair can blow the VMEM budget
        r_cands = [rb for rb in R_BLOCK_CANDIDATES
                   if _feasible(n, d, dtype_bytes, best_nb, rb)] or \
            [R_BLOCK_CANDIDATES[0]]
        best_rb = min(r_cands,
                      key=lambda rb: (round_hbm_bytes(n, d, dtype_bytes,
                                                      best_nb, rb) / rb, -rb))
    choice = BlockChoice(best_nb, best_rb,
                         round_hbm_bytes(n, d, dtype_bytes, best_nb, 1),
                         wall, source)
    _CACHE[key] = choice
    return choice


def report() -> Dict[Tuple[int, int, str], BlockChoice]:
    """Cached winners keyed by (N, d, dtype name) — for benchmark output."""
    return dict(_CACHE)


def clear_cache() -> None:
    _CACHE.clear()
