"""Block-size autotuner for the fused greedy-selection kernels.

``greedy_round_pallas`` has two launch parameters that trade HBM traffic
against VMEM pressure:

``n_block``
    Rows per grid step. The (Rp, d) center tile is re-fetched once per row
    block (its BlockSpec index map is constant), so small ``n_block`` means
    ceil(N / n_block) redundant center reads; large ``n_block`` grows the
    per-step VMEM footprint (row tile + (n_block, Rp) distance matrix) and
    eventually spills.

``r_block``
    Centers folded per fused pass in ``ops.warm_start_min_dist``. M centers
    cost ceil(M / r_block) full pool reads, so bytes-per-center shrinks
    monotonically with ``r_block`` until the center tile + distance matrix
    no longer fit the VMEM budget.

The tuner sweeps both over the same op-accounted HBM model the benchmarks
use (bytes actually moved per fused round), rejects candidates whose tiles
exceed the VMEM budget (~16 MB/core on TPU; we keep half as headroom for
double buffering), and — when a TPU is attached or ``measure=True`` —
re-ranks the model's shortlist by measured wall clock. Winners are cached
per (N, d, dtype) shape key; ``report()`` exposes the cache so benchmarks
can print the chosen blocks.

Winners also persist to a result directory (``REPRO_AUTOTUNE_CACHE_DIR``,
default ``~/.cache/repro/pairwise-autotune``; empty string disables) as
one small JSON per shape key, so measured picks survive process restarts —
and CI restores the directory across workflow runs with ``actions/cache``
instead of re-measuring every run. Corrupt or unreadable entries are
ignored and re-tuned.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

N_BLOCK_CANDIDATES = (64, 128, 256, 512, 1024)
R_BLOCK_CANDIDATES = (8, 32, 64, 128, 256, 512)

# ~16 MB VMEM per core; half of it as the tile budget leaves room for the
# compiler's double buffering of streamed inputs.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class BlockChoice:
    n_block: int
    r_block: int
    hbm_bytes: float          # modeled bytes per fused round at (n, r)
    wall_s: float             # measured s/round (0.0 when model-only)
    source: str               # "model" | "measured"


# keyed (n, d, dtype name, round variant): the plain fused round and the
# gated (block-masked) round have different per-step footprints — a VMEM
# budget that holds scalar-prefetch vectors and a winner that amortizes
# dead-block skips do NOT transfer between variants, so sharing one entry
# would serve one of them a wrong (possibly infeasible) block
_CACHE: Dict[Tuple[int, int, str, str], BlockChoice] = {}

VARIANTS = ("round", "gated")


def cache_dir() -> Optional[str]:
    """Result directory for persisted winners; None when disabled."""
    d = os.environ.get("REPRO_AUTOTUNE_CACHE_DIR")
    if d == "":
        return None
    return d or os.path.join(os.path.expanduser("~"), ".cache", "repro",
                             "pairwise-autotune")


def _disk_path(key: Tuple[int, int, str, str]) -> Optional[str]:
    d = cache_dir()
    if d is None:
        return None
    return os.path.join(d, f"n{key[0]}_d{key[1]}_{key[2]}_{key[3]}.json")


# bump when the candidate sets, the HBM/VMEM model, or the entry schema
# change: older persisted winners are then ignored and re-tuned instead of
# being trusted across a code change that invalidated them.
# format 2: the round variant joined the key AND the filename — format-1
# entries predate the gated round and could alias both variants
_DISK_FORMAT = 2


def _disk_load(key: Tuple[int, int, str, str]) -> Optional[BlockChoice]:
    path = _disk_path(key)
    if path is None or not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            raw = json.load(f)
        if raw.get("format") != _DISK_FORMAT:
            return None
        choice = BlockChoice(int(raw["n_block"]), int(raw["r_block"]),
                             float(raw["hbm_bytes"]), float(raw["wall_s"]),
                             str(raw["source"]))
    except (OSError, ValueError, KeyError, TypeError, AttributeError):
        return None     # corrupt entry: fall through and re-tune
    # never serve blocks the CURRENT candidate lists / VMEM model would
    # reject (a stale-but-well-formed entry from different code)
    n, d = key[0], key[1]
    dtype_bytes = float(jnp.dtype(key[2]).itemsize)
    if choice.n_block not in N_BLOCK_CANDIDATES \
            or choice.r_block not in R_BLOCK_CANDIDATES \
            or not _feasible(n, d, dtype_bytes, choice.n_block,
                             choice.r_block):
        return None
    return choice


def _disk_store(key: Tuple[int, int, str, str],
                choice: BlockChoice) -> None:
    path = _disk_path(key)
    if path is None:
        return
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        # write-then-rename so a killed run never leaves a torn entry for
        # the next (possibly cached-in-CI) run to trip over
        tmp = path + f".tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"format": _DISK_FORMAT,
                       **dataclasses.asdict(choice)}, f)
        os.replace(tmp, path)
    except OSError:
        pass            # persistence is best-effort; the run still has _CACHE


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


def round_hbm_bytes(n: int, d: int, dtype_bytes: float, n_block: int,
                    r_block: int) -> float:
    """Modeled HBM bytes of ONE fused greedy round (see kernel.py ledger):
    pool read + min-dist read/write + weight read + per-block center
    re-fetch + (max, argmax) block partials."""
    nb = min(n_block, n)
    nn = -(-n // nb)
    np_ = nn * nb
    rp = _pad_to(max(r_block, 1), 8)
    pool = np_ * d * dtype_bytes
    vectors = 3 * 4 * np_                 # mind in, mind out, weights in
    centers = nn * rp * (d * 4 + 4)       # (Rp, d) tile + sel idx per block
    partials = nn * 2 * 4
    return pool + vectors + centers + partials


def tile_vmem_bytes(d: int, dtype_bytes: float, n_block: int,
                    r_block: int) -> float:
    """Per-grid-step VMEM: row tile (input dtype + f32 upcast), center tile,
    the (n_block, Rp) distance matrix, and the (N,) vector tiles."""
    rp = _pad_to(max(r_block, 1), 8)
    row = n_block * d * (dtype_bytes + 4)
    cen = rp * d * (dtype_bytes + 4)
    dist = n_block * rp * 4
    vecs = 4 * n_block * 4                # mind in/out, weights, iota masks
    return row + cen + dist + vecs


def _feasible(n: int, d: int, dtype_bytes: float, n_block: int,
              r_block: int) -> bool:
    return tile_vmem_bytes(d, dtype_bytes, n_block, r_block) \
        <= VMEM_BUDGET_BYTES


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def _measure_round(x, n_block: int, variant: str = "round",
                   reps: int = 3) -> float:
    from repro.kernels.pairwise import kernel as _k
    n = x.shape[0]
    mind = jnp.full((n,), 3.4e38, jnp.float32)
    c = x[:1]
    if variant == "gated":
        # measure the gated round at full occupancy (every block live):
        # the worst case it must win at, and the shape-compatible one
        nn = -(-n // min(n_block, n))
        live = jnp.ones((nn,), jnp.int32)
        pend = jnp.zeros((nn,), jnp.int32)

        def run(m):
            return _k.gated_greedy_round_pallas(x, m, c, live, pend,
                                                n_block=n_block)
    else:
        sel = jnp.full((1,), -1, jnp.int32)

        def run(m):
            return _k.greedy_round_pallas(x, m, c, sel, n_block=n_block)

    nm, _, _ = run(mind)
    nm.block_until_ready()                # compile outside the timed region
    t0 = time.perf_counter()
    for _ in range(reps):
        nm, _, _ = run(nm)
    nm.block_until_ready()
    return (time.perf_counter() - t0) / reps


def autotune_blocks(n: int, d: int, dtype=jnp.float32,
                    measure: Optional[bool] = None,
                    variant: str = "round") -> BlockChoice:
    """Best (n_block, r_block) for an (N, d) pool of ``dtype``; cached
    per round ``variant`` ("round" = plain fused, "gated" =
    block-masked)."""
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}, "
                         f"got {variant!r}")
    dt = jnp.dtype(dtype)
    key = (int(n), int(d), dt.name, variant)
    if key in _CACHE:
        return _CACHE[key]
    dtype_bytes = float(dt.itemsize)
    if measure is None:
        measure = _on_tpu()
    # a persisted winner is reused when it is at least as informed as what
    # this process would produce: measured entries always, model-only
    # entries only for a model-only run (a TPU run re-measures and
    # overwrites a stale model pick rather than trusting it)
    disk = _disk_load(key)
    if disk is not None and (disk.source == "measured" or not measure):
        _CACHE[key] = disk
        return disk

    # n_block is scored on the single-center round (R = 1, the greedy-loop
    # hot path); ties in modeled bytes break to the LARGER block (fewer
    # grid steps and partials to reduce host-side).
    n_cands = [nb for nb in N_BLOCK_CANDIDATES
               if _feasible(n, d, dtype_bytes, nb, 8)] or \
        [N_BLOCK_CANDIDATES[0]]
    best_nb = min(n_cands,
                  key=lambda nb: (round_hbm_bytes(n, d, dtype_bytes, nb, 1),
                                  -nb))
    # r_block amortizes a warm-start pass over r centers: rank by modeled
    # bytes per folded center at the chosen n_block.
    r_cands = [rb for rb in R_BLOCK_CANDIDATES
               if _feasible(n, d, dtype_bytes, best_nb, rb)] or \
        [R_BLOCK_CANDIDATES[0]]
    best_rb = min(r_cands,
                  key=lambda rb: (round_hbm_bytes(n, d, dtype_bytes, best_nb,
                                                  rb) / rb, -rb))
    wall = 0.0
    source = "model"
    if measure:
        # re-rank the model's feasible n_block shortlist by wall clock
        import numpy as np
        x = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)), dtype)
        timed = {nb: _measure_round(x, nb, variant) for nb in n_cands}
        best_nb = min(timed, key=timed.get)
        wall = timed[best_nb]
        source = "measured"
        # r_block feasibility depends on n_block: re-derive it at the
        # measured winner or the cached pair can blow the VMEM budget
        r_cands = [rb for rb in R_BLOCK_CANDIDATES
                   if _feasible(n, d, dtype_bytes, best_nb, rb)] or \
            [R_BLOCK_CANDIDATES[0]]
        best_rb = min(r_cands,
                      key=lambda rb: (round_hbm_bytes(n, d, dtype_bytes,
                                                      best_nb, rb) / rb, -rb))
    choice = BlockChoice(best_nb, best_rb,
                         round_hbm_bytes(n, d, dtype_bytes, best_nb, 1),
                         wall, source)
    _CACHE[key] = choice
    _disk_store(key, choice)
    return choice


def report() -> Dict[Tuple[int, int, str, str], BlockChoice]:
    """Cached winners keyed by (N, d, dtype name, variant) — for benchmark
    output."""
    return dict(_CACHE)


def clear_cache() -> None:
    """Clear the in-memory cache only; persisted winners stay on disk (the
    next autotune_blocks reloads them, exactly like a fresh process)."""
    _CACHE.clear()
