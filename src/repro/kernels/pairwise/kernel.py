"""Pallas TPU kernel: fused pairwise-distance + running min/argmin.

The k-center / core-set inner loop needs min_j ||x_i - c_j||^2 over a large
center set without materializing the (N, M) distance matrix in HBM. Tiles
(N_b, d) x (M_b, d) hit the MXU via the -2*x@c^T term; the ||.||^2 terms and
the running (min, argmin) fold into the same pass through VMEM scratch.

Grid: (n_blocks, m_blocks); rows parallel, centers sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 3.4e38


def _kernel(x_ref, c_ref, mind_ref, argm_ref, acc_d, acc_i, *, nm: int,
            m: int, m_block: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_d[...] = jnp.full_like(acc_d, BIG)
        acc_i[...] = jnp.zeros_like(acc_i)

    x = x_ref[...].astype(jnp.float32)                  # (Nb, d)
    c = c_ref[...].astype(jnp.float32)                  # (Mb, d)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)         # (Nb, 1)
    c2 = jnp.sum(c * c, axis=-1)                        # (Mb,)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d = jnp.maximum(x2 + c2[None, :] - 2.0 * xc, 0.0)   # (Nb, Mb)
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1) + j * m_block
    d = jnp.where(col < m, d, BIG)

    bmin = jnp.min(d, axis=-1)
    barg = jnp.argmin(d, axis=-1).astype(jnp.int32) + j * m_block
    better = bmin < acc_d[...]
    acc_i[...] = jnp.where(better, barg, acc_i[...])
    acc_d[...] = jnp.where(better, bmin, acc_d[...])

    @pl.when(j == nm - 1)
    def _fin():
        mind_ref[...] = acc_d[...]
        argm_ref[...] = acc_i[...]


def pairwise_min_argmin_pallas(x, c, *, n_block: int = 256,
                               m_block: int = 256, interpret: bool = False):
    """x: (N,d), c: (M,d) -> (min_d (N,), argmin (N,)) fp32/int32."""
    N, d = x.shape
    M, _ = c.shape
    nb = min(n_block, N)
    mb = min(m_block, M)
    nn = -(-N // nb)
    nm = -(-M // mb)
    Np, Mp = nn * nb, nm * mb
    if Np != N:
        x = jnp.pad(x, ((0, Np - N), (0, 0)))
    if Mp != M:
        c = jnp.pad(c, ((0, Mp - M), (0, 0)))
    mind, argm = pl.pallas_call(
        functools.partial(_kernel, nm=nm, m=M, m_block=mb),
        grid=(nn, nm),
        in_specs=[
            pl.BlockSpec((nb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((mb, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((nb,), lambda i, j: (i,)),
            pl.BlockSpec((nb,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.float32),
            jax.ShapeDtypeStruct((Np,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nb,), jnp.float32),
            pltpu.VMEM((nb,), jnp.int32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, c)
    return mind[:N], argm[:N]
