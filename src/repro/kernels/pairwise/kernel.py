"""Pallas TPU kernels for pairwise-distance reductions and fused k-center
greedy selection rounds.

Two kernels live here:

``pairwise_min_argmin_pallas``
    min_j ||x_i - c_j||^2 (and its argmin) over a large center set without
    materializing the (N, M) distance matrix in HBM. Tiles (N_b, d) x
    (M_b, d) hit the MXU via the -2*x@c^T term; the ||.||^2 terms and the
    running (min, argmin) fold into the same pass through VMEM scratch.
    Grid: (n_blocks, m_blocks); rows parallel, centers sequential.

``greedy_round_pallas``
    One *fused* k-center greedy round. The unfused round re-streams the
    pool repeatedly:

        HBM traffic per round, unfused (N rows, d features, fp32):
          1. sq_dist_to_center      read (N, d) + write (N,)
          2. jnp.minimum            read 2x (N,) + write (N,)
          3. scatter winner mask    read/write (N,)
          4. jnp.argmax             read (N,)
        => one (N, d) pool read plus ~6 full (N,) vector streams, each a
        separate XLA op with its own HBM round trip.

        HBM traffic per round, fused (this kernel):
          1. one grid pass: read (N, d) + read (N,) min-dist + write (N,)
             min-dist + write 2 x (N / N_b) block partials
        => exactly ONE (N, d) pool read per selected center; everything
        else rides along in the same pass.

    Per (N_b, d) embedding tile the kernel (a) computes squared distances
    to the R queued centers held in VMEM, (b) folds them into the running
    min-dist in place, (c) masks already-selected indices to -1, and (d)
    emits per-block (max, argmax) partials of the (optionally weighted)
    min-dist. A tiny O(N / N_b) host-side reduction over the partials
    yields the next center — no second pass over the pool.

    The R-center ("multi-center") form is what makes the Core-Set
    warm-start cheap: M labeled centers fold into ceil(M / R) pool passes
    instead of one pass per center (see ``ops.warm_start_min_dist``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

BIG = 3.4e38


def _kernel(x_ref, c_ref, mind_ref, argm_ref, acc_d, acc_i, *, nm: int,
            m: int, m_block: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_d[...] = jnp.full_like(acc_d, BIG)
        acc_i[...] = jnp.zeros_like(acc_i)

    x = x_ref[...].astype(jnp.float32)                  # (Nb, d)
    c = c_ref[...].astype(jnp.float32)                  # (Mb, d)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)         # (Nb, 1)
    c2 = jnp.sum(c * c, axis=-1)                        # (Mb,)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d = jnp.maximum(x2 + c2[None, :] - 2.0 * xc, 0.0)   # (Nb, Mb)
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1) + j * m_block
    d = jnp.where(col < m, d, BIG)

    bmin = jnp.min(d, axis=-1)
    barg = jnp.argmin(d, axis=-1).astype(jnp.int32) + j * m_block
    better = bmin < acc_d[...]
    acc_i[...] = jnp.where(better, barg, acc_i[...])
    acc_d[...] = jnp.where(better, bmin, acc_d[...])

    @pl.when(j == nm - 1)
    def _fin():
        mind_ref[...] = acc_d[...]
        argm_ref[...] = acc_i[...]


def pairwise_min_argmin_pallas(x, c, *, n_block: int = 256,
                               m_block: int = 256, interpret: bool = False):
    """x: (N,d), c: (M,d) -> (min_d (N,), argmin (N,)) fp32/int32."""
    N, d = x.shape
    M, _ = c.shape
    nb = min(n_block, N)
    mb = min(m_block, M)
    nn = -(-N // nb)
    nm = -(-M // mb)
    Np, Mp = nn * nb, nm * mb
    if Np != N:
        x = jnp.pad(x, ((0, Np - N), (0, 0)))
    if Mp != M:
        c = jnp.pad(c, ((0, Mp - M), (0, 0)))
    mind, argm = pl.pallas_call(
        functools.partial(_kernel, nm=nm, m=M, m_block=mb),
        grid=(nn, nm),
        in_specs=[
            pl.BlockSpec((nb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((mb, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((nb,), lambda i, j: (i,)),
            pl.BlockSpec((nb,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.float32),
            jax.ShapeDtypeStruct((Np,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nb,), jnp.float32),
            pltpu.VMEM((nb,), jnp.int32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, c)
    return mind[:N], argm[:N]


def _greedy_kernel(x_ref, mind_ref, c_ref, sel_ref, w_ref,
                   nmind_ref, bmax_ref, barg_ref, *, n: int, r: int,
                   n_block: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                  # (Nb, d)
    c = c_ref[...].astype(jnp.float32)                  # (Rp, d)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)         # (Nb, 1)
    c2 = jnp.sum(c * c, axis=-1)                        # (Rp,)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d = jnp.maximum(x2 + c2[None, :] - 2.0 * xc, 0.0)   # (Nb, Rp)
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(col < r, d, BIG)

    nm = jnp.minimum(mind_ref[...], jnp.min(d, axis=-1))
    gid2 = jax.lax.broadcasted_iota(jnp.int32, d.shape, 0) + i * n_block
    hit = jnp.any(gid2 == sel_ref[...][None, :], axis=-1)
    nm = jnp.where(hit, -1.0, nm)
    nmind_ref[...] = nm

    # Selected (nm < 0) and padded rows are pinned to -BIG *before* the
    # weight multiply: with -1 * w a zero-weight masked row scores -0.0 and
    # ties (first-index wins) against legitimate zero-score rows, so a
    # masked row could win the argmax. -BIG can never tie a real score.
    score = nm * w_ref[...]
    valid = (gid2[:, 0] < n) & jnp.logical_not(nm < 0.0)
    mval = jnp.where(valid, score, -BIG)
    bmax_ref[...] = jnp.max(mval).reshape(1)
    barg_ref[...] = (jnp.argmax(mval).astype(jnp.int32)
                     + i * n_block).reshape(1)


def greedy_round_pallas(x, mind, centers, sel_idx, weights=None, *,
                        n_block: int = 256, interpret: bool = False):
    """One fused greedy round: fold ``centers`` into the running min-dist,
    mask ``sel_idx``, and return the next (weighted) farthest point.

    x: (N, d) pool; mind: (N,) running min sq-dist (selected rows already
    -1); centers: (R, d) newly queued centers; sel_idx: (R,) int32 pool
    indices to mask this round (-1 = no mask); weights: optional (N,)
    non-negative weights applied to the argmax score only — the returned
    min-dist is never weighted. Selected rows (new or carried-in -1) and
    padded rows score -BIG, so they cannot win the argmax even against
    zero-weight or zero-distance rows; exact score ties break to the
    lowest pool index independent of ``n_block`` (per-block argmax takes
    the first max in the block, the host reduction the first max block).

    Returns ``(new_mind (N,) f32, next_idx () i32, next_score () f32)``.
    """
    N, d = x.shape
    R = centers.shape[0]
    if sel_idx.shape[0] != R:
        raise ValueError(
            f"sel_idx must mask exactly the queued centers: got "
            f"{sel_idx.shape[0]} indices for {R} centers")
    nb = min(n_block, N)
    nn = -(-N // nb)
    Np = nn * nb
    Rp = -(-R // 8) * 8
    if Np != N:
        x = jnp.pad(x, ((0, Np - N), (0, 0)))
        mind = jnp.pad(mind, (0, Np - N))
    if Rp != R:
        centers = jnp.pad(centers, ((0, Rp - R), (0, 0)))
        sel_idx = jnp.pad(sel_idx, (0, Rp - R), constant_values=-1)
    w = (jnp.ones((Np,), jnp.float32) if weights is None
         else jnp.pad(weights.astype(jnp.float32), (0, Np - N)))
    nmind, bmax, barg = pl.pallas_call(
        functools.partial(_greedy_kernel, n=N, r=R, n_block=nb),
        grid=(nn,),
        in_specs=[
            pl.BlockSpec((nb, d), lambda i: (i, 0)),
            pl.BlockSpec((nb,), lambda i: (i,)),
            pl.BlockSpec((Rp, d), lambda i: (0, 0)),
            pl.BlockSpec((Rp,), lambda i: (0,)),
            pl.BlockSpec((nb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((nb,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.float32),
            jax.ShapeDtypeStruct((nn,), jnp.float32),
            jax.ShapeDtypeStruct((nn,), jnp.int32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, mind.astype(jnp.float32), centers.astype(jnp.float32),
      sel_idx.astype(jnp.int32), w)
    # O(N / N_b) reduction over block partials picks the next center.
    win = jnp.argmax(bmax)
    return nmind[:N], barg[win], bmax[win]


def _gated_kernel(live_ref, pend_ref, x_ref, mind_ref, c_ref, w_ref,
                  nmind_ref, bmax_ref, barg_ref, *, n: int, r: int,
                  n_block: int):
    i = pl.program_id(0)
    mind = mind_ref[...]
    live = live_ref[i] > 0

    @pl.when(live)
    def _eval():
        x = x_ref[...].astype(jnp.float32)              # (Nb, d)
        c = c_ref[...].astype(jnp.float32)              # (Rp, d)
        x2 = jnp.sum(x * x, axis=-1, keepdims=True)
        c2 = jnp.sum(c * c, axis=-1)
        xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        d = jnp.maximum(x2 + c2[None, :] - 2.0 * xc, 0.0)
        col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
        # catch-up masking: this block already folded centers
        # [0, pend[i]) in earlier rounds; fold only the queue's tail
        d = jnp.where((col >= pend_ref[i]) & (col < r), d, BIG)
        nm = jnp.minimum(mind, jnp.min(d, axis=-1))
        nmind_ref[...] = nm
        gid = (jax.lax.broadcasted_iota(jnp.int32, (n_block, 1), 0)[:, 0]
               + i * n_block)
        score = nm * w_ref[...]
        valid = (gid < n) & jnp.logical_not(nm < 0.0)
        mval = jnp.where(valid, score, -BIG)
        bmax_ref[...] = jnp.max(mval).reshape(1)
        barg_ref[...] = (jnp.argmax(mval).astype(jnp.int32)
                         + i * n_block).reshape(1)

    @pl.when(jnp.logical_not(live))
    def _skip():
        # dead block: min-dists pass through, partials can never win
        nmind_ref[...] = mind
        bmax_ref[...] = jnp.full((1,), -BIG, jnp.float32)
        barg_ref[...] = jnp.full((1,), i * n_block, jnp.int32)


def gated_greedy_round_pallas(x, mind, centers, block_live, block_pending,
                              weights=None, *, n_block: int = 256,
                              interpret: bool = False):
    """Block-masked greedy round: the centroid prefilter's TPU path.

    Same per-row math as ``greedy_round_pallas``, but two scalar-prefetch
    vectors steer the grid: ``block_live[b]`` gates whether block ``b`` is
    evaluated at all (a dead block's x-tile index map redirects to block 0,
    so its pool rows are never fetched from HBM), and ``block_pending[b]``
    is the first queued-center column the block has NOT folded yet — a
    block that skipped earlier rounds folds the centers it missed when its
    bound finally fails. Winner masking is host-side (mind[i] = -1.0).

    Returns ``(new_mind (N,), next_idx () i32, next_score () f32)`` where
    the argmax ranges over live, unmasked, unpadded rows only.
    """
    N, d = x.shape
    R = centers.shape[0]
    nb = min(n_block, N)
    nn = -(-N // nb)
    Np = nn * nb
    Rp = -(-R // 8) * 8
    if block_live.shape[0] != nn or block_pending.shape[0] != nn:
        raise ValueError(
            f"block vectors must have one entry per row block: got "
            f"{block_live.shape[0]}/{block_pending.shape[0]} for {nn}")
    if Np != N:
        x = jnp.pad(x, ((0, Np - N), (0, 0)))
        mind = jnp.pad(mind, (0, Np - N))
    if Rp != R:
        centers = jnp.pad(centers, ((0, Rp - R), (0, 0)))
    w = (jnp.ones((Np,), jnp.float32) if weights is None
         else jnp.pad(weights.astype(jnp.float32), (0, Np - N)))
    live = block_live.astype(jnp.int32)
    pend = block_pending.astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nn,),
        in_specs=[
            # dead blocks re-point their x tile at block 0: no HBM fetch
            # for the pool rows the gate pruned
            pl.BlockSpec((nb, d),
                         lambda i, lv, pd: (jnp.where(lv[i] > 0, i, 0), 0)),
            pl.BlockSpec((nb,), lambda i, lv, pd: (i,)),
            pl.BlockSpec((Rp, d), lambda i, lv, pd: (0, 0)),
            pl.BlockSpec((nb,), lambda i, lv, pd: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((nb,), lambda i, lv, pd: (i,)),
            pl.BlockSpec((1,), lambda i, lv, pd: (i,)),
            pl.BlockSpec((1,), lambda i, lv, pd: (i,)),
        ],
    )
    nmind, bmax, barg = pl.pallas_call(
        functools.partial(_gated_kernel, n=N, r=R, n_block=nb),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.float32),
            jax.ShapeDtypeStruct((nn,), jnp.float32),
            jax.ShapeDtypeStruct((nn,), jnp.int32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(live, pend, x, mind.astype(jnp.float32),
      centers.astype(jnp.float32), w)
    win = jnp.argmax(bmax)
    return nmind[:N], barg[win], bmax[win]
