"""Jit'd wrappers for pairwise distance reductions (kernel on TPU, jnp ref
elsewhere)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pairwise import ref
from repro.kernels.pairwise.kernel import pairwise_min_argmin_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("impl",))
def pairwise_min_dist(x, c, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ref.pairwise_min_dist_ref(x, c)
    return pairwise_min_argmin_pallas(x, c, interpret=(impl == "interpret"))[0]


@functools.partial(jax.jit, static_argnames=("impl",))
def pairwise_argmin(x, c, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ref.pairwise_argmin_ref(x, c)
    return pairwise_min_argmin_pallas(x, c, interpret=(impl == "interpret"))[1]


@jax.jit
def pairwise_sq_dists(x, c):
    """Full (N, M) matrix — only for small M (DBAL centroid matching)."""
    return ref.pairwise_sq_dists_ref(x, c)


@jax.jit
def sq_dist_to_center(x, center):
    diff = x.astype(jnp.float32) - center.astype(jnp.float32)[None, :]
    return jnp.sum(diff * diff, axis=-1)
