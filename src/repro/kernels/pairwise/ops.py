"""Wrappers for pairwise distance reductions and fused greedy-selection
rounds (Pallas kernel on TPU, jnp ref elsewhere).

Besides impl dispatch ("auto" / "ref" / "interpret" / "pallas"), this layer
does HBM-pass accounting: inside ``track_ops()`` every wrapper records how
many full (N, d) embedding-pool reads and full (N,) vector streams it
issues, so benchmarks can verify the fused greedy round really costs one
pool read per selected center (see kernel.py for the per-round ledger).
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pairwise import autotune, ref
from repro.kernels.pairwise.kernel import (BIG, greedy_round_pallas,
                                           pairwise_min_argmin_pallas)


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


# ------------------------------------------------------- op accounting ----
# ``pool_rows`` counts POOL ROWS TOUCHED: rows whose feature vector (or
# probs row) a selection pass actually read/scored. The centroid prefilter's
# ≥10x claim is stated in these units — a gated pass records only the rows
# of blocks whose centroid survived the bound check.
_STATS = {"embedding_reads": 0, "vector_streams": 0, "hbm_bytes": 0,
          "pool_rows": 0}
_TRACKING = [False]


def reset_op_stats() -> None:
    for k in _STATS:
        _STATS[k] = 0


def op_stats() -> dict:
    return dict(_STATS)


@contextlib.contextmanager
def track_ops():
    """Count embedding-pool reads / vector streams issued while active.

    Only Python-level calls are counted (ops invoked from inside a traced
    ``fori_loop`` body trace once) — drive rounds from a Python loop when
    accounting, as the microbenchmark does.
    """
    reset_op_stats()
    _TRACKING[0] = True
    try:
        yield _STATS
    finally:
        _TRACKING[0] = False


def _record(x, emb_reads: int = 0, vec_streams: int = 0) -> None:
    if not _TRACKING[0]:
        return
    n, d = x.shape
    _STATS["embedding_reads"] += emb_reads
    _STATS["vector_streams"] += vec_streams
    _STATS["hbm_bytes"] += 4 * (emb_reads * n * d + vec_streams * n)
    _STATS["pool_rows"] += emb_reads * n


def record_pool_rows(n: int) -> None:
    """Explicit pool-rows-touched tally for passes that do not flow through
    an (N, d) wrapper here (uncertainty scoring over probs rows, gated
    cluster scans)."""
    if _TRACKING[0]:
        _STATS["pool_rows"] += int(n)


# ------------------------------------------------- pairwise reductions ----
@functools.partial(jax.jit, static_argnames=("impl",))
def _pairwise_min_and_argmin(x, c, impl: str):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ref.pairwise_min_and_argmin_ref(x, c)
    return pairwise_min_argmin_pallas(x, c, interpret=(impl == "interpret"))


def pairwise_min_and_argmin(x, c, impl: str = "auto"):
    """Both (min_d (N,), argmin (N,)) from ONE kernel launch — call-sites
    needing the pair must not pay two pool passes."""
    _record(x, emb_reads=1, vec_streams=2)
    return _pairwise_min_and_argmin(x, c, impl)


def pairwise_min_dist(x, c, impl: str = "auto"):
    return pairwise_min_and_argmin(x, c, impl)[0]


def pairwise_argmin(x, c, impl: str = "auto"):
    return pairwise_min_and_argmin(x, c, impl)[1]


@jax.jit
def _pairwise_sq_dists(x, c):
    return ref.pairwise_sq_dists_ref(x, c)


def pairwise_sq_dists(x, c):
    """Full (N, M) matrix — only for small M (DBAL centroid matching)."""
    _record(x, emb_reads=1)
    return _pairwise_sq_dists(x, c)


@jax.jit
def _sq_dist_to_center(x, center):
    diff = x.astype(jnp.float32) - center.astype(jnp.float32)[None, :]
    return jnp.sum(diff * diff, axis=-1)


def sq_dist_to_center(x, center):
    _record(x, emb_reads=1, vec_streams=1)
    return _sq_dist_to_center(x, center)


# ---------------------------------------------- fused greedy selection ----
@functools.partial(jax.jit, static_argnames=("impl", "n_block"))
def _greedy_round(x, mind, centers, sel_idx, weights, impl: str,
                  n_block: int):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ref.greedy_round_ref(x, mind, centers, sel_idx, weights)
    return greedy_round_pallas(x, mind, centers, sel_idx, weights,
                               n_block=n_block,
                               interpret=(impl == "interpret"))


def autotuned_blocks(n: int, d: int, dtype=jnp.float32):
    """The autotuner's cached (n_block, r_block) winner for this shape."""
    return autotune.autotune_blocks(n, d, dtype)


def masked_weighted_score(mind, weights=None):
    """Host-side mirror of the fused round's argmax score rule: selected
    rows (mind < 0) pin to -BIG BEFORE the weight multiply. Every pre-loop
    argmax must use this, never re-derive it — drifting from the kernel's
    in-round rule is how masked rows leak back into selections."""
    score = mind if weights is None else mind * weights
    return jnp.where(mind < 0.0, -BIG, score)


def greedy_round(x, mind, centers, sel_idx, weights=None, impl: str = "auto",
                 n_block: int | None = None):
    """One fused greedy round: one (N, d) pool read folds the (R, d) queued
    ``centers`` into ``mind``, masks ``sel_idx``, and returns the next
    (weighted) farthest point. -> (new_mind, next_idx, next_score).
    ``n_block=None`` uses the autotuned block for (N, d, dtype)."""
    if sel_idx.shape[0] != centers.shape[0]:
        # enforce the contract on EVERY dispatch path (the ref oracle would
        # otherwise silently leave queued centers unmasked on CPU)
        raise ValueError(
            f"sel_idx must mask exactly the queued centers: got "
            f"{sel_idx.shape[0]} indices for {centers.shape[0]} centers")
    if n_block is None:
        n_block = autotune.autotune_blocks(x.shape[0], x.shape[1],
                                           x.dtype).n_block
    _record(x, emb_reads=1, vec_streams=2)
    return _greedy_round(x, mind, centers, sel_idx, weights, impl, n_block)


@jax.jit
def _greedy_round_unfused(x, mind, center, sel_idx):
    d = _sq_dist_to_center(x, center)
    nm = jnp.minimum(mind, d)
    nm = nm.at[sel_idx].set(-1.0)
    nxt = jnp.argmax(nm).astype(jnp.int32)
    return nm, nxt, nm[nxt]


def greedy_round_unfused(x, mind, center, sel_idx):
    """The pre-fusion round (distance pass, minimum pass, scatter, argmax
    pass as separate XLA ops) — kept as the microbenchmark baseline."""
    _record(x, emb_reads=1, vec_streams=6)
    return _greedy_round_unfused(x, mind, center, sel_idx)


@functools.partial(jax.jit, static_argnames=("impl", "n_block"))
def _gated_greedy_round(x, mind, centers, block_live, block_pending,
                        weights, impl: str, n_block: int):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ref.gated_greedy_round_ref(x, mind, centers, block_live,
                                          block_pending, weights,
                                          n_block=n_block)
    from repro.kernels.pairwise.kernel import gated_greedy_round_pallas
    return gated_greedy_round_pallas(x, mind, centers, block_live,
                                     block_pending, weights, n_block=n_block,
                                     interpret=(impl == "interpret"))


def gated_greedy_round(x, mind, centers, block_live, block_pending,
                       weights=None, impl: str = "auto", n_block: int = 256):
    """The BLOCK-MASKED round variant behind the centroid prefilter.

    Folds queued ``centers`` (R, d) into ``mind`` for LIVE row blocks only:
    block ``b`` (rows ``[b*n_block, (b+1)*n_block)``) is touched iff
    ``block_live[b]``, and folds only centers ``[block_pending[b]:R)`` —
    blocks skipped in earlier rounds catch up on the centers they missed
    when their centroid bound finally fails. Dead blocks pass ``mind``
    through untouched and emit -BIG partials, so the returned argmax ranges
    over live rows only. Winner masking stays host-side (set the winner's
    ``mind`` slot to -1.0): the caller owns per-block center bookkeeping,
    so it owns row masking too.

    Returns ``(new_mind, next_idx, next_score)`` like ``greedy_round``.
    Accounting: only live-block rows count as pool rows touched.
    """
    nb = int(n_block)
    N = x.shape[0]
    nn = -(-N // min(nb, max(N, 1)))
    live = np.asarray(block_live)
    if live.shape[0] != nn:
        raise ValueError(f"block_live has {live.shape[0]} entries for "
                         f"{nn} blocks of {nb} rows over {N}")
    if _TRACKING[0]:
        rows = int(sum(min(nb, N - b * nb) for b in np.nonzero(live)[0]))
        _STATS["pool_rows"] += rows
        _STATS["embedding_reads"] += 1 if rows else 0
        _STATS["vector_streams"] += 2
        _STATS["hbm_bytes"] += 4 * (rows * x.shape[1] + 2 * N)
    return _gated_greedy_round(x, mind, centers,
                               jnp.asarray(live, jnp.int32),
                               jnp.asarray(block_pending, jnp.int32),
                               weights, impl, nb)


def warm_start_min_dist(x, centers, impl: str = "auto",
                        r_block: int | None = None):
    """Min sq-dist from every pool row to ANY of (M, d) ``centers`` —
    the Core-Set warm start. Folds up to ``r_block`` centers per fused
    pass: ceil(M / r_block) pool reads instead of one per center.
    ``r_block=None`` uses the autotuned block for (N, d, dtype)."""
    if r_block is None:
        r_block = autotune.autotune_blocks(x.shape[0], x.shape[1],
                                           x.dtype).r_block
    N = x.shape[0]
    M = centers.shape[0]
    mind = jnp.full((N,), BIG, jnp.float32)
    for s in range(0, M, r_block):
        chunk = centers[s:s + r_block]
        mind = greedy_round(x, mind, chunk,
                            jnp.full((chunk.shape[0],), -1, jnp.int32),
                            impl=impl)[0]
    return mind
