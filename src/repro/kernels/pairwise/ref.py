"""Pure-jnp oracle for pairwise squared-distance reductions."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_sq_dists_ref(x, c):
    """x: (N,d), c: (M,d) -> (N,M) squared L2 distances (fp32)."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d = x2 + c2[None, :] - 2.0 * (x @ c.T)
    return jnp.maximum(d, 0.0)


def pairwise_min_dist_ref(x, c):
    return jnp.min(pairwise_sq_dists_ref(x, c), axis=-1)


def pairwise_argmin_ref(x, c):
    return jnp.argmin(pairwise_sq_dists_ref(x, c), axis=-1).astype(jnp.int32)
