"""Pure-jnp oracle for pairwise squared-distance reductions and the fused
k-center greedy round."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_sq_dists_ref(x, c):
    """x: (N,d), c: (M,d) -> (N,M) squared L2 distances (fp32)."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d = x2 + c2[None, :] - 2.0 * (x @ c.T)
    return jnp.maximum(d, 0.0)


def pairwise_min_dist_ref(x, c):
    return jnp.min(pairwise_sq_dists_ref(x, c), axis=-1)


def pairwise_argmin_ref(x, c):
    return jnp.argmin(pairwise_sq_dists_ref(x, c), axis=-1).astype(jnp.int32)


def pairwise_min_and_argmin_ref(x, c):
    d = pairwise_sq_dists_ref(x, c)
    return jnp.min(d, axis=-1), jnp.argmin(d, axis=-1).astype(jnp.int32)


BIG = 3.4e38


def greedy_round_ref(x, mind, centers, sel_idx, weights=None):
    """Oracle for ``greedy_round_pallas`` (same contract; see kernel.py).

    Weights only scale the argmax score; selected rows (nm < 0) are pinned
    to -BIG so they can never win — not even with zero weights, where
    -1 * 0 would tie legitimate zero-score rows.
    """
    N = x.shape[0]
    if centers.shape[0] == 1:
        # broadcast-diff beats the matmul identity for a single center and
        # matches the pre-fusion round bit-for-bit
        diff = x.astype(jnp.float32) - centers[0].astype(jnp.float32)[None, :]
        dmin = jnp.sum(diff * diff, axis=-1)
    else:
        dmin = jnp.min(pairwise_sq_dists_ref(x, centers), axis=-1)
    nm = jnp.minimum(mind.astype(jnp.float32), dmin)
    hit = jnp.any(jnp.arange(N)[:, None] == sel_idx[None, :], axis=-1)
    nm = jnp.where(hit, -1.0, nm)
    score = nm if weights is None else nm * weights.astype(jnp.float32)
    score = jnp.where(nm < 0.0, -BIG, score)
    nxt = jnp.argmax(score).astype(jnp.int32)
    return nm, nxt, score[nxt]


def gated_greedy_round_ref(x, mind, centers, block_live, block_pending,
                           weights=None, *, n_block: int = 256):
    """Oracle for ``gated_greedy_round_pallas`` (same contract; see
    kernel.py). Vectorized over ALL rows with block/column masking — it
    physically touches the whole pool, so it is a correctness oracle for
    the kernel's parity tests, not a sublinear path (the engine's CPU path
    slices live segments exactly instead of calling this)."""
    N = x.shape[0]
    R = centers.shape[0]
    d2 = pairwise_sq_dists_ref(x, centers)                    # (N, R)
    row = jnp.arange(N)
    blk = (row // n_block).astype(jnp.int32)
    live = block_live[blk] > 0                                # (N,)
    pend = block_pending[blk]                                 # (N,)
    col = jnp.arange(R)[None, :]
    d2 = jnp.where(col >= pend[:, None], d2, BIG)             # catch-up mask
    fold = jnp.minimum(mind.astype(jnp.float32), jnp.min(d2, axis=-1))
    nm = jnp.where(live, fold, mind.astype(jnp.float32))
    score = nm if weights is None else nm * weights.astype(jnp.float32)
    score = jnp.where(live & jnp.logical_not(nm < 0.0), score, -BIG)
    nxt = jnp.argmax(score).astype(jnp.int32)
    return nm, nxt, score[nxt]
