"""Oracle for the flash-attention kernel: the naive attention from the model
layer (same masking semantics)."""
from __future__ import annotations

from typing import Optional

from repro.models.layers.attention import naive_attention


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        kv_valid=None, scale: Optional[float] = None):
    """q: (B,Sq,H,D); k,v: (B,Skv,KH,D) -> (B,Sq,H,D)."""
    return naive_attention(q, k, v, causal=causal, window=window,
                           kv_valid=kv_valid, scale=scale)
