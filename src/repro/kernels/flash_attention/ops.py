"""Wrapper: pallas flash attention on TPU, chunked-jnp fallback elsewhere."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def flash_attention_auto(q, k, v, *, causal: bool = True,
                         window: Optional[int] = None,
                         scale: Optional[float] = None, **chunk_kw):
    if _on_tpu():
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      scale=scale)
    from repro.models.layers.attention import chunked_attention
    return chunked_attention(q, k, v, causal=causal, window=window,
                             scale=scale,
                             q_chunk=chunk_kw.get("q_chunk", 512),
                             kv_chunk=chunk_kw.get("kv_chunk", 1024))
