"""Pallas TPU flash-attention (forward) with GQA, causal and local-window
masking.

Grid: (B, H, q_blocks, kv_blocks) — first three parallel, kv sequential.
Online-softmax carry (m, l, acc) lives in VMEM scratch; K/V blocks are
indexed at h // G so grouped query heads share one KV stream (GQA without
materializing repeated KV). Block shapes default to (128, head_dim) tiles —
MXU-aligned for head_dim in {64, 128, 256}.

Serving-path kernel: forward only (training uses the chunked jnp attention,
which XLA differentiates; see DESIGN.md §kernels).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            nk: int, qb: int, kb: int, skv: int, scale: float,
            causal: bool, window: Optional[int]):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, :, 0, :].astype(jnp.float32)            # (qb, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (kb, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qi = pl.program_id(2)
    q_pos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    k_pos = j * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    mask = k_pos < skv
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG)

    m_prev = m_s[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_cur[:, None])
    corr = jnp.exp(m_prev - m_cur)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_cur

    @pl.when(j == nk - 1)
    def _fin():
        l = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_s[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           q_block: int = 128, kv_block: int = 128,
                           interpret: bool = False):
    """q: (B,Sq,H,D); k,v: (B,Skv,KH,D) -> (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else D ** -0.5
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    nq = -(-Sq // qb)
    nk = -(-Skv // kb)
    if nq * qb != Sq:
        q = jnp.pad(q, ((0, 0), (0, nq * qb - Sq), (0, 0), (0, 0)))
    if nk * kb != Skv:
        k = jnp.pad(k, ((0, 0), (0, nk * kb - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, nk * kb - Skv), (0, 0), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, qb=qb, kb=kb, skv=Skv, scale=scale,
                          causal=causal, window=window),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, kb, 1, D),
                         lambda b, h, i, j, G=G: (b, j, h // G, 0)),
            pl.BlockSpec((1, kb, 1, D),
                         lambda b, h, i, j, G=G: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq * qb, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, D), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
