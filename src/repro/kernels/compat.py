"""Version shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; support
both spellings so the kernels run against whichever jax the host bakes in.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
