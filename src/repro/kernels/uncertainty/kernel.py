"""Pallas TPU kernel: fused uncertainty scores over the vocab axis.

One streaming pass over (R_b, V_b) VMEM tiles of the logits, carrying
per-row online statistics in VMEM scratch across the sequential vocab grid
axis: running max m1, runner-up m2, shifted sum-exp, and shifted
sum(l * exp(l)) — everything LC/MC/RC/ES need, with no (N, V) softmax ever
materialized in HBM. This is the AL serving hot-spot when the scorer is an
LLM (V up to 256k): arithmetic intensity is O(1) per logit, so the kernel's
job is to keep the pass memory-bound at exactly one HBM read of the logits.

Grid: (row_blocks, vocab_blocks); rows parallel, vocab sequential
(dimension_semantics = ("parallel", "arbitrary")).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG = -1e30


def _kernel(logits_ref, out_ref, m1, m2, se, sl, *, nv: int, v: int,
            v_block: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m1[...] = jnp.full_like(m1, NEG)
        m2[...] = jnp.full_like(m2, NEG)
        se[...] = jnp.zeros_like(se)
        sl[...] = jnp.zeros_like(sl)

    lg = logits_ref[...].astype(jnp.float32)            # (R, Vb)
    # mask the vocab-padding tail
    col = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 1) + j * v_block
    lg = jnp.where(col < v, lg, NEG)

    bm1 = jnp.max(lg, axis=-1)                          # block max
    # block runner-up: max over the block with the argmax knocked out
    is_max = lg == bm1[:, None]
    # knock out exactly one occurrence (leftmost) of the max
    first_max = jnp.cumsum(is_max.astype(jnp.int32), axis=-1) == 1
    knock = is_max & first_max
    bm2 = jnp.max(jnp.where(knock, NEG, lg), axis=-1)

    om1, om2 = m1[...], m2[...]
    nm1 = jnp.maximum(om1, bm1)
    # new runner-up = max of remaining candidates
    nm2 = jnp.maximum(jnp.maximum(jnp.minimum(om1, bm1), om2), bm2)

    scale = jnp.exp(om1 - nm1)                          # rescale old sums
    e = jnp.exp(lg - nm1[:, None])
    e = jnp.where(col < v, e, 0.0)
    se[...] = se[...] * scale + jnp.sum(e, axis=-1)
    sl[...] = sl[...] * scale + jnp.sum(e * lg, axis=-1)
    m1[...] = nm1
    m2[...] = nm2

    @pl.when(j == nv - 1)
    def _fin():
        lse = m1[...] + jnp.log(jnp.maximum(se[...], 1e-30))
        p1 = jnp.exp(m1[...] - lse)
        p2 = jnp.exp(m2[...] - lse)
        ent = lse - sl[...] / jnp.maximum(se[...], 1e-30)
        out_ref[0, ...] = 1.0 - p1                      # lc
        out_ref[1, ...] = -(p1 - p2)                    # mc
        out_ref[2, ...] = p2 / jnp.maximum(p1, 1e-12)   # rc
        out_ref[3, ...] = ent                           # es


def uncertainty_stats_pallas(logits, *, row_block: int = 256,
                             v_block: int = 2048, interpret: bool = False):
    """logits: (N, V) -> (4, N) fp32 rows = [lc, mc, rc, es]."""
    N, V = logits.shape
    rb = min(row_block, N)
    vb = min(v_block, V)
    nr = -(-N // rb)
    nv = -(-V // vb)
    Np, Vp = nr * rb, nv * vb
    if (Np, Vp) != (N, V):
        logits = jnp.pad(logits, ((0, Np - N), (0, Vp - V)),
                         constant_values=NEG)
    out = pl.pallas_call(
        functools.partial(_kernel, nv=nv, v=V, v_block=vb),
        grid=(nr, nv),
        in_specs=[pl.BlockSpec((rb, vb), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((4, rb), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((4, Np), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((rb,), jnp.float32),
            pltpu.VMEM((rb,), jnp.float32),
            pltpu.VMEM((rb,), jnp.float32),
            pltpu.VMEM((rb,), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(logits)
    return out[:, :N]
