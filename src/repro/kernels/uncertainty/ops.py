"""Jit'd wrapper for fused uncertainty scoring.

impl="auto" uses the Pallas kernel on TPU and the jnp reference elsewhere
(interpret-mode Pallas is for validation, not speed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.uncertainty import ref
from repro.kernels.uncertainty.kernel import uncertainty_stats_pallas

KINDS = ("lc", "mc", "rc", "es")


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("kind", "impl"))
def uncertainty_scores(logits, kind: str = "lc", impl: str = "auto"):
    """logits: (N, V) -> (N,) fp32 scores (higher = more informative)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ref.uncertainty_scores_ref(logits, kind)
    stats = uncertainty_stats_pallas(logits, interpret=(impl == "interpret"))
    return stats[KINDS.index(kind)]


@functools.partial(jax.jit, static_argnames=("impl",))
def uncertainty_stats(logits, impl: str = "auto"):
    """All four scores in one pass: dict of (N,) fp32."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ref.uncertainty_stats_ref(logits)
    stats = uncertainty_stats_pallas(logits, interpret=(impl == "interpret"))
    return {k: stats[i] for i, k in enumerate(KINDS)}
