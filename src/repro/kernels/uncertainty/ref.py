"""Pure-jnp oracle for fused uncertainty scoring over logits."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def uncertainty_stats_ref(logits):
    """logits: (N, V) -> dict of per-row scores (fp32).

    lc = 1 - p_max; mc = -(p1 - p2); rc = p2/p1; es = entropy(softmax).
    """
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    top2 = jax.lax.top_k(lg, 2)[0]
    p1 = jnp.exp(top2[:, 0] - lse)
    p2 = jnp.exp(top2[:, 1] - lse)
    p = jax.nn.softmax(lg, axis=-1)
    es = -jnp.sum(jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-30)), 0.0),
                  axis=-1)
    return {
        "lc": 1.0 - p1,
        "mc": -(p1 - p2),
        "rc": p2 / jnp.maximum(p1, 1e-12),
        "es": es,
    }


def uncertainty_scores_ref(logits, kind: str):
    return uncertainty_stats_ref(logits)[kind]
