"""Pallas TPU decode attention (flash-decode style): one query token against
a long KV cache, KV-block sequential with online softmax, valid-length
masking via scalar prefetch.

Grid: (B, KH, kv_blocks). The G grouped query heads of each KV head are
processed together as the (G, D) left operand of the MXU dots — this turns
GQA decode into dense (G x D) @ (D x kb) matmuls instead of G vector-matrix
products, the standard v5e trick for batch-1-friendly decode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            nk: int, kb: int, scale: float, window: Optional[int]):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    cur_len = len_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (kb, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = j * kb + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = k_pos < cur_len
    if window is not None:
        ok &= k_pos > cur_len - 1 - window
    s = jnp.where(ok, s, NEG)

    m_prev = m_s[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_cur[:, None])
    corr = jnp.exp(m_prev - m_cur)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_cur

    @pl.when(j == nk - 1)
    def _fin():
        l = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0] = (acc_s[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, cur_len, *,
                            window: Optional[int] = None,
                            scale: Optional[float] = None,
                            kv_block: int = 256, interpret: bool = False):
    """q: (B,1,H,D); caches (B,S,KH,D); cur_len: int32 scalar/array.

    Returns (B,1,H,D)."""
    B, _, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = scale if scale is not None else D ** -0.5
    kb = min(kv_block, S)
    nk = -(-S // kb)
    if nk * kb != S:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, nk * kb - S), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, nk * kb - S), (0, 0), (0, 0)))
    qg = q.reshape(B, KH, G, D)
    cur = jnp.asarray(cur_len, jnp.int32).reshape((1,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KH, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, kb, 1, D), lambda b, h, j, *_: (b, j, h, 0)),
            pl.BlockSpec((1, kb, 1, D), lambda b, h, j, *_: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, kb=kb, scale=scale, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cur, qg, k_cache, v_cache)
    return out.reshape(B, 1, H, D)
