"""Wrapper: pallas decode attention on TPU, fused-jnp fallback elsewhere."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def decode_attention_auto(q, k_cache, v_cache, cur_len, *,
                          window: Optional[int] = None, scale=None):
    if _on_tpu():
        return decode_attention_pallas(q, k_cache, v_cache, cur_len,
                                       window=window, scale=scale)
    from repro.models.layers.attention import decode_attention
    return decode_attention(q, k_cache, v_cache, cur_len, window=window,
                            scale=scale)
