"""Oracle for the decode-attention kernel."""
from __future__ import annotations

from repro.models.layers.attention import decode_attention


def decode_attention_ref(q, k_cache, v_cache, cur_len, *, window=None,
                         scale=None):
    """q: (B,1,H,D); caches (B,S,KH,D); cur_len valid entries."""
    return decode_attention(q, k_cache, v_cache, cur_len, window=window,
                            scale=scale)
