"""Host->device double-buffered prefetch.

The device-side analogue of the paper's stage pipeline: while step i
computes, batch i+1 is already being decoded and transferred. On real
multi-host TPU this hides the host input pipeline entirely; the pattern is
identical on CPU.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax


def prefetch_to_device(it: Iterator, size: int = 2,
                       device_put: Optional[Callable] = None) -> Iterator:
    device_put = device_put or jax.device_put
    q: "queue.Queue" = queue.Queue(maxsize=size)
    sentinel = object()

    def producer():
        try:
            for item in it:
                q.put(device_put(item))
        finally:
            q.put(sentinel)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is sentinel:
            return
        yield item
