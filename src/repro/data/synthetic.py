"""Synthetic datasets: LM token pools and CIFAR-like image pools.

Deterministic in seed; used by smoke tests, benchmarks, and examples (no
dataset downloads in this offline environment — documented in DESIGN.md).
The image pool plants a class-dependent localized activation so a frozen
random feature extractor + trained head genuinely separates classes, making
AL-strategy accuracy differences (paper Fig. 4a) measurable.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def lm_pool(n_seqs: int, seq_len: int, vocab: int, seed: int = 0,
            n_domains: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Token sequences from ``n_domains`` Markov-ish generators; returns
    (tokens (n, S) int32, domain_id (n,) int32). Domains give diversity
    structure for AL to find."""
    rng = np.random.default_rng(seed)
    dom = rng.integers(0, n_domains, n_seqs)
    base = rng.integers(0, vocab, (n_domains, 64))
    toks = np.empty((n_seqs, seq_len), np.int32)
    for i in range(n_seqs):
        table = base[dom[i]]
        walk = rng.integers(0, 64, seq_len)
        drift = rng.integers(0, vocab, seq_len)
        mix = rng.random(seq_len) < 0.15
        toks[i] = np.where(mix, drift, table[walk])
    return toks, dom.astype(np.int32)


def image_pool(n: int, num_classes: int = 10, hw: int = 8, seed: int = 0,
               noise: float = 0.15) -> Tuple[np.ndarray, np.ndarray]:
    """(x (n,hw,hw,3) f32, y (n,) i32) with class-dependent signal."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, num_classes, n)
    x = rng.normal(size=(n, hw, hw, 3)).astype(np.float32) * noise
    for c in range(num_classes):
        m = y == c
        x[m, c % hw, (c * 3) % hw, c % 3] += 2.5
        x[m, (c * 2) % hw, c % hw, (c + 1) % 3] += 1.5
    return x, y.astype(np.int32)


def lm_batches(tokens: np.ndarray, batch: int, seed: int = 0,
               shard_index: int = 0, num_shards: int = 1
               ) -> Iterator[dict]:
    """Infinite shuffled batches of {tokens, labels} (labels = next token).

    Per-host sharding: each host sees a disjoint slice (the multi-host data
    pipeline contract; on CPU num_shards=1)."""
    n = tokens.shape[0]
    mine = np.arange(shard_index, n, num_shards)
    rng = np.random.default_rng(seed + shard_index)
    while True:
        order = rng.permutation(mine)
        for i in range(0, len(order) - batch + 1, batch):
            sel = order[i:i + batch]
            t = tokens[sel]
            yield {
                "tokens": t[:, :-1].astype(np.int32),
                "labels": t[:, 1:].astype(np.int32),
            }
