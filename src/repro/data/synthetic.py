"""Synthetic datasets: LM token pools and CIFAR-like image pools.

Deterministic in seed; used by smoke tests, benchmarks, and examples (no
dataset downloads in this offline environment — documented in DESIGN.md).
The image pool plants a class-dependent localized activation so a frozen
random feature extractor + trained head genuinely separates classes, making
AL-strategy accuracy differences (paper Fig. 4a) measurable.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def lm_pool(n_seqs: int, seq_len: int, vocab: int, seed: int = 0,
            n_domains: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Token sequences from ``n_domains`` Markov-ish generators; returns
    (tokens (n, S) int32, domain_id (n,) int32). Domains give diversity
    structure for AL to find."""
    rng = np.random.default_rng(seed)
    dom = rng.integers(0, n_domains, n_seqs)
    base = rng.integers(0, vocab, (n_domains, 64))
    toks = np.empty((n_seqs, seq_len), np.int32)
    for i in range(n_seqs):
        table = base[dom[i]]
        walk = rng.integers(0, 64, seq_len)
        drift = rng.integers(0, vocab, seq_len)
        mix = rng.random(seq_len) < 0.15
        toks[i] = np.where(mix, drift, table[walk])
    return toks, dom.astype(np.int32)


def text_pool(n: int, num_classes: int = 10, seq_len: int = 64,
              vocab: int = 512, seed: int = 0
              ) -> Tuple[np.ndarray, np.ndarray]:
    """(tokens (n, seq_len) i32 right-padded with -1, y (n,) i32).

    Variable-length sequences (half to full ``seq_len``) of uniform noise
    tokens with a class-specific 8-token motif planted on most 8-aligned
    spans — a frozen random transformer mean-pools those motifs into
    linearly separable features, the text analogue of ``image_pool``'s
    localized activations. Fixed-width rows (pad = -1) so pushed items
    share one shape per batch (the ingest pipeline stacks raw items)."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, num_classes, n)
    lengths = rng.integers(max(seq_len // 2, 1), seq_len + 1, n)
    motifs = rng.integers(0, vocab, (num_classes, 8))
    toks = np.full((n, seq_len), -1, np.int32)
    for i in range(n):
        L = int(lengths[i])
        t = rng.integers(0, vocab, L).astype(np.int32)
        for s in range(0, L - 8 + 1, 8):
            if rng.random() < 0.7:
                t[s:s + 8] = motifs[y[i]]
        toks[i, :L] = t
    return toks, y.astype(np.int32)


def audio_pool(n: int, num_classes: int = 10, n_frames: int = 64,
               n_mels: int = 16, seed: int = 0, noise: float = 0.3
               ) -> Tuple[np.ndarray, np.ndarray]:
    """(x (n, n_frames, n_mels) f32, y (n,) i32) synthetic log-mel frames.

    Each class gets a fixed spectral band boost plus a slow tone in a
    second band — class-dependent signal a frozen random encoder + linear
    head genuinely separates."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, num_classes, n)
    x = rng.normal(size=(n, n_frames, n_mels)).astype(np.float32) * noise
    t = np.arange(n_frames, dtype=np.float32)
    for c in range(num_classes):
        m = y == c
        band = c % n_mels
        x[m, :, band] += 1.5
        x[m, :, (band + 3) % n_mels] += 0.8 * np.sin(
            2.0 * np.pi * t * (c + 1) / n_frames)[None, :]
    return x, y.astype(np.int32)


def image_pool(n: int, num_classes: int = 10, hw: int = 8, seed: int = 0,
               noise: float = 0.15) -> Tuple[np.ndarray, np.ndarray]:
    """(x (n,hw,hw,3) f32, y (n,) i32) with class-dependent signal."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, num_classes, n)
    x = rng.normal(size=(n, hw, hw, 3)).astype(np.float32) * noise
    for c in range(num_classes):
        m = y == c
        x[m, c % hw, (c * 3) % hw, c % 3] += 2.5
        x[m, (c * 2) % hw, c % hw, (c + 1) % 3] += 1.5
    return x, y.astype(np.int32)


def lm_batches(tokens: np.ndarray, batch: int, seed: int = 0,
               shard_index: int = 0, num_shards: int = 1
               ) -> Iterator[dict]:
    """Infinite shuffled batches of {tokens, labels} (labels = next token).

    Per-host sharding: each host sees a disjoint slice (the multi-host data
    pipeline contract; on CPU num_shards=1)."""
    n = tokens.shape[0]
    mine = np.arange(shard_index, n, num_shards)
    rng = np.random.default_rng(seed + shard_index)
    while True:
        order = rng.permutation(mine)
        for i in range(0, len(order) - batch + 1, batch):
            sel = order[i:i + batch]
            t = tokens[sel]
            yield {
                "tokens": t[:, :-1].astype(np.int32),
                "labels": t[:, 1:].astype(np.int32),
            }
