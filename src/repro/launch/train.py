"""Fault-tolerant training driver.

End-to-end: synthetic LM data -> prefetch -> jitted train_step (pjit on a
mesh when available) -> checkpoint every N steps (async, atomic) ->
straggler monitor -> supervisor that restarts from the latest checkpoint on
(injected) node failure.

CLI (CPU, smoke config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --steps 60 \
      --batch 8 --seq 64 --ckpt-dir runs/ckpt_demo --fail-at 25
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data.prefetch import prefetch_to_device
from repro.data.synthetic import lm_batches, lm_pool
from repro.distributed.fault_tolerance import (FailureInjector,
                                               StragglerMonitor,
                                               SimulatedFailure, supervise)
from repro.models.transformer import Model
from repro.optim.optimizer import make_optimizer


@dataclasses.dataclass
class TrainReport:
    steps: int
    final_loss: float
    losses: List[float]
    restarts: int
    straggler_events: int
    ckpt_steps: List[int]


def run_training(arch: str = "qwen1.5-4b", *, smoke: bool = True,
                 steps: int = 50, batch: int = 8, seq: int = 64,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 20,
                 optimizer: str = "adamw", fail_at: Optional[List[int]] = None,
                 pool_size: int = 512, seed: int = 0,
                 log_every: int = 10, tokens: Optional[np.ndarray] = None,
                 params_init=None, lr: float = 3e-4,
                 warmup: int = 100) -> TrainReport:
    from repro.optim.optimizer import cosine_schedule
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = Model(cfg)
    opt = make_optimizer(optimizer,
                         lr=cosine_schedule(lr, warmup, max(steps, 1000)))
    if tokens is None:
        tokens, _ = lm_pool(pool_size, seq + 1, cfg.vocab, seed=seed)

    @jax.jit
    def train_step(params, opt_state, batch_):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch_)
        new_p, new_s, om = opt.update(grads, opt_state, params)
        return new_p, new_s, dict(metrics, loss=loss, **om)

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    injector = FailureInjector(fail_at or [])
    monitor = StragglerMonitor()
    losses: List[float] = []
    restarts = [0]

    def train_round(start_step: int) -> int:
        params = model.init(jax.random.PRNGKey(seed)) \
            if params_init is None else params_init
        opt_state = opt.init(params)
        step = 0
        if mgr is not None and mgr.latest_step():
            (params, opt_state), step, _ = mgr.restore((params, opt_state))
            restarts[0] += int(step > 0 and step == start_step and
                               start_step > 0 and False)
        data = lm_batches(tokens, batch, seed=seed)
        data = prefetch_to_device(data, size=2)
        for batch_ in data:
            if step >= steps:
                break
            t0 = time.perf_counter()
            injector.maybe_fail(step)
            params, opt_state, metrics = train_step(params, opt_state, batch_)
            loss = float(metrics["loss"])
            monitor.observe(step, time.perf_counter() - t0)
            losses.append(loss)
            step += 1
            if log_every and step % log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e}", flush=True)
            if mgr is not None and step % ckpt_every == 0:
                mgr.save_async(step, (params, opt_state))
        if mgr is not None:
            mgr.wait()
            mgr.save(step, (params, opt_state))
        return step

    if mgr is not None:
        def latest():
            return mgr.latest_step()
        n_fail = len(fail_at or [])
        rep = supervise(train_round, total_steps=steps, latest_step=latest,
                        max_restarts=n_fail + 2, monitor=monitor)
        restarts[0] = rep.restarts
    else:
        train_round(0)

    return TrainReport(
        steps=steps, final_loss=losses[-1] if losses else float("nan"),
        losses=losses, restarts=restarts[0],
        straggler_events=len(monitor.events),
        ckpt_steps=mgr.all_steps() if mgr else [])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--full", action="store_true",
                    help="full config (dry-run scale; default smoke)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args()
    rep = run_training(args.arch, smoke=not args.full, steps=args.steps,
                       batch=args.batch, seq=args.seq,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       optimizer=args.optimizer, fail_at=args.fail_at)
    print(f"done: {rep.steps} steps, final loss {rep.final_loss:.4f}, "
          f"restarts {rep.restarts}, stragglers {rep.straggler_events}, "
          f"ckpts {rep.ckpt_steps}")


if __name__ == "__main__":
    main()
