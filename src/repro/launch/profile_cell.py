import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Profile one dry-run cell: roofline terms + top cost contributors.

  PYTHONPATH=src python -m repro.launch.profile_cell \
      --arch deepseek-v3-671b --shape decode_32k [--multi] [--opt ...]
"""
import argparse  # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell  # noqa: E402
from repro.roofline import analysis  # noqa: E402
from repro.roofline.attribution import top_costs  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi)
    cell = build_cell(cfg, shape, mesh, optimizer=args.optimizer)
    compiled = cell.lower().compile()
    roof = analysis.analyze(compiled, cfg, shape, mesh.devices.size)
    print(f"=== {args.arch} | {args.shape} | "
          f"{'multi' if args.multi else 'single'}")
    for k, v in roof.as_dict().items():
        print(f"  {k}: {v}")
    mem = compiled.memory_analysis()
    if mem is not None:
        print(f"  temp_GB: {getattr(mem, 'temp_size_in_bytes', 0)/1e9:.1f}  "
              f"args_GB: {getattr(mem, 'argument_size_in_bytes', 0)/1e9:.1f}")
    print(top_costs(compiled.as_text(), k=args.top))


if __name__ == "__main__":
    main()
