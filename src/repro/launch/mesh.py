"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single-pod: (16,16)=(data,model), 256 chips. Multi-pod:
(2,16,16)=(pod,data,model), 512 chips. The dry-run launcher forces 512 host
platform devices via XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import contextlib

import numpy as np

import jax
from jax.sharding import Mesh


def set_mesh(mesh: Mesh):
    """``jax.set_mesh`` where it exists; otherwise a no-op context (older
    jax — every shard_map in this repo passes ``mesh=`` explicitly, so the
    ambient-mesh context is optional)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext(mesh)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"need {need} devices, have {len(devs)} — launch via "
            "repro.launch.dryrun which forces "
            "--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_debug_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    need = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need])
