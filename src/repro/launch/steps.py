"""Step builders + input specs for every (arch x shape) dry-run cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation). ``lower_cell`` assembles the jitted step with
in/out shardings from the logical-axis rules and lowers it against the specs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.param import ParamDecl, is_decl, param_shapes
from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed import partition
from repro.models.transformer import Model
from repro.optim.optimizer import AdamW, Adafactor, make_optimizer


def data_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for the host-data inputs of a cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    elif shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    else:  # decode
        out = {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    if cfg.enc_dec and shape.kind != "decode":
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_enc_frames, cfg.d_model), jnp.bfloat16)
    if cfg.n_patches and shape.kind != "decode":
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return out


def data_pspecs(cfg: ArchConfig, shape: ShapeConfig,
                rules: partition.AxisRules) -> Dict[str, P]:
    specs = data_specs(cfg, shape)

    def one(name: str, sds) -> P:
        logical = ("batch",) + (None,) * (len(sds.shape) - 1)
        return rules.pspec(logical, sds.shape)

    return {k: one(k, v) for k, v in specs.items()}


def default_optimizer(cfg: ArchConfig) -> str:
    # fp32 Adam state for 671B params does not fit 16 GB/chip at 512 chips;
    # the factored optimizer does (see EXPERIMENTS.md §Dry-run).
    return "adafactor" if cfg.n_params() > 5e10 else "adamw"


@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch x shape) on one mesh."""
    cfg: ArchConfig
    shape: ShapeConfig
    mesh: Mesh
    rules: partition.AxisRules
    step_fn: Any
    args_sds: Tuple
    in_shardings: Tuple
    out_shardings: Any
    donate: Tuple[int, ...] = ()

    def lower(self):
        jitted = jax.jit(self.step_fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate)
        with self.mesh, partition.activation_rules(self.rules):
            return jitted.lower(*self.args_sds)


def _shardings(mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               optimizer: Optional[str] = None,
               rule_overrides: Optional[dict] = None) -> Cell:
    rules = partition.make_rules(mesh, rule_overrides)
    model = Model(cfg)
    p_decls = model.param_decls()
    p_sds = param_shapes(p_decls)
    p_pspec = partition.tree_pspecs(p_decls, rules)

    if shape.kind == "train":
        opt_name = optimizer or default_optimizer(cfg)
        opt = make_optimizer(opt_name)
        s_decls = opt.state_decls(p_decls)
        s_sds = param_shapes(s_decls)
        s_pspec = partition.tree_pspecs(s_decls, rules)
        d_sds = data_specs(cfg, shape)
        d_pspec = data_pspecs(cfg, shape, rules)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            new_params, new_state, opt_metrics = opt.update(
                grads, opt_state, params)
            metrics = dict(metrics, loss=loss, **opt_metrics)
            return new_params, new_state, metrics

        metric_sds = {k: jax.ShapeDtypeStruct((), jnp.float32) for k in
                      ["ce", "z_loss", "aux_loss", "loss", "grad_norm", "lr"]}
        if cfg.mtp:
            metric_sds["mtp"] = jax.ShapeDtypeStruct((), jnp.float32)
        out_shardings = (_shardings(mesh, p_pspec), _shardings(mesh, s_pspec),
                         jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                      metric_sds))
        return Cell(
            cfg, shape, mesh, rules, train_step,
            (p_sds, s_sds, d_sds),
            (_shardings(mesh, p_pspec), _shardings(mesh, s_pspec),
             _shardings(mesh, d_pspec)),
            out_shardings, donate=(0, 1))

    if shape.kind == "prefill":
        c_decls = model.cache_decls(shape.global_batch, shape.seq_len)
        c_sds = param_shapes(c_decls)
        c_pspec = partition.tree_pspecs(c_decls, rules)
        d_sds = data_specs(cfg, shape)
        d_pspec = data_pspecs(cfg, shape, rules)

        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)

        logits_sh = NamedSharding(mesh, rules.pspec(
            ("batch", "vocab"), (shape.global_batch, cfg.padded_vocab)))
        return Cell(
            cfg, shape, mesh, rules, prefill_step,
            (p_sds, d_sds, c_sds),
            (_shardings(mesh, p_pspec), _shardings(mesh, d_pspec),
             _shardings(mesh, c_pspec)),
            (_shardings(mesh, c_pspec), logits_sh), donate=(2,))

    # decode: serve_step — one token against a seq_len cache
    c_decls = model.cache_decls(shape.global_batch, shape.seq_len)
    c_sds = param_shapes(c_decls)
    c_pspec = partition.tree_pspecs(c_decls, rules)
    d_sds = data_specs(cfg, shape)
    d_pspec = data_pspecs(cfg, shape, rules)

    def serve_step(params, cache, token):
        return model.decode_step(params, cache, token)

    logits_sh = NamedSharding(mesh, rules.pspec(
        ("batch", "vocab"), (shape.global_batch, cfg.padded_vocab)))
    return Cell(
        cfg, shape, mesh, rules, serve_step,
        (p_sds, c_sds, d_sds["token"]),
        (_shardings(mesh, p_pspec), _shardings(mesh, c_pspec),
         _shardings(mesh, d_pspec["token"]) if isinstance(d_pspec["token"], NamedSharding)
         else NamedSharding(mesh, d_pspec["token"])),
        (logits_sh, _shardings(mesh, c_pspec)), donate=(1,))
