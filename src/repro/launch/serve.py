"""Serving driver: batched decode with first-class AL scoring.

Runs prefill + N decode steps for a batch of synthetic prompts and computes
fused uncertainty scores from every step's logits — the paper's technique
(uncertainty scoring) integrated into the serving path itself, so an AL
sweep over a pool is just "serve the pool, keep the scores".

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --batch 4 \
      --prompt-len 32 --decode-steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.param import init_params
from repro.configs import get_config, get_smoke_config
from repro.data.synthetic import lm_pool
from repro.kernels.uncertainty import ops as unc_ops
from repro.models.transformer import Model


def run_serving(arch: str = "rwkv6-3b", *, smoke: bool = True, batch: int = 4,
                prompt_len: int = 32, decode_steps: int = 16,
                max_len: int = 128, seed: int = 0, log: bool = True) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    toks, _ = lm_pool(batch, prompt_len, cfg.vocab, seed=seed)
    batch_in = {"tokens": jnp.asarray(toks)}
    if cfg.enc_dec:
        batch_in["frames"] = jnp.zeros((batch, cfg.n_enc_frames, cfg.d_model),
                                       jnp.bfloat16)
    if cfg.n_patches:
        batch_in["patch_embeds"] = jnp.zeros(
            (batch, min(cfg.n_patches, prompt_len), cfg.d_model), jnp.bfloat16)

    cache = init_params(model.cache_decls(batch, max_len),
                        jax.random.PRNGKey(1))
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    cache, logits = prefill(params, batch_in, cache)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    scores_hist = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(decode_steps):
        logits, cache = decode(params, cache, tok)
        # paper technique in the serving path: fused uncertainty per step
        scores_hist.append(unc_ops.uncertainty_stats(logits))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(scores_hist[-1])
    t_decode = time.perf_counter() - t0

    lc = np.stack([np.asarray(s["lc"]) for s in scores_hist])  # (T, B)
    out = {
        "arch": cfg.name,
        "prefill_s": t_prefill,
        "decode_s_per_step": t_decode / decode_steps,
        "tokens_per_s": batch * decode_steps / t_decode,
        "mean_lc": float(lc.mean()),
        "mean_es": float(np.mean([np.asarray(s["es"]) for s in scores_hist])),
        "final_len": int(cache["len"]),
    }
    if log:
        print({k: (round(v, 5) if isinstance(v, float) else v)
               for k, v in out.items()})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    args = ap.parse_args()
    run_serving(args.arch, smoke=not args.full, batch=args.batch,
                prompt_len=args.prompt_len, decode_steps=args.decode_steps)


if __name__ == "__main__":
    main()
