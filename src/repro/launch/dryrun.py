import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
record memory/cost/collective analysis for EXPERIMENTS.md (§Dry-run,
§Roofline).

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices. Smoke
tests and benchmarks do NOT import this module and see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      --mesh both --arch all --shape all --out runs/dryrun.json
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell, default_optimizer  # noqa: E402
from repro.roofline import analysis  # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             optimizer=None, rule_overrides=None, tp_pad: bool = False) -> dict:
    cfg = get_config(arch)
    if tp_pad:
        cfg = cfg.tp_friendly(16)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "params": cfg.n_params(), "active_params": cfg.active_params(),
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    try:
        t0 = time.time()
        cell = build_cell(cfg, shape, mesh, optimizer=optimizer,
                          rule_overrides=rule_overrides)
        lowered = cell.lower()
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        mem_rec = {}
        if mem is not None:
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(mem, f, None)
                if v is not None:
                    mem_rec[f] = int(v)
        roof = analysis.analyze(compiled, cfg, shape, chips)
        rec.update(
            status="ok",
            optimizer=(optimizer or default_optimizer(cfg))
            if shape.kind == "train" else None,
            t_lower_s=round(t_lower, 2), t_compile_s=round(t_compile, 2),
            memory=mem_rec, roofline=roof.as_dict(),
        )
    except Exception as e:  # record the failure; these are bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="runs/dryrun.json")
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--tp-pad", action="store_true",
                    help="apply ArchConfig.tp_friendly (head padding + KV "
                         "replication) before lowering")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                key = f"{arch}|{shape_name}|{'multi' if multi else 'single'}"
                if args.skip_existing and results.get(key, {}).get("status") == "ok":
                    continue
                print(f"=== {key}", flush=True)
                rec = run_cell(arch, shape_name, multi,
                               optimizer=args.optimizer, tp_pad=args.tp_pad)
                results[key] = rec
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" t=({r['t_compute']:.2e},{r['t_memory']:.2e},"
                             f"{r['t_collective']:.2e})s"
                             f" compile={rec['t_compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"    -> {status}{extra}", flush=True)
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {args.out}")


if __name__ == "__main__":
    main()
