"""Overload-safe serving: admission control, per-tenant weighted fair
queueing, deadline propagation, bounded ingest, and client retry — unit
tests against a fake clock plus end-to-end TCP drills, and (hypothesis,
slow lane) scheduler invariants under random per-tenant interleavings
with a serial-replay bit-identity oracle."""
import random
import threading
import time

import numpy as np
import pytest

from repro.data.synthetic import image_pool
from repro.service.admission import (AdmissionConfig, FrameScheduler,
                                     TokenBucket, attach_stream)
from repro.service.backends import MLPBackend
from repro.service.client import ALClient, serve_tcp
from repro.service.config import ALServiceConfig
from repro.service.errors import DeadlineExceeded, ServerOverloaded
from repro.service.server import ALServer
from repro.service.transport import RPCClient, RPCServer


class _Stream:
    def __init__(self):
        attach_stream(self)


class _Clock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def _mlp_server(**cfg):
    return ALServer(ALServiceConfig(batch_size=16, **cfg),
                    backend=MLPBackend(in_dim=192, feat_dim=32))


# ------------------------------------------------------- token bucket --
def test_token_bucket_rate_burst_and_exact_wait():
    clk = _Clock()
    b = TokenBucket(rate=2.0, burst=3.0, clock=clk)
    assert all(b.try_take()[0] for _ in range(3))    # burst spends
    ok, wait = b.try_take()
    assert not ok and wait == pytest.approx(0.5)     # 1 token at 2/s
    clk.t += 0.25
    ok, wait = b.try_take()
    assert not ok and wait == pytest.approx(0.25)    # accrual is exact
    clk.t += 0.25
    assert b.try_take()[0]
    clk.t += 100.0
    b.try_take()
    assert b.tokens <= b.burst                       # banked at burst cap


def test_token_bucket_zero_rate_never_admits_after_burst():
    b = TokenBucket(rate=0.0, burst=1.0, clock=_Clock())
    assert b.try_take()[0]
    ok, wait = b.try_take()
    assert not ok and wait > 0


# ------------------------------------------- scheduler: admission -----
def test_inflight_bound_sheds_with_retry_after_and_frees_on_done():
    sched = FrameScheduler(AdmissionConfig(enabled=True, max_inflight=2))
    s = _Stream()
    assert sched.submit(s, "a", {"op": "x"})[0] == "admitted"
    assert sched.submit(s, "a", {"op": "x"})[0] == "admitted"
    verdict, code, retry = sched.submit(s, "a", {"op": "x"})
    assert (verdict, code) == ("shed", "overloaded") and retry > 0
    item = sched.next(timeout=0)
    sched.done(item[0], 0.01)                        # slot freed
    assert sched.submit(s, "a", {"op": "x"})[0] == "admitted"
    st = sched.stats()
    assert st["admitted"] == 3 and st["shed"] == 1
    assert st["inflight_hw"] == 2


def test_tenant_bucket_shed_carries_exact_wait():
    clk = _Clock()
    sched = FrameScheduler(
        AdmissionConfig(enabled=True, max_inflight=100,
                        tenant_rate=1.0, tenant_burst=1.0), clock=clk)
    s = _Stream()
    assert sched.submit(s, "a", {})[0] == "admitted"
    verdict, code, retry = sched.submit(s, "a", {})
    assert (verdict, code) == ("shed", "overloaded")
    assert retry == pytest.approx(1.0)               # 1 token at 1/s
    # buckets are per-tenant: tenant b is untouched by a's spend
    assert sched.submit(_Stream(), "b", {})[0] == "admitted"


def test_admission_disabled_never_sheds():
    sched = FrameScheduler(AdmissionConfig(enabled=False, max_inflight=1,
                                           tenant_rate=0.001))
    s = _Stream()
    for _ in range(50):
        assert sched.submit(s, "a", {})[0] == "admitted"
    assert sched.stats()["shed"] == 0


def test_deadline_shed_is_independent_of_admission():
    wall = _Clock(100.0)
    sched = FrameScheduler(AdmissionConfig(enabled=False), wall=wall)
    s = _Stream()
    verdict, code, _ = sched.submit(s, "a", {"deadline": 99.0})
    assert (verdict, code) == ("shed", "deadline")
    st = sched.stats()
    assert st["expired"] == 1 and st["shed"] == 1
    assert sched.submit(s, "a", {"deadline": 101.0})[0] == "admitted"


def test_retry_counter_tracks_attempt_frames():
    sched = FrameScheduler()
    s = _Stream()
    sched.submit(s, "a", {"attempt": 1})
    sched.submit(s, "a", {})
    st = sched.stats()
    assert st["retries"] == 1 and st["admitted"] == 2


# ------------------------------------------- scheduler: fairness ------
def _drain_counts(sched, n):
    served = []
    for _ in range(n):
        item = sched.next(timeout=0)
        if item is None:
            break
        served.append(item[1])
        sched.done(item[0], 0.0, control=item[3])
    return served


def test_wfq_weight_shares_are_exact():
    sched = FrameScheduler(weights={"heavy": 3.0, "light": 1.0})
    sa, sb = _Stream(), _Stream()
    for _ in range(40):
        sched.submit(sa, "heavy", {})
        sched.submit(sb, "light", {})
    served = _drain_counts(sched, 40)
    # stride scheduling: heavy gets exactly 3 of every 4 slots
    assert served.count("heavy") == 30
    assert served.count("light") == 10


def test_equal_weights_interleave_no_starvation():
    sched = FrameScheduler()
    streams = {t: _Stream() for t in "abc"}
    for _ in range(30):
        for t, s in streams.items():
            sched.submit(s, t, {})
    served = _drain_counts(sched, 90)
    # any 6-slot window holds every tenant: nobody waits a full rotation
    for i in range(0, 84):
        assert set(served[i:i + 6]) == set("abc")


def test_idle_tenant_banks_no_credit():
    sched = FrameScheduler()
    sa, sb = _Stream(), _Stream()
    for _ in range(50):
        sched.submit(sa, "busy", {})
    _drain_counts(sched, 50)                         # busy runs alone
    # idle tenant activates: it resumes at the current virtual time and
    # must NOT burst ahead on banked credit — slots alternate
    for _ in range(10):
        sched.submit(sa, "busy", {})
        sched.submit(sb, "idle", {})
    served = _drain_counts(sched, 20)
    first_half = served[:10]
    assert 4 <= first_half.count("idle") <= 6


def test_per_stream_fifo_one_inflight_at_a_time():
    sched = FrameScheduler()
    s = _Stream()
    for i in range(5):
        sched.submit(s, "a", {"seq": i})
    first = sched.next(timeout=0)
    assert first[2]["seq"] == 0
    # stream is inflight: its later frames are not offered yet
    assert sched.next(timeout=0) is None
    sched.done(s, 0.0)
    assert sched.next(timeout=0)[2]["seq"] == 1


def test_control_entries_bypass_admission_but_keep_fifo():
    sched = FrameScheduler(AdmissionConfig(enabled=True, max_inflight=1))
    s = _Stream()
    assert sched.submit(s, "a", {"id": 1})[0] == "admitted"
    assert sched.submit(s, "a", {"id": 2})[0] == "shed"
    assert sched.submit_control(s, "a", {"resp": 2})
    a = sched.next(timeout=0)
    assert a[2]["id"] == 1 and not a[3]
    sched.done(s, 0.0)
    b = sched.next(timeout=0)                        # shed notice after
    assert b[2] == {"resp": 2} and b[3]
    sched.done(s, 0.0, control=True)
    assert sched.stats()["inflight"] == 0


def test_drop_stream_releases_inflight_slots():
    sched = FrameScheduler(AdmissionConfig(enabled=True, max_inflight=2))
    s = _Stream()
    sched.submit(s, "a", {})
    sched.submit(s, "a", {})
    assert sched.submit(_Stream(), "b", {})[0] == "shed"
    sched.drop_stream(s)                             # conn died
    assert sched.submit(_Stream(), "b", {})[0] == "admitted"


def test_cancel_pending_returns_everything_and_stops_admission():
    sched = FrameScheduler()
    s1, s2 = _Stream(), _Stream()
    sched.submit(s1, "a", {"id": 1})
    sched.submit(s2, "b", {"id": 2})
    sched.submit_control(s2, "b", {"resp": 9})
    out = sched.cancel_pending()
    assert sorted(p.get("id", 9) for _, _, p, _ in out) == [1, 2, 9]
    assert sched.submit(s1, "a", {})[0] == "shed"
    assert sched.submit(s1, "a", {})[1] == "shutdown"
    assert sched.stats()["inflight"] == 0


def test_scheduler_random_interleavings_keep_fifo_and_drain(seed=0):
    """Seeded smoke version of the slow hypothesis invariant test: random
    per-tenant submissions with interleaved serving keep per-stream FIFO
    order and every admitted frame is eventually served."""
    rng = random.Random(seed)
    for trial in range(10):
        sched = FrameScheduler(
            weights={t: rng.choice([1.0, 2.0]) for t in "abcd"})
        streams = {t: _Stream() for t in "abcd"}
        submitted = {t: [] for t in "abcd"}
        served = {t: [] for t in "abcd"}
        seq = 0
        inflight = []
        for _ in range(rng.randrange(50, 150)):
            if inflight and rng.random() < 0.4:
                stream, control = inflight.pop(rng.randrange(len(inflight)))
                sched.done(stream, 0.0, control=control)
            t = rng.choice("abcd")
            sched.submit(streams[t], t, {"seq": seq})
            submitted[t].append(seq)
            seq += 1
            if rng.random() < 0.6:
                item = sched.next(timeout=0)
                if item is not None:
                    served[item[1]].append(item[2]["seq"])
                    inflight.append((item[0], item[3]))
        while True:                                   # drain
            for stream, control in inflight:
                sched.done(stream, 0.0, control=control)
            inflight.clear()
            item = sched.next(timeout=0)
            if item is None:
                break
            served[item[1]].append(item[2]["seq"])
            inflight.append((item[0], item[3]))
        assert served == submitted                    # FIFO + no starvation
        assert sched.stats()["inflight"] == 0


# ------------------------------------------------- bounded ingest -----
def test_ingest_shed_policy_raises_retryable_and_counts():
    srv = _mlp_server(ingest_max_rows=4, ingest_policy="shed")
    sess = srv.session()
    X, _ = image_pool(8, seed=0)
    with sess._ingest_cv:                  # stall the worker (RLock)
        t = sess.push_data(list(X[:4]), asynchronous=True)
        with pytest.raises(ServerOverloaded) as ei:
            sess.push_data(list(X[4:5]), asynchronous=True)
        assert ei.value.retry_after_s > 0
    sess.flush()
    assert t.done()
    st = srv.stats()
    assert st["pool"] == 4
    assert st["ingest"]["shed"] == 1
    assert st["ingest"]["rows_hw"] == 4
    # drained: a retry of the shed push now succeeds — nothing was lost,
    # nothing duplicated
    sess.push_data(list(X[4:5]), asynchronous=True)
    sess.flush()
    assert srv.stats()["pool"] == 5


def test_ingest_block_policy_backpressures_and_bounds_high_water():
    srv = _mlp_server(ingest_max_rows=4, ingest_policy="block")
    sess = srv.session()
    X, _ = image_pool(12, seed=1)
    done = threading.Event()

    def producer():
        for i in range(3):
            sess.push_data(list(X[i * 4:(i + 1) * 4]), asynchronous=True)
        done.set()

    threading.Thread(target=producer, daemon=True).start()
    assert done.wait(timeout=30)           # blocked pushes eventually admit
    sess.flush()
    st = srv.stats()
    assert st["pool"] == 12
    assert st["ingest"]["rows_hw"] <= 4    # cap held throughout
    assert st["ingest"]["shed"] == 0


def test_oversize_single_push_admitted_when_queue_empty():
    srv = _mlp_server(ingest_max_rows=2, ingest_policy="shed")
    X, _ = image_pool(6, seed=2)
    t = srv.push_data(list(X), asynchronous=True)    # 6 rows > cap, empty
    srv.flush()
    assert t.done() and srv.stats()["pool"] == 6


def test_bad_ingest_policy_rejected():
    srv = _mlp_server(ingest_max_rows=4, ingest_policy="drop")
    with pytest.raises(ValueError, match="block"):
        srv.push_data([np.zeros((192,), np.float32)], asynchronous=True)


# ------------------------------------------------- flush timeout ------
def _stall_integrate(sess):
    """Gate the ingest worker inside _integrate (cv released there), so
    the queue genuinely cannot drain until the gate opens."""
    gate = threading.Event()
    orig = sess._integrate

    def stalled(batch):
        gate.wait(timeout=30)
        return orig(batch)

    sess._integrate = stalled
    return gate


def test_flush_timeout_raises_and_backlog_survives():
    srv = _mlp_server()
    sess = srv.session()
    gate = _stall_integrate(sess)
    X, _ = image_pool(4, seed=3)
    sess.push_data(list(X), asynchronous=True)
    t0 = time.perf_counter()
    with pytest.raises(TimeoutError, match="not drained|pending"):
        sess.flush(timeout=0.2)
    assert time.perf_counter() - t0 < 2.0
    gate.set()
    sess.flush()                           # released: drains fine
    assert srv.stats()["pool"] == 4


def test_flush_timeout_over_tcp():
    srv = _mlp_server()
    rpc = serve_tcp(srv)
    cli = ALClient(url=f"127.0.0.1:{rpc.port}")
    try:
        sess = srv.session()
        gate = _stall_integrate(sess)
        X, _ = image_pool(4, seed=4)
        cli.push_data(list(X), asynchronous=True).result(timeout=30)
        with pytest.raises(TimeoutError):
            cli.flush(timeout=0.2)         # typed across the wire
        gate.set()
        cli.flush()
        assert cli.stats()["pool"] == 4
    finally:
        cli.close()
        rpc.stop()


# ------------------------------------------------- client retry -------
def _overloaded_then_ok(n_sheds, retry_after_s=0.01):
    calls = {"n": 0}

    def handler(p, s, ctx):
        calls["n"] += 1
        if calls["n"] <= n_sheds:
            raise ServerOverloaded(retry_after_s, "synthetic overload")
        return {}

    return handler, calls


def test_client_retries_overloaded_with_bounded_attempts():
    handler, calls = _overloaded_then_ok(2)
    srv = RPCServer({"flush": handler}, "127.0.0.1", 0, max_workers=2)
    srv.start()
    try:
        cli = ALClient(url=f"127.0.0.1:{srv.port}", retries=2,
                       retry_jitter_s=0.0)
        cli.flush()                        # 2 sheds then success
        assert calls["n"] == 3
        # server-side per-tenant accounting saw the retry attempts
        assert srv.stats()["retries"] == 2
        cli.close()
    finally:
        srv.stop()


def test_client_retry_budget_exhausts_to_typed_error():
    handler, calls = _overloaded_then_ok(10)
    srv = RPCServer({"flush": handler}, "127.0.0.1", 0, max_workers=2)
    srv.start()
    try:
        cli = ALClient(url=f"127.0.0.1:{srv.port}", retries=1,
                       retry_jitter_s=0.0)
        with pytest.raises(ServerOverloaded) as ei:
            cli.flush()
        assert ei.value.retry_after_s > 0  # contract: hint always present
        assert calls["n"] == 2             # initial + 1 retry, bounded
        cli.close()
    finally:
        srv.stop()


def test_connection_error_is_never_retried():
    """The PR-9 poisoning contract survives the retry layer: a mid-call
    timeout poisons the connection and raises ConnectionError — the op
    may have executed, so the client must NOT resend it."""
    calls = {"n": 0}

    def slow(p, s, ctx):
        calls["n"] += 1
        time.sleep(0.6)
        return {}

    srv = RPCServer({"flush": slow}, "127.0.0.1", 0, max_workers=2)
    srv.start()
    try:
        cli = ALClient(url=f"127.0.0.1:{srv.port}", retries=5)
        cli._rpc.sock.settimeout(0.15)
        with pytest.raises(ConnectionError):
            cli.flush()
        time.sleep(0.8)
        assert calls["n"] == 1             # exactly one execution
    finally:
        srv.stop()


# ------------------------------------------- deadline propagation -----
def test_expired_deadline_sheds_before_dispatch():
    ran = []
    srv = RPCServer({"op": lambda p, s, c: ran.append(1) or {}},
                    "127.0.0.1", 0, max_workers=2)
    srv.start()
    try:
        cli = RPCClient("127.0.0.1", srv.port, timeout=5.0)
        with pytest.raises(DeadlineExceeded):
            cli.call("op", deadline=time.time() - 1.0)
        assert not ran                     # never reached the handler
        assert srv.stats()["expired"] == 1
        cli.close()
    finally:
        srv.stop()


def test_deadline_sheds_at_queue_head_behind_slow_op():
    gate = threading.Event()
    ran = []

    def slow(p, s, ctx):
        gate.wait(timeout=10)
        return {}

    def fast(p, s, ctx):
        ran.append(1)
        return {}

    srv = RPCServer({"slow": slow, "fast": fast}, "127.0.0.1", 0,
                    max_workers=1)        # single worker: forced queueing
    srv.start()
    try:
        c1 = RPCClient("127.0.0.1", srv.port, timeout=10.0)
        c2 = RPCClient("127.0.0.1", srv.port, timeout=10.0)
        blocker = threading.Thread(target=c1.call, args=("slow",),
                                   daemon=True)
        blocker.start()
        time.sleep(0.2)                   # slow op occupies the worker
        results = []
        t2 = threading.Thread(
            target=lambda: results.append(_catch(c2)), daemon=True)
        t2.start()
        time.sleep(0.5)                   # deadline passes while queued
        gate.set()
        blocker.join(timeout=10)
        t2.join(timeout=10)
        assert results and isinstance(results[0], DeadlineExceeded)
        assert not ran                    # shed at queue-head, never ran
        assert srv.stats()["expired"] >= 1
        c1.close()
        c2.close()
    finally:
        srv.stop()


def _catch(cli):
    try:
        return cli.call("fast", deadline=time.time() + 0.3)
    except Exception as e:
        return e


# ------------------------------ hypothesis: scheduler invariants ------
@pytest.mark.slow
def test_fairness_scheduler_invariants_random_interleavings():
    """Hypothesis: for any per-tenant op interleaving and weight map, the
    scheduler preserves per-connection FIFO order, serves every admitted
    op (no starvation), and — run end-to-end against an ALServer TCP twin
    with fair scheduling active — selections are bit-identical to an
    unscheduled serial replay of the same per-tenant op sequences."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops_st = st.lists(
        st.tuples(st.sampled_from("abcd"), st.integers(0, 99)),
        min_size=5, max_size=60)
    weights_st = st.fixed_dictionaries(
        {t: st.sampled_from([0.5, 1.0, 2.0, 4.0]) for t in "abcd"})

    @settings(max_examples=25, deadline=None)
    @given(ops=ops_st, weights=weights_st, serve_bias=st.floats(0.1, 0.9))
    def run(ops, weights, serve_bias):
        rng = random.Random(1234)
        sched = FrameScheduler(weights=weights)
        streams = {t: _Stream() for t in "abcd"}
        submitted = {t: [] for t in "abcd"}
        served = {t: [] for t in "abcd"}
        inflight = []
        for i, (t, _) in enumerate(ops):
            sched.submit(streams[t], t, {"seq": i})
            submitted[t].append(i)
            while inflight and rng.random() < serve_bias:
                stream, control = inflight.pop(0)
                sched.done(stream, 0.0, control=control)
            if rng.random() < serve_bias:
                item = sched.next(timeout=0)
                if item is not None:
                    served[item[1]].append(item[2]["seq"])
                    inflight.append((item[0], item[3]))
        while True:
            for stream, control in inflight:
                sched.done(stream, 0.0, control=control)
            inflight.clear()
            item = sched.next(timeout=0)
            if item is None:
                break
            served[item[1]].append(item[2]["seq"])
            inflight.append((item[0], item[3]))
        assert served == submitted        # per-stream FIFO, all served
        assert sched.stats()["inflight"] == 0

    run()

    # end-to-end bit-identity: per-tenant AL op sequences through the
    # fair-scheduled TCP server == unscheduled serial replay, per tenant
    X, Y = image_pool(48, seed=11)
    srv = _mlp_server(fairness_weights={"t0": 4.0, "t1": 1.0})
    rpc = serve_tcp(srv)
    clients = [ALClient(url=f"127.0.0.1:{rpc.port}", session="new")
               for _ in range(2)]
    try:
        tcp_sel = []
        for i, cli in enumerate(clients):
            cli.push_data(list(X[i * 24:(i + 1) * 24]))
            keys = cli.query(24, "lc")["keys"]
            cli.label(keys[:8], Y[i * 24:i * 24 + 8])
            tcp_sel.append(cli.query(6, "coreset")["keys"])
        for i in range(2):
            oracle = _mlp_server()
            oracle.push_data(list(X[i * 24:(i + 1) * 24]))
            keys = oracle.query(24, "lc")["keys"]
            oracle.label(keys[:8], Y[i * 24:i * 24 + 8])
            assert oracle.query(6, "coreset")["keys"] == tcp_sel[i]
    finally:
        for cli in clients:
            cli.close()
        rpc.stop()
