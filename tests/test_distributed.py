"""Distributed machinery (8 forced host devices, subprocess): partition
rules, distributed top-k / k-center selection, compressed psum, small-mesh
lower+compile of build_cell."""
import os
import subprocess
import sys
import textwrap

import pytest

import jax
from repro.common.param import ParamDecl
from repro.distributed import partition


# ------------------------------------------------------- partition rules ----
class FakeMesh:
    def __init__(self, axis_names, shape):
        self.axis_names = axis_names
        import numpy as np
        self.devices = np.zeros(shape)


def _rules(axes=("data", "model"), shape=(16, 16)):
    return partition.make_rules(FakeMesh(axes, shape))


def test_pspec_basic():
    r = _rules()
    assert r.pspec(("embed", "ff"), (256, 1024)) == \
        jax.sharding.PartitionSpec("data", "model")


def test_pspec_divisibility_relaxation():
    r = _rules()
    # 40 heads do not divide 16 -> replicate that dim
    assert r.pspec(("heads", None), (40, 128)) == \
        jax.sharding.PartitionSpec()
    # flat fused dim divides -> sharded
    assert r.pspec(("batch", None, "qkv"), (256, 4, 5120)) == \
        jax.sharding.PartitionSpec("data", None, "model")


def test_pspec_no_axis_reuse():
    r = _rules()
    # expert takes "model" first; ff must not reuse it
    spec = r.pspec(("expert", "embed", "ff"), (64, 2048, 1408))
    assert spec == jax.sharding.PartitionSpec("model", "data")


def test_pspec_multipod_batch():
    r = _rules(("pod", "data", "model"), (2, 16, 16))
    assert r.pspec(("batch", None), (256, 4096)) == \
        jax.sharding.PartitionSpec(("pod", "data"))
    # batch=1 cannot shard
    assert r.pspec(("batch", None), (1, 4096)) == \
        jax.sharding.PartitionSpec()


def test_tree_pspecs():
    r = _rules()
    decls = {"w": ParamDecl((512, 1024), ("embed", "ff"))}
    specs = partition.tree_pspecs(decls, r)
    assert specs["w"] == jax.sharding.PartitionSpec("data", "model")


# --------------------------------------------------- subprocess helpers ----
def _run_sub(code: str, devices: int = 8) -> str:
    prog = (f'import os\n'
            f'os.environ["XLA_FLAGS"] = '
            f'"--xla_force_host_platform_device_count={devices}"\n'
            f'import sys\nsys.path.insert(0, "src")\n') + textwrap.dedent(code)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    return r.stdout


@pytest.mark.slow
def test_distributed_topk_matches_global():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.selection import distributed_top_k
        from repro.launch.mesh import make_debug_mesh, set_mesh
        mesh = make_debug_mesh((8,), ("data",))
        scores = jnp.asarray(np.random.default_rng(0).normal(size=(512,)),
                             jnp.float32)
        with set_mesh(mesh):
            idx = distributed_top_k(scores, 16, mesh)
        ref = np.argsort(-np.asarray(scores))[:16]
        assert set(np.asarray(idx).tolist()) == set(ref.tolist())
        print("TOPK_OK")
    """)
    assert "TOPK_OK" in out


@pytest.mark.slow
def test_distributed_kcenter_covers_clusters():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.selection import distributed_k_center
        from repro.launch.mesh import make_debug_mesh, set_mesh
        rng = np.random.default_rng(0)
        centers = rng.normal(size=(8, 16)) * 20
        pts = np.concatenate([c + rng.normal(size=(32, 16)) * 0.1
                              for c in centers]).astype(np.float32)
        perm = rng.permutation(256)
        lab = np.repeat(np.arange(8), 32)[perm]
        mesh = make_debug_mesh((8,), ("data",))
        with set_mesh(mesh):
            idx = distributed_k_center(jnp.asarray(pts[perm]), 8, mesh)
        got = set(lab[np.asarray(idx)].tolist())
        assert len(got) == 8, got
        print("KC_OK")
    """)
    assert "KC_OK" in out


@pytest.mark.slow
def test_distributed_kcenter_weighted():
    """Weighted distributed k-center: ones-weights reproduce the unweighted
    selections exactly, and random weights still give unique in-range
    indices that favor the heavily-weighted region."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.selection import distributed_k_center
        from repro.launch.mesh import make_debug_mesh, set_mesh
        rng = np.random.default_rng(0)
        pts = jnp.asarray(rng.normal(size=(256, 16)), jnp.float32)
        mesh = make_debug_mesh((8,), ("data",))
        with set_mesh(mesh):
            base = distributed_k_center(pts, 12, mesh)
            ones = distributed_k_center(pts, 12, mesh,
                                        weights=jnp.ones((256,), jnp.float32))
            w = jnp.asarray(rng.uniform(0.001, 1.0, size=(256,)), jnp.float32)
            w = w.at[128:].set(w[128:] * 1000.0)   # favor the upper half
            wsel = distributed_k_center(pts, 12, mesh, weights=w)
        assert np.array_equal(np.asarray(base), np.asarray(ones)), \\
            (base, ones)
        wi = np.asarray(wsel)
        assert len(set(wi.tolist())) == 12 and wi.min() >= 0 and wi.max() < 256
        assert np.mean(wi[1:] >= 128) >= 0.7, wi   # seed (idx 0) is unweighted
        print("KCW_OK")
    """)
    assert "KCW_OK" in out


@pytest.mark.slow
def test_compressed_psum_close_to_exact():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import compressed_psum
        from repro.launch.mesh import make_debug_mesh, set_mesh
        mesh = make_debug_mesh((8,), ("data",))
        g = jnp.asarray(np.random.default_rng(1).normal(size=(8, 64)),
                        jnp.float32)
        def f(x):
            return compressed_psum(x[0], "data", quantize=True)
        fn = shard_map(f, mesh=mesh, in_specs=P("data", None), out_specs=P())
        with set_mesh(mesh):
            approx = np.asarray(fn(g))
        exact = np.asarray(jnp.sum(g, 0))
        err = np.abs(approx - exact).max() / (np.abs(exact).max() + 1e-9)
        assert err < 0.05, err
        print("PSUM_OK", err)
    """)
    assert "PSUM_OK" in out


@pytest.mark.slow
def test_build_cell_small_mesh_compiles():
    """build_cell lower+compile on a small mesh for one arch x two shapes;
    validates the full dry-run path end to end in-process."""
    out = _run_sub("""
        import jax
        from repro.configs import get_smoke_config, SHAPES
        import dataclasses
        from repro.launch.mesh import make_debug_mesh, set_mesh
        from repro.launch.steps import build_cell
        from repro.roofline import analysis
        cfg = get_smoke_config("qwen3-8b")
        mesh = make_debug_mesh((2, 2, 2), ("pod", "data", "model"))
        for shape_name in ("train_4k", "decode_32k"):
            shape = dataclasses.replace(SHAPES[shape_name], seq_len=64,
                                        global_batch=8)
            cell = build_cell(cfg, shape, mesh)
            compiled = cell.lower().compile()
            roof = analysis.analyze(compiled, cfg, shape, 8)
            assert roof.flops_per_chip > 0
            assert roof.step_time > 0
        print("CELL_OK")
    """)
    assert "CELL_OK" in out
