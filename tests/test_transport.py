"""Transport-layer regression tests: the timeout-desync poisoning, the
writable decoded arrays, and the accept backlog decoupled from the worker
pool."""
import threading
import time

import numpy as np
import pytest

from repro.service.transport import RPCClient, RPCServer


def _serve(handlers, max_workers=4):
    srv = RPCServer(handlers, "127.0.0.1", 0, max_workers=max_workers)
    srv.start()
    return srv


def test_timeout_mid_call_poisons_connection_no_stale_frame():
    # Pre-fix behavior: call 1 times out mid-recv, its response frame stays
    # in flight, and call 2 silently reads THAT frame as its own answer.
    # Post-fix: call 1 raises ConnectionError (socket closed), and every
    # later call on the poisoned client fails fast instead of desyncing.
    def slow(p, s, ctx):
        time.sleep(0.6)
        return {"answer": "slow"}

    def fast(p, s, ctx):
        return {"answer": "fast", "echo": p.get("x")}

    srv = _serve({"slow": slow, "fast": fast})
    try:
        cli = RPCClient("127.0.0.1", srv.port, timeout=0.15)
        with pytest.raises(ConnectionError, match="timed out mid-call"):
            cli.call("slow")
        # the stale 'slow' frame must never surface as a later answer
        with pytest.raises(ConnectionError):
            cli.call("fast", {"x": 1})
        cli.close()
        # a fresh connection is fully functional
        cli2 = RPCClient("127.0.0.1", srv.port, timeout=5.0)
        assert cli2.call("fast", {"x": 2})["echo"] == 2
        assert cli2.call("slow")["answer"] == "slow"
        cli2.close()
    finally:
        srv.stop()


def test_response_frames_echo_request_ids():
    def fast(p, s, ctx):
        return {"ok": True}

    srv = _serve({"fast": fast})
    try:
        cli = RPCClient("127.0.0.1", srv.port, timeout=5.0)
        cli.call("fast")
        cli.call("fast")
        assert cli._req_id == 2       # monotone ids assigned per call
        cli.close()
    finally:
        srv.stop()


def test_decoded_arrays_are_writable_server_and_client_side():
    # pre-fix: np.frombuffer views are read-only and in-place mutation
    # server-side raised ValueError deep in the handler
    def mutate(p, s, ctx):
        x = p["x"]
        x += 1                        # in-place on the decoded payload
        return {"x": x}

    srv = _serve({"mutate": mutate})
    try:
        cli = RPCClient("127.0.0.1", srv.port, timeout=5.0)
        sent = np.arange(6, dtype=np.float32).reshape(2, 3)
        out = cli.call("mutate", {"x": sent})["x"]
        np.testing.assert_array_equal(out, sent + 1)
        out += 1                      # client-side decode is writable too
        np.testing.assert_array_equal(out, sent + 2)
        cli.close()
    finally:
        srv.stop()


def test_clients_beyond_max_workers_queue_instead_of_refusing():
    # the accept backlog is fixed (128), decoupled from max_workers: with a
    # 1-worker pool, clients 2 and 3 connect fine and are served once the
    # busy connection frees its worker
    gate = threading.Event()

    def wait(p, s, ctx):
        gate.wait(timeout=5.0)
        return {"served": True}

    def ping(p, s, ctx):
        return {"served": True}

    srv = _serve({"wait": wait, "ping": ping}, max_workers=1)
    try:
        c1 = RPCClient("127.0.0.1", srv.port, timeout=10.0)
        t = threading.Thread(target=lambda: c1.call("wait"))
        t.start()
        time.sleep(0.1)               # c1 occupies the only worker
        extra = [RPCClient("127.0.0.1", srv.port, timeout=10.0)
                 for _ in range(3)]   # > max_workers: must not refuse
        results = []

        def ping_then_close(c):
            results.append(c.call("ping")["served"])
            c.close()                 # one worker per LIVE connection:
            #                           disconnect so the next client runs

        threads = [threading.Thread(target=ping_then_close, args=(c,))
                   for c in extra]
        for th in threads:
            th.start()
        time.sleep(0.2)
        gate.set()
        t.join(timeout=5.0)
        c1.close()                    # disconnect frees the worker: drain
        for th in threads:
            th.join(timeout=9.0)
        assert results == [True, True, True]
    finally:
        srv.stop()


def test_silent_client_idle_timeout_fires_on_close():
    # a half-open / connect-and-go-silent client must not hold server
    # state forever: the idle timeout closes it and fires on_close
    closed = []
    srv = RPCServer({"ping": lambda p, s, c: {}}, "127.0.0.1", 0,
                    max_workers=2, on_close=lambda ctx: closed.append(ctx),
                    idle_timeout_s=0.3)
    srv.start()
    try:
        import socket as _socket
        silent = _socket.create_connection(("127.0.0.1", srv.port))
        # an ACTIVE client on the same server stays connected throughout
        cli = RPCClient("127.0.0.1", srv.port, timeout=5.0)
        deadline = time.time() + 5.0
        while not closed and time.time() < deadline:
            cli.call("ping")
            time.sleep(0.05)
        assert len(closed) == 1       # the silent conn, not the active one
        assert cli.call("ping") == {}
        # server-side close is observable client-side as EOF
        silent.settimeout(2.0)
        assert silent.recv(1) == b""
        silent.close()
        cli.close()
    finally:
        srv.stop()


def test_stalled_send_to_nonreading_client_frees_the_worker():
    # a client that sends a request and never reads the (large) response
    # must not wedge a handler thread forever: the send times out, the
    # connection closes, on_close fires, and other clients keep working
    closed = []
    big = {"blob": np.zeros((64 << 20,), np.uint8)}  # 64MB >> socket bufs
    srv = RPCServer({"big": lambda p, s, c: big,
                     "ping": lambda p, s, c: {}},
                    "127.0.0.1", 0, max_workers=1,
                    on_close=lambda ctx: closed.append(ctx),
                    send_timeout_s=0.5)
    srv.start()
    try:
        from repro.service.transport import send_msg
        import socket as _socket
        dead = _socket.create_connection(("127.0.0.1", srv.port))
        send_msg(dead, {"op": "big", "id": 1})       # request, never read
        deadline = time.time() + 10.0
        while not closed and time.time() < deadline:
            time.sleep(0.05)
        assert len(closed) == 1       # send stalled -> conn reclaimed
        # the single worker is free again for a well-behaved client
        cli = RPCClient("127.0.0.1", srv.port, timeout=5.0)
        assert cli.call("ping") == {}
        cli.close()
        dead.close()
    finally:
        srv.stop()


def test_stop_under_load_is_deterministic_no_leaked_threads():
    # stop() while frames are queued and executing: in-flight handlers
    # drain (their responses arrive), queued-not-started frames answer
    # with a typed shutdown ConnectionError, on_close fires exactly once
    # per connection, and no server thread outlives stop()
    before = {t.name for t in threading.enumerate()}
    closed = []
    gate = threading.Event()

    def slow(p, s, ctx):
        gate.wait(timeout=10)
        return {"done": True}

    srv = RPCServer({"slow": slow}, "127.0.0.1", 0, max_workers=1,
                    on_close=lambda ctx: closed.append(ctx))
    srv.start()
    clients = [RPCClient("127.0.0.1", srv.port, timeout=30.0)
               for _ in range(3)]
    results = []

    def call(c):
        try:
            results.append(c.call("slow"))
        except Exception as e:
            results.append(e)

    threads = [threading.Thread(target=call, args=(c,)) for c in clients]
    for t in threads:
        t.start()
    time.sleep(0.3)                   # 1 executing, 2 queued
    stopper = threading.Thread(target=srv.stop)
    stopper.start()
    time.sleep(0.2)
    gate.set()                        # release the in-flight handler
    stopper.join(timeout=20)
    assert not stopper.is_alive()
    for t in threads:
        t.join(timeout=10)
    served = [r for r in results if isinstance(r, dict)]
    shut = [r for r in results if isinstance(r, ConnectionError)]
    assert len(served) == 1           # the in-flight frame drained
    assert len(shut) == 2             # queued frames: typed shutdown
    assert all("shutting down" in str(e) or "closed" in str(e)
               for e in shut)
    assert len(closed) == 3           # on_close exactly once per conn
    for c in clients:
        c.close()
    # no leaked rpc threads: everything the server started is joined
    time.sleep(0.2)
    leaked = {t.name for t in threading.enumerate()} - before
    assert not {n for n in leaked if n.startswith("rpc-")}


def test_stop_under_load_reclaims_sessions():
    # serve_tcp + stop with live sessions: every per-connection session
    # is reclaimed through on_close (no leaked server-side sessions)
    from repro.data.synthetic import image_pool
    from repro.service.backends import MLPBackend
    from repro.service.client import ALClient, serve_tcp
    from repro.service.config import ALServiceConfig
    from repro.service.server import ALServer

    srv = ALServer(ALServiceConfig(batch_size=16),
                   backend=MLPBackend(in_dim=192, feat_dim=32))
    rpc = serve_tcp(srv)
    clis = [ALClient(url=f"127.0.0.1:{rpc.port}", session="new")
            for _ in range(3)]
    X, _ = image_pool(6, seed=0)
    for cli in clis:
        cli.push_data(list(X))
    assert len(srv.session_ids()) == 4          # default + 3
    rpc.stop()                                  # stop with clients live
    assert srv.session_ids() == ["default"]     # all reclaimed
    for cli in clis:
        try:
            cli.close()
        except Exception:
            pass


def test_pipelined_frames_serve_in_fifo_order():
    # frame-level dispatch must preserve per-connection ordering even
    # with many workers: responses come back in request order
    from repro.service.transport import send_msg, recv_msg
    import socket as _socket

    log = []
    srv = RPCServer({"echo": lambda p, s, c: log.append(p["i"]) or
                     {"i": p["i"]}}, "127.0.0.1", 0, max_workers=8)
    srv.start()
    try:
        sock = _socket.create_connection(("127.0.0.1", srv.port))
        sock.settimeout(10.0)
        for i in range(20):           # pipelined: all sent before reads
            send_msg(sock, {"op": "echo", "payload": {"i": i}, "id": i})
        got = [recv_msg(sock)["result"]["i"] for _ in range(20)]
        assert got == list(range(20)) # response order == request order
        assert log == list(range(20)) # execution order too (FIFO, 1 at
        sock.close()                  # a time per connection)
    finally:
        srv.stop()
