"""Transport-layer regression tests: the timeout-desync poisoning, the
writable decoded arrays, and the accept backlog decoupled from the worker
pool."""
import threading
import time

import numpy as np
import pytest

from repro.service.transport import RPCClient, RPCServer


def _serve(handlers, max_workers=4):
    srv = RPCServer(handlers, "127.0.0.1", 0, max_workers=max_workers)
    srv.start()
    return srv


def test_timeout_mid_call_poisons_connection_no_stale_frame():
    # Pre-fix behavior: call 1 times out mid-recv, its response frame stays
    # in flight, and call 2 silently reads THAT frame as its own answer.
    # Post-fix: call 1 raises ConnectionError (socket closed), and every
    # later call on the poisoned client fails fast instead of desyncing.
    def slow(p, s, ctx):
        time.sleep(0.6)
        return {"answer": "slow"}

    def fast(p, s, ctx):
        return {"answer": "fast", "echo": p.get("x")}

    srv = _serve({"slow": slow, "fast": fast})
    try:
        cli = RPCClient("127.0.0.1", srv.port, timeout=0.15)
        with pytest.raises(ConnectionError, match="timed out mid-call"):
            cli.call("slow")
        # the stale 'slow' frame must never surface as a later answer
        with pytest.raises(ConnectionError):
            cli.call("fast", {"x": 1})
        cli.close()
        # a fresh connection is fully functional
        cli2 = RPCClient("127.0.0.1", srv.port, timeout=5.0)
        assert cli2.call("fast", {"x": 2})["echo"] == 2
        assert cli2.call("slow")["answer"] == "slow"
        cli2.close()
    finally:
        srv.stop()


def test_response_frames_echo_request_ids():
    def fast(p, s, ctx):
        return {"ok": True}

    srv = _serve({"fast": fast})
    try:
        cli = RPCClient("127.0.0.1", srv.port, timeout=5.0)
        cli.call("fast")
        cli.call("fast")
        assert cli._req_id == 2       # monotone ids assigned per call
        cli.close()
    finally:
        srv.stop()


def test_decoded_arrays_are_writable_server_and_client_side():
    # pre-fix: np.frombuffer views are read-only and in-place mutation
    # server-side raised ValueError deep in the handler
    def mutate(p, s, ctx):
        x = p["x"]
        x += 1                        # in-place on the decoded payload
        return {"x": x}

    srv = _serve({"mutate": mutate})
    try:
        cli = RPCClient("127.0.0.1", srv.port, timeout=5.0)
        sent = np.arange(6, dtype=np.float32).reshape(2, 3)
        out = cli.call("mutate", {"x": sent})["x"]
        np.testing.assert_array_equal(out, sent + 1)
        out += 1                      # client-side decode is writable too
        np.testing.assert_array_equal(out, sent + 2)
        cli.close()
    finally:
        srv.stop()


def test_clients_beyond_max_workers_queue_instead_of_refusing():
    # the accept backlog is fixed (128), decoupled from max_workers: with a
    # 1-worker pool, clients 2 and 3 connect fine and are served once the
    # busy connection frees its worker
    gate = threading.Event()

    def wait(p, s, ctx):
        gate.wait(timeout=5.0)
        return {"served": True}

    def ping(p, s, ctx):
        return {"served": True}

    srv = _serve({"wait": wait, "ping": ping}, max_workers=1)
    try:
        c1 = RPCClient("127.0.0.1", srv.port, timeout=10.0)
        t = threading.Thread(target=lambda: c1.call("wait"))
        t.start()
        time.sleep(0.1)               # c1 occupies the only worker
        extra = [RPCClient("127.0.0.1", srv.port, timeout=10.0)
                 for _ in range(3)]   # > max_workers: must not refuse
        results = []

        def ping_then_close(c):
            results.append(c.call("ping")["served"])
            c.close()                 # one worker per LIVE connection:
            #                           disconnect so the next client runs

        threads = [threading.Thread(target=ping_then_close, args=(c,))
                   for c in extra]
        for th in threads:
            th.start()
        time.sleep(0.2)
        gate.set()
        t.join(timeout=5.0)
        c1.close()                    # disconnect frees the worker: drain
        for th in threads:
            th.join(timeout=9.0)
        assert results == [True, True, True]
    finally:
        srv.stop()
