"""Backend determinism contract: per-sample preprocessing, explicit PRNG
keys, clear input validation, and the cross-backend parity suite (chunk
invariance, eviction-recompute bit-identity, deterministic head fits).
"""
import jax
import numpy as np
import pytest

from repro.data.synthetic import audio_pool, image_pool, text_pool
from repro.service.backends import (MLPBackend, ResNetBackend,
                                    TransformerBackend, make_backend)
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer


# ------------------------------------------------- per-sample preprocess --
def test_resnet_preprocess_is_per_sample():
    """Regression: the uint8-range check used to be a whole-batch
    ``x.max() > 1.5`` — a [0,1] sample batched next to a 255-range sample
    got divided by 255, so the same bytes produced different features
    depending on batchmates (content-addressed cache poison)."""
    be = ResNetBackend()
    lo = np.random.default_rng(0).random((1, 8, 8, 3)).astype(np.float32)
    hi = np.full((1, 8, 8, 3), 200.0, np.float32)
    alone = be.preprocess(lo)
    batched = be.preprocess(np.concatenate([lo, hi]))
    assert np.array_equal(alone[0], batched[0])       # lo untouched
    assert np.allclose(batched[1], hi[0] / 255.0)     # hi rescaled
    f_alone = be.features(alone)
    f_batched = be.features(be.preprocess(np.concatenate([lo, hi])))
    assert np.array_equal(f_alone[0], f_batched[0])


def test_resnet_preprocess_keeps_unit_range_batches():
    be = ResNetBackend()
    x = np.random.default_rng(1).random((4, 8, 8, 3)).astype(np.float32)
    assert np.array_equal(be.preprocess(x), x)


# ------------------------------------------------------- explicit PRNG keys --
def test_explicit_old_style_keys_accepted():
    """Regression: ``rng or PRNGKey(0)`` raised "truth value of an array
    is ambiguous" for explicit uint32[2] keys in init_head and every
    backend constructor."""
    key = jax.random.PRNGKey(123)
    be = MLPBackend(in_dim=12, rng=key)
    h1 = be.init_head(jax.random.PRNGKey(7))
    h2 = be.init_head(jax.random.PRNGKey(7))
    assert np.array_equal(h1.w, h2.w)
    ResNetBackend(rng=key)
    TransformerBackend(rng=key, seq_len=8, block_size=4)
    # defaults still work
    assert be.init_head().w.shape == (be.feat_dim, be.num_classes)


# --------------------------------------------------------- MLP validation --
def test_mlp_preprocess_validates_ndim():
    be = MLPBackend(in_dim=12)
    with pytest.raises(ValueError, match="batch"):
        be.preprocess(np.zeros((7,), np.float32))      # 1-D payload
    with pytest.raises(ValueError, match="in_dim=12"):
        be.preprocess(np.zeros((3, 5), np.float32))    # wrong feature width
    flat = be.preprocess(np.zeros((3, 12), np.float32))
    nested = be.preprocess(np.zeros((3, 4, 3), np.float32))
    assert flat.shape == nested.shape == (3, 12)


# ------------------------------------------------------------ parity suite --
def _cases():
    return {
        "synthetic_cnn": (
            lambda: make_backend("synthetic_cnn"),
            lambda: image_pool(24, num_classes=4, hw=8, seed=3)[0]),
        "mlp": (
            lambda: MLPBackend(in_dim=48, feat_dim=16),
            lambda: np.random.default_rng(4).normal(
                size=(24, 48)).astype(np.float32)),
        "transformer_text": (
            lambda: make_backend("transformer", seq_len=24, block_size=8,
                                 kv_chunk=8),
            lambda: text_pool(24, num_classes=4, seq_len=24, vocab=512,
                              seed=5)[0]),
        "transformer_audio": (
            lambda: make_backend("transformer", seq_len=24, block_size=8,
                                 kv_chunk=8, modality="audio", input_dim=6),
            lambda: audio_pool(24, num_classes=4, n_frames=24, n_mels=6,
                               seed=6)[0]),
    }


@pytest.mark.parametrize("case", sorted(_cases()))
def test_backend_chunk_invariance(case):
    """Features are identical whether the pool is embedded all at once or
    one sample at a time in the canonical padded batch shape."""
    make, data = _cases()[case]
    be, raw = make(), data()
    x = be.preprocess(raw)
    bs = 8
    full = be.features(x)
    for i in range(0, len(x), 3):           # spot-check rows
        padded = np.concatenate(
            [x[i:i + 1], np.zeros((bs - 1,) + x.shape[1:], x.dtype)])
        assert np.array_equal(be.features(padded)[0], full[i]), \
            f"{case}: row {i} depends on batch composition"


@pytest.mark.parametrize("case", sorted(_cases()))
def test_backend_eviction_recompute_bitwise(case):
    """A feature recomputed after cache eviction reproduces the
    ingest-time bytes exactly (the `_feats_for` canonical-shape path)."""
    make, data = _cases()[case]
    raw = list(data())
    ingest = ALServer(ALServiceConfig(batch_size=8), backend=make())
    keys = ingest.push_data(raw)
    want = np.stack([ingest.cache.get(k) for k in keys])
    feat_bytes = want[0].nbytes
    tiny = ALServer(ALServiceConfig(batch_size=8,
                                    cache_bytes=5 * feat_bytes),
                    backend=make())
    keys2 = tiny.push_data(raw)
    assert keys2 == keys
    assert tiny.cache.stats()["entries"] < len(keys)   # eviction happened
    got = tiny.session()._feats_for(keys)
    assert np.array_equal(got, want), f"{case}: recompute changed bytes"


@pytest.mark.parametrize("case", sorted(_cases()))
def test_backend_head_fit_deterministic(case):
    make, data = _cases()[case]
    be, raw = make(), data()
    feats = be.features(be.preprocess(raw))
    labels = np.arange(len(feats)) % be.num_classes
    key = jax.random.PRNGKey(9)
    h1 = be.fit_head(feats, labels, head=be.init_head(key))
    h2 = be.fit_head(feats, labels, head=be.init_head(key))
    assert np.array_equal(h1.w, h2.w) and np.array_equal(h1.b, h2.b)
    assert np.array_equal(be.probs(feats, h1), be.probs(feats, h2))
