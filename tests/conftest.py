"""Shared test plumbing: skip the `interpret` kernel lane cleanly when
Pallas (or its TPU interpret mode) is not importable in this environment."""
import pytest


def _interpret_supported() -> bool:
    try:
        from jax.experimental import pallas  # noqa: F401
        from jax.experimental.pallas import tpu  # noqa: F401
        from repro.kernels import compat  # noqa: F401
        return True
    except ImportError:
        # ONLY a missing Pallas skips the lane; any other failure (e.g. a
        # bug in the compat shim) must surface as loud test errors, not an
        # all-green all-skipped kernel lane.
        return False


def pytest_collection_modifyitems(config, items):
    if _interpret_supported():
        return
    skip = pytest.mark.skip(reason="Pallas interpret mode unavailable")
    for item in items:
        if "interpret" in item.keywords:
            item.add_marker(skip)
