"""tp_friendly config transform (EXPERIMENTS §Perf B1/C1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config


def test_tp_friendly_pads_hostile_archs():
    phi3 = get_config("phi3-medium-14b").tp_friendly(16)
    assert phi3.n_heads == 48 and phi3.n_kv_heads == 16
    assert phi3.hd == 128                      # head_dim preserved
    llava = get_config("llava-next-34b").tp_friendly(16)
    assert llava.n_heads == 64 and llava.n_kv_heads == 16
    qwen15 = get_config("qwen1.5-4b").tp_friendly(16)
    assert qwen15.n_heads == 32 and qwen15.n_kv_heads == 32  # MHA pads both


def test_tp_friendly_replicates_kv_when_under_tp():
    q3 = get_config("qwen3-8b").tp_friendly(16)
    assert q3.n_heads == 32 and q3.n_kv_heads == 16   # GQA kv 8 -> 16


def test_tp_friendly_noop_where_inapplicable():
    # MLA and attention-free archs are untouched
    assert get_config("deepseek-v3-671b").tp_friendly(16) is \
        get_config("deepseek-v3-671b")
    assert get_config("rwkv6-3b").tp_friendly(16) is get_config("rwkv6-3b")


def test_tp_friendly_model_still_runs():
    import dataclasses
    from repro.models.transformer import Model
    cfg = dataclasses.replace(get_smoke_config("phi3-medium-14b"),
                              n_heads=6, n_kv_heads=3)
    padded = cfg.tp_friendly(4)
    assert padded.n_heads == 8
    model = Model(padded)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.zeros((2, 16), jnp.int32),
        "labels": jnp.zeros((2, 16), jnp.int32),
    }
    loss, _ = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
