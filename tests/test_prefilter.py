"""Centroid-gated prefilter + mmap shard spill (PR 6): summary
construction/maintenance invariants, gated top-k and gated greedy
bit-identity against the ``prefilter: false`` full-scan oracle (including
ragged/degenerate edges), and spilled-column bit-identity against
RAM-resident buffers — deterministically here and under random pools and
budgets (hypothesis, slow lane)."""
import numpy as np
import pytest

from repro.core import prefilter as pf
from repro.core.selection import ColumnSpill, grow_append
from repro.service.backends import MLPBackend
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer

GATED = ("lc", "mc", "rc", "es", "kcg", "coreset")


def _mlp_server(replicas=1, **cfg):
    return ALServer(ALServiceConfig(batch_size=16, replicas=replicas, **cfg),
                    backend=MLPBackend(in_dim=192, feat_dim=32))


def _vec_pool(n, seed=0, d=192):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _pair(replicas, n=96, seed=1, **pf_cfg):
    """(oracle, gated) servers fed the identical pool."""
    X = _vec_pool(n, seed)
    cfg = dict(prefilter=True, prefilter_min_rows=8, prefilter_clusters=6)
    cfg.update(pf_cfg)
    off = _mlp_server(replicas)
    on = _mlp_server(replicas, **cfg)
    keys = off.push_data(list(X))
    assert on.push_data(list(X)) == keys
    return off, on, keys, X


# ------------------------------------------------------ summary building --
def test_build_summary_partitions_rows_and_bounds_radii():
    rng = np.random.default_rng(3)
    feats = rng.normal(size=(57, 16)).astype(np.float32)
    s = pf.build_summary(feats, k=5, salt="t")
    assert s.covered == 57 and s.starts[0] == 0 and s.starts[-1] == 57
    assert sorted(s.rowid.tolist()) == list(range(57))   # a permutation
    np.testing.assert_array_equal(s.xperm, feats[s.rowid])
    for j in range(s.k):
        seg = s.rowid[int(s.starts[j]):int(s.starts[j + 1])]
        # ascending within a cluster: within-cluster argmax tie-breaks
        # must match pool order
        assert np.all(np.diff(seg) > 0) or seg.size <= 1
        if seg.size:
            d2 = ((feats[seg].astype(np.float64)
                   - s.cents[j]) ** 2).sum(-1)
            assert np.sqrt(d2).max() <= s.radii[j] + 1e-9
    # deterministic per (salt, rows, k)
    s2 = pf.build_summary(feats, k=5, salt="t")
    np.testing.assert_array_equal(s.rowid, s2.rowid)
    assert pf.build_summary(feats, k=5, salt="u").builds == s.builds


def test_maintain_summary_epochs_and_caps_cow():
    cfg = pf.PrefilterConfig(clusters=4, min_rows=16)
    rng = np.random.default_rng(4)
    feats = rng.normal(size=(40, 8)).astype(np.float32)
    probs = rng.dirichlet(np.ones(4), size=40).astype(np.float32)
    assert pf.maintain_summary(None, feats[:10], probs[:10], 0, cfg) is None
    s = pf.maintain_summary(None, feats[:24], probs[:24], 0, cfg)
    assert s is not None and s.covered == 24 and s.builds == 1
    assert s.caps is not None and s.caps_head_epoch == 0
    # small tail: same summary object (caps fresh, no rebuild)
    assert pf.maintain_summary(s, feats[:30], probs[:30], 0, cfg) is s
    # head bump: copy-on-write caps — NEW object, shared geometry
    s2 = pf.maintain_summary(s, feats[:30], probs[:30], 1, cfg)
    assert s2 is not s and s2.xperm is s.xperm and s2.builds == s.builds
    assert s.caps_head_epoch == 0 and s2.caps_head_epoch == 1
    # tail outgrows the covered prefix (40 - 24 > min(24, 16) fails;
    # force it with a tiny covered prefix)
    small = pf.maintain_summary(None, feats[:17], probs[:17], 0, cfg)
    big = pf.maintain_summary(small, feats, probs, 0, cfg)
    assert big.covered == 40 and big.builds == 2
    # caps are true per-cluster maxima over covered rows
    from repro.core.strategies.uncertainty import SCORE_FNS
    for kind, fn in SCORE_FNS.items():
        sc = np.asarray(fn(probs[:s.covered]))
        for j in range(s.k):
            seg = s.rowid[int(s.starts[j]):int(s.starts[j + 1])]
            if seg.size:
                assert s.caps[kind][j] == sc[seg].max(), (kind, j)


def test_auto_k_clamps():
    assert pf.PrefilterConfig().auto_k(100_000) == 64
    assert pf.PrefilterConfig().auto_k(300) == 4
    assert pf.PrefilterConfig(clusters=9).auto_k(5) == 5   # k <= rows
    assert pf.PrefilterConfig().auto_k(1) == 1


# ------------------------------------------- bit-identity vs the oracle --
@pytest.mark.parametrize("replicas", (1, 3))
def test_gated_selections_bit_identical(replicas):
    """Every gated strategy must match the full-scan oracle through a
    realistic label/train/push/query script."""
    off, on, keys, X = _pair(replicas, n=96)
    for srv in (off, on):
        srv.label(keys[:20], [i % 4 for i in range(20)])
        srv.train_and_eval()
    for s in GATED:
        assert on.query(budget=7, strategy=s, rng_seed=5)["keys"] == \
            off.query(budget=7, strategy=s, rng_seed=5)["keys"], s
    # ingest after the summary built: tail rows must stay selectable
    X2 = _vec_pool(24, seed=9)
    for srv in (off, on):
        srv.push_data(list(X2))
    for s in GATED:
        assert on.query(budget=7, strategy=s, rng_seed=8)["keys"] == \
            off.query(budget=7, strategy=s, rng_seed=8)["keys"], s
    assert max(on.stats()["artifacts"]["summary_builds"]) >= 1
    on.session().close(), off.session().close()


def test_loose_slack_is_the_full_scan():
    """A degenerate bound (huge slack: nothing ever pruned) must reproduce
    the oracle bit-for-bit — the exactness escape hatch."""
    off, on, keys, _ = _pair(1, n=80, prefilter_slack=1e9)
    for srv in (off, on):
        srv.label(keys[:16], [i % 4 for i in range(16)])
        srv.train_and_eval()
    for s in GATED:
        assert on.query(budget=9, strategy=s, rng_seed=2)["keys"] == \
            off.query(budget=9, strategy=s, rng_seed=2)["keys"], s


def test_prefilter_ignored_by_weighted_strategies():
    """Fresh per-slot weights defeat distance-only bounds: the weighted
    strategies accept the knob and run ungated — still oracle-identical."""
    off, on, keys, _ = _pair(3, n=72)
    for srv in (off, on):
        srv.label(keys[:16], [i % 4 for i in range(16)])
        srv.train_and_eval()
    for s in ("badge", "margin_density", "weighted_kcenter"):
        assert on.query(budget=5, strategy=s, rng_seed=4)["keys"] == \
            off.query(budget=5, strategy=s, rng_seed=4)["keys"], s


# ----------------------------------------------------- degenerate edges --
def test_empty_shard_edge():
    """A pool smaller than the replica count leaves shards empty; the
    gated path must agree with the oracle anyway."""
    off, on, keys, _ = _pair(3, n=2, prefilter_min_rows=1,
                             prefilter_clusters=2)
    for s in ("lc", "kcg"):
        assert on.query(budget=2, strategy=s, rng_seed=1)["keys"] == \
            off.query(budget=2, strategy=s, rng_seed=1)["keys"], s


def test_shards_smaller_than_one_centroid():
    """clusters > shard rows: auto_k clamps to the row count (one-row
    clusters), selections stay oracle-identical."""
    off, on, keys, _ = _pair(3, n=10, prefilter_min_rows=1,
                             prefilter_clusters=64)
    for s in GATED:
        assert on.query(budget=4, strategy=s, rng_seed=3)["keys"] == \
            off.query(budget=4, strategy=s, rng_seed=3)["keys"], s


def test_all_rows_labeled_pool():
    """Labeling the whole pool leaves zero candidates — both engines must
    behave identically (no crash in the gated path)."""
    off, on, keys, _ = _pair(1, n=24, prefilter_min_rows=1)
    for srv in (off, on):
        srv.label(keys, [i % 4 for i in range(len(keys))])
        srv.train_and_eval()
    res = {}
    for name, srv in (("off", off), ("on", on)):
        try:
            res[name] = srv.query(budget=4, strategy="lc",
                                  rng_seed=1)["keys"]
        except Exception as e:
            res[name] = type(e).__name__
    assert res["on"] == res["off"]


def test_below_min_rows_full_scans():
    """Pools under prefilter_min_rows never build summaries (full-scan
    fallback), and selections still match the oracle."""
    off, on, keys, _ = _pair(1, n=40, prefilter_min_rows=4096)
    assert on.stats()["artifacts"]["summary_builds"] == [0]
    for s in ("lc", "kcg"):
        assert on.query(budget=5, strategy=s, rng_seed=6)["keys"] == \
            off.query(budget=5, strategy=s, rng_seed=6)["keys"], s


# ------------------------------------------------------- mmap shard spill --
def test_column_spill_allocate_release_adopt(tmp_path):
    sp = ColumnSpill(str(tmp_path / "s"), ram_bytes=64)
    small = np.ones((2, 4), np.float32)          # 32 B: stays in RAM
    assert sp.adopt(small) is small
    big = np.arange(64, dtype=np.float32).reshape(4, 16)   # 256 B: spills
    m = sp.adopt(big)
    assert isinstance(m, np.memmap)
    np.testing.assert_array_equal(m, big)
    assert sp.spill_events == 1 and sp.spilled_bytes == big.nbytes
    view = m[:2]                                 # pinned snapshot
    sp.release(m)                                # unlink: view survives
    assert sp.spilled_bytes == 0
    np.testing.assert_array_equal(view, big[:2])
    import os
    assert not os.path.exists(m.filename)
    sp.release(small)                            # RAM array: no-op


def test_grow_append_spills_past_budget(tmp_path):
    sp = ColumnSpill(str(tmp_path / "g"), ram_bytes=200)
    buf, n = grow_append(None, 0, np.ones((3, 4), np.float32), sp)
    assert not isinstance(buf, np.memmap)        # 48 B cap: RAM
    view = buf[:n].copy()
    for i in range(6):                           # growth crosses the budget
        buf, n = grow_append(buf, n, np.full((3, 4), i, np.float32), sp)
    assert isinstance(buf, np.memmap)
    assert sp.spill_events >= 1
    np.testing.assert_array_equal(buf[:3], view)  # rows survived the moves
    # appending to a spilled buffer keeps extending it
    buf2, n2 = grow_append(buf, n, np.full((2, 4), 9, np.float32), sp)
    assert n2 == n + 2
    np.testing.assert_array_equal(buf2[n2 - 2:n2], 9.0)


@pytest.mark.parametrize("replicas", (1, 3))
def test_spilled_server_bit_identical(replicas, tmp_path):
    """shard_ram_bytes small enough that every column buffer spills: the
    full push/query/label/train/push script must select identically to
    the RAM-resident server, and the spill must actually happen."""
    X = _vec_pool(64, seed=12)
    ram = _mlp_server(replicas)
    spl = _mlp_server(replicas, shard_ram_bytes=1024,
                      shard_spill_dir=str(tmp_path))
    keys = ram.push_data(list(X[:40]))
    assert spl.push_data(list(X[:40])) == keys
    for s in ("lc", "kcg", "coreset", "badge"):
        assert spl.query(budget=6, strategy=s, rng_seed=2)["keys"] == \
            ram.query(budget=6, strategy=s, rng_seed=2)["keys"], s
    for srv in (ram, spl):
        srv.label(keys[:12], [i % 4 for i in range(12)])
        srv.train_and_eval()
        srv.push_data(list(X[40:]))
    for s in ("lc", "kcg", "coreset", "badge"):
        assert spl.query(budget=6, strategy=s, rng_seed=7)["keys"] == \
            ram.query(budget=6, strategy=s, rng_seed=7)["keys"], s
    art = spl.stats()["artifacts"]
    assert art["spill_events"] > 0 and art["spilled_bytes"] > 0
    assert ram.stats()["artifacts"]["spill_events"] == 0
    spl.session().close()
    import os
    assert not os.listdir(str(tmp_path))     # close removed the spill dir


def test_spilled_snapshot_pinned_across_push(tmp_path):
    """The PR-5 pinned-snapshot contract must hold over memmap buffers:
    rows appended after the pin stay invisible, pinned rows stay readable
    after growth relocates (and unlinks) the old file."""
    X = _vec_pool(30, seed=13)
    srv = _mlp_server(shard_ram_bytes=512, shard_spill_dir=str(tmp_path))
    srv.push_data(list(X[:20]))
    sess = srv.session()
    feats_l, probs_l, rows_l, index = sess._artifact_snapshot()
    pinned = feats_l[0][:5].copy()
    srv.push_data(list(X[20:]))                  # growth after the pin
    assert feats_l[0].shape[0] == 20
    np.testing.assert_array_equal(feats_l[0][:5], pinned)
    assert sess._artifact_snapshot()[0][0].shape[0] == 30
    sess.close()


def test_spill_with_prefilter_bit_identical(tmp_path):
    """Both tentpole halves together: spilled columns + gated selection
    still match the plain-RAM, ungated oracle."""
    X = _vec_pool(72, seed=14)
    plain = _mlp_server(3)
    both = _mlp_server(3, shard_ram_bytes=1024,
                       shard_spill_dir=str(tmp_path), prefilter=True,
                       prefilter_min_rows=8, prefilter_clusters=6)
    keys = plain.push_data(list(X))
    assert both.push_data(list(X)) == keys
    for srv in (plain, both):
        srv.label(keys[:16], [i % 4 for i in range(16)])
        srv.train_and_eval()
    for s in GATED:
        assert both.query(budget=6, strategy=s, rng_seed=9)["keys"] == \
            plain.query(budget=6, strategy=s, rng_seed=9)["keys"], s
    art = both.stats()["artifacts"]
    assert art["spill_events"] > 0 and max(art["summary_builds"]) >= 1
    both.session().close()


# ------------------------------------------------ random pools (slow) ----
@pytest.mark.slow
def test_random_pools_gated_matches_oracle():
    """Hypothesis: across random pool sizes, cluster counts, budgets,
    slacks and replicas, ``prefilter: true`` selections equal the
    ``prefilter: false`` oracle for every gated strategy."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(12, 120), replicas=st.sampled_from([1, 3]),
           clusters=st.integers(1, 12), budget=st.integers(1, 10),
           slack=st.sampled_from([0.0, 0.05, 1.0]),
           seed=st.integers(0, 9), labeled=st.integers(0, 10))
    def run(n, replicas, clusters, budget, slack, seed, labeled):
        X = _vec_pool(n, seed=seed)
        off = _mlp_server(replicas)
        on = _mlp_server(replicas, prefilter=True, prefilter_min_rows=4,
                         prefilter_clusters=clusters,
                         prefilter_slack=slack)
        keys = off.push_data(list(X))
        on.push_data(list(X))
        lab = min(labeled, n - 1)
        if lab:
            for srv in (off, on):
                srv.label(keys[:lab], [i % 4 for i in range(lab)])
                srv.train_and_eval()
        budget = min(budget, n - lab)
        for s in ("lc", "es", "kcg", "coreset"):
            assert on.query(budget=budget, strategy=s,
                            rng_seed=seed)["keys"] == \
                off.query(budget=budget, strategy=s,
                          rng_seed=seed)["keys"], s
        on.session().close(), off.session().close()

    run()
