"""Per-architecture smoke tests: reduced config, one forward + train-ish step
on CPU; asserts output shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.transformer import Model


def _batch(cfg, B=2, S=32, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_enc_frames, cfg.d_model)), jnp.bfloat16)
    if cfg.n_patches:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(metrics["ce"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_grad_step(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat), \
        f"{arch}: non-finite grads"
    # one SGD step must change the loss
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                              params, grads)
    loss2 = jax.jit(model.loss)(new_params, batch)[0]
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S)
    from repro.common.param import init_params
    cache = init_params(model.cache_decls(B, S + 8), jax.random.PRNGKey(1))
    cache, logits = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert int(cache["len"]) == S
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(params, cache, tok)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits2)))
    assert int(cache["len"]) == S + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_embed_pool(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    emb = jax.jit(model.embed_pool)(params, batch)
    assert emb.shape == (2, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(emb, np.float32)))
