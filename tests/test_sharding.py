"""Replica sharding: every zoo strategy's sharded selection must be
bit-identical to ``replicas=1`` across shard counts and ragged pools,
including the empty-shard edge; plus the merge primitives themselves and
the evicted-embedding recompute path under sharding."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.selection import (ShardView, gather_rows, locate_row,
                                  replica_of, replica_top_k)
from repro.core.strategies.zoo import SHARDED_COMPLETE, ZOO
from repro.data.synthetic import image_pool
from repro.service.backends import MLPBackend
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer

REPLICAS = (1, 2, 3, 7)
STRATEGIES = sorted(ZOO)


def _mlp_server(replicas, **cfg):
    return ALServer(ALServiceConfig(batch_size=16, replicas=replicas, **cfg),
                    backend=MLPBackend(in_dim=192, feat_dim=32))


def _make_shards(feats, probs, keys, replicas):
    """Hash-partition a pool the way the session does: shard-local rows
    keep global order."""
    shards = []
    for s in range(replicas):
        g = np.asarray([i for i, k in enumerate(keys)
                        if replica_of(k, replicas) == s], np.int64)
        shards.append(ShardView(feats=feats[g] if g.size else feats[:0],
                                probs=probs[g] if g.size else probs[:0],
                                gidx=g))
    return shards


# ----------------------------------------------------- merge primitives --
def test_replica_of_stable_and_in_range():
    keys = [f"key-{i}" for i in range(200)]
    for r in (1, 2, 3, 7):
        shards = [replica_of(k, r) for k in keys]
        assert all(0 <= s < r for s in shards)
        assert shards == [replica_of(k, r) for k in keys]  # deterministic
    # every shard of a reasonably sized pool is populated at small R
    assert set(replica_of(k, 3) for k in keys) == {0, 1, 2}


def test_replica_top_k_matches_lax_top_k_with_ties():
    rng = np.random.default_rng(0)
    # coarse quantization manufactures many exact float ties
    scores = (rng.integers(0, 5, size=97) / 4.0).astype(np.float32)
    keys = [f"t{i}" for i in range(97)]
    feats = rng.standard_normal((97, 4)).astype(np.float32)
    single_v, single_i = jax.lax.top_k(jnp.asarray(scores), 10)
    for r in REPLICAS:
        shards = _make_shards(feats, feats, keys, r)
        sc = [jnp.asarray(scores[np.asarray(s.gidx)]) for s in shards]
        gidx, vals = replica_top_k(shards, sc, 10)
        assert gidx.tolist() == np.asarray(single_i).tolist(), r
        assert vals.tolist() == np.asarray(single_v).tolist(), r


def test_locate_and_gather_rows():
    rng = np.random.default_rng(1)
    feats = rng.standard_normal((31, 8)).astype(np.float32)
    keys = [f"g{i}" for i in range(31)]
    shards = _make_shards(feats, feats, keys, 4)
    rows = [0, 30, 17, 17, 5]
    np.testing.assert_array_equal(gather_rows(shards, rows), feats[rows])
    for g in rows:
        si, li = locate_row(shards, g)
        assert int(shards[si].gidx[li]) == g
    with pytest.raises(IndexError):
        locate_row(shards, 31)


# ------------------------------------------- strategy-level equivalence --
@pytest.fixture(scope="module")
def pool_artifacts():
    """A ragged-size pool with probs/embeddings + labeled rows."""
    rng = np.random.default_rng(7)
    N, d, C = 61, 16, 10
    feats = rng.standard_normal((N, d)).astype(np.float32)
    logits = rng.standard_normal((N, C)).astype(np.float32)
    probs = np.asarray(jax.nn.softmax(jnp.asarray(logits)))
    labeled = rng.standard_normal((7, d)).astype(np.float32)
    keys = [f"pool-{i}" for i in range(N)]
    return feats, probs, labeled, keys


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_sharded_strategy_bit_identical(strategy, pool_artifacts):
    feats, probs, labeled, keys = pool_artifacts
    strat = ZOO[strategy]
    budget = 6
    single = np.asarray(strat.select(
        jax.random.PRNGKey(3), budget,
        probs=jnp.asarray(probs) if "probs" in strat.needs else None,
        embeddings=jnp.asarray(feats) if "embeddings" in strat.needs
        else None,
        labeled_embeddings=(jnp.asarray(labeled)
                            if "embeddings" in strat.needs else None)))
    for r in REPLICAS:
        sharded = np.asarray(strat.select_sharded(
            jax.random.PRNGKey(3), budget,
            _make_shards(feats, probs, keys, r),
            labeled_embeddings=(jnp.asarray(labeled)
                                if "embeddings" in strat.needs else None)))
        assert sharded.tolist() == single.tolist(), \
            f"{strategy} diverged at replicas={r}"


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_sharded_strategy_empty_shard_edge(strategy):
    """Pool smaller than the shard count: some shards are empty and must
    neither crash nor perturb the merge."""
    rng = np.random.default_rng(11)
    N, d, C = 5, 16, 10
    feats = rng.standard_normal((N, d)).astype(np.float32)
    probs = np.asarray(jax.nn.softmax(
        jnp.asarray(rng.standard_normal((N, C)).astype(np.float32))))
    keys = [f"tiny-{i}" for i in range(N)]
    shards = _make_shards(feats, probs, keys, 7)
    assert any(s.n == 0 for s in shards), "edge requires an empty shard"
    strat = ZOO[strategy]
    single = np.asarray(strat.select(
        jax.random.PRNGKey(9), 3,
        probs=jnp.asarray(probs) if "probs" in strat.needs else None,
        embeddings=jnp.asarray(feats) if "embeddings" in strat.needs
        else None,
        labeled_embeddings=None))
    sharded = np.asarray(strat.select_sharded(jax.random.PRNGKey(9), 3,
                                              shards))
    assert sharded.tolist() == single.tolist()


def test_every_zoo_strategy_has_a_sharded_path():
    assert SHARDED_COMPLETE
    assert all(ZOO[s].sharded_fn is not None for s in ZOO)


# --------------------------------------------- server-level equivalence --
@pytest.fixture(scope="module")
def servers():
    """One server per shard count, identically populated (same pushes,
    labels and head training), over two ragged pool sizes."""
    X, Y = image_pool(53, seed=5)
    out = {}
    for r in REPLICAS:
        srv = _mlp_server(r)
        keys = srv.push_data(list(X))
        srv.label(keys[:11], Y[:11])
        srv.train_and_eval()
        out[r] = srv
    return out


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_server_query_bit_identical_across_replicas(strategy, servers):
    ref = servers[1].query(budget=5, strategy=strategy, rng_seed=4)
    for r in REPLICAS[1:]:
        res = servers[r].query(budget=5, strategy=strategy, rng_seed=4)
        assert res["keys"] == ref["keys"], f"replicas={r}"
        assert res["indices"] == ref["indices"], f"replicas={r}"


def test_server_budget_exceeding_pool_across_replicas(servers):
    """budget > unlabeled clamps identically on every shard count."""
    ref = servers[1].query(budget=500, strategy="lc", rng_seed=0)
    assert len(ref["keys"]) == 53 - 11
    for r in REPLICAS[1:]:
        res = servers[r].query(budget=500, strategy="lc", rng_seed=0)
        assert res["keys"] == ref["keys"]


def test_sharded_artifact_cache_hits_and_invalidation():
    X, Y = image_pool(30, seed=6)
    srv = _mlp_server(3)
    keys = srv.push_data(list(X))
    sess = srv.session()
    srv.query(budget=4, strategy="lc")
    srv.query(budget=4, strategy="kcg")
    assert sess.artifact_builds == 1          # per-shard set built once
    srv.label(keys[:6], Y[:6])                # label: NO shard invalidated
    srv.query(budget=4, strategy="lc")
    assert sess.artifact_builds == 1
    X2, _ = image_pool(6, seed=16)
    new_keys = srv.push_data(list(X2))        # delta: only touched shards
    touched = {replica_of(k, 3) for k in new_keys}
    before = [c.builds for c in sess._columns]
    srv.query(budget=4, strategy="lc")
    assert sess.artifact_builds == 2
    after = [c.builds for c in sess._columns]
    assert {si for si in range(3) if after[si] > before[si]} == touched
    assert all(after[si] == before[si]
               for si in range(3) if si not in touched)


def test_sharded_tiny_cache_recomputes_evicted_embeddings():
    """Eviction under sharding: per-shard artifact builds recompute evicted
    embeddings from the session's raw copies instead of crashing."""
    X, Y = image_pool(60, seed=8)
    srv = _mlp_server(3, cache_bytes=10 * 32 * 4)   # ~10 of 60 feats fit
    keys = srv.push_data(list(X))
    assert srv.cache.stats()["entries"] < 60        # eviction happened
    res = srv.query(budget=6, strategy="lc")
    assert len(res["keys"]) == 6
    res = srv.query(budget=6, strategy="kcg")
    assert len(set(res["keys"])) == 6
    srv.label(keys[:20], Y[:20])
    assert 0.0 <= srv.train_and_eval() <= 1.0
