"""Incremental pool artifacts: per-shard epoch versioning, delta feats
extends, head-only prob refreshes — op-accounted and proven bit-identical
to ``artifact_cache: false`` from-scratch builds, deterministically here
and under random op interleavings (hypothesis, slow lane)."""
import numpy as np
import pytest

from repro.core.selection import ShardColumns, grow_append, replica_of
from repro.data.synthetic import image_pool
from repro.service.backends import MLPBackend
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer

STRATEGIES = ("lc", "es", "kcg", "coreset", "badge")


def _mlp_server(replicas=1, **cfg):
    return ALServer(ALServiceConfig(batch_size=16, replicas=replicas, **cfg),
                    backend=MLPBackend(in_dim=192, feat_dim=32))


# ------------------------------------------------------- column storage --
def test_grow_append_amortized_and_view_stable():
    buf, n = grow_append(None, 0, np.ones((3, 4), np.float32))
    assert n == 3 and buf.shape[0] >= 3
    view = buf[:n]                     # a pinned snapshot of rows [0:3]
    before = view.copy()
    allocs = 0
    for i in range(50):                # appends never rewrite old rows
        old = buf
        buf, n = grow_append(buf, n, np.full((2, 4), i, np.float32))
        allocs += old is not buf
    assert n == 103
    np.testing.assert_array_equal(view, before)     # snapshot untouched
    assert allocs <= 6                 # doubling: O(log n) reallocations
    np.testing.assert_array_equal(buf[3:5], np.zeros((2, 4)))
    # incompatible rows must fail loud, not crash the copy or silently
    # cast the already-written rows
    with pytest.raises(ValueError, match="cannot extend"):
        grow_append(buf, n, np.ones((1, 7), np.float32))
    with pytest.raises(ValueError, match="cannot extend"):
        grow_append(buf, n, np.ones((1, 4), np.float64))


def test_shard_columns_views_and_reset():
    col = ShardColumns()
    assert col.feats_view(8).shape == (0, 8)
    assert col.probs_view(10).shape == (0, 10)
    col.feats, col.feats_rows = grow_append(None, 0, np.ones((5, 8)))
    assert col.feats_view(8).shape == (5, 8)
    col.reset()
    assert col.feats is None and col.probs_head_epoch == -1


# ------------------------------------------------- deterministic engine --
@pytest.mark.parametrize("replicas", (1, 3))
def test_scripted_interleaving_bit_identical_to_from_scratch(replicas):
    """A fixed push/label/train/push/query script must select identically
    on the incremental engine and the cache-off from-scratch engine, and
    the incremental side must do O(delta) work: the second push embeds
    only its own rows and rebuilds only the shards it touched."""
    X, Y = image_pool(64, seed=3)
    on = _mlp_server(replicas)
    off = _mlp_server(replicas, artifact_cache=False)
    k_on = on.push_data(list(X[:48]))
    assert off.push_data(list(X[:48])) == k_on
    for srv in (on, off):
        srv.label(k_on[:10], Y[:10])
        srv.train_and_eval()
    for s in STRATEGIES:
        assert on.query(budget=6, strategy=s, rng_seed=5)["keys"] == \
            off.query(budget=6, strategy=s, rng_seed=5)["keys"], s

    sess = on.session()
    builds_before = [c.builds for c in sess._columns]
    e0 = on.embed_rows
    new_keys = on.push_data(list(X[48:]))             # 16 delta rows
    off.push_data(list(X[48:]))
    assert on.embed_rows - e0 == 16                   # push embeds its rows
    for s in STRATEGIES:
        assert on.query(budget=6, strategy=s, rng_seed=8)["keys"] == \
            off.query(budget=6, strategy=s, rng_seed=8)["keys"], s
    assert on.embed_rows - e0 == 16                   # queries embed nothing
    touched = ({0} if replicas == 1
               else {replica_of(k, replicas) for k in new_keys})
    builds_after = [c.builds for c in sess._columns]
    assert {si for si in range(replicas)
            if builds_after[si] > builds_before[si]} == touched


def test_train_refresh_is_probs_only_and_label_free():
    """train_and_eval must not re-embed (head forward over cached feats);
    label must not trigger any refresh at all."""
    X, Y = image_pool(40, seed=4)
    srv = _mlp_server(3)
    keys = srv.push_data(list(X))
    sess = srv.session()
    srv.query(budget=4, strategy="lc")                # columns warm
    builds = sess.artifact_builds
    srv.label(keys[:8], Y[:8])
    srv.query(budget=4, strategy="lc")
    assert sess.artifact_builds == builds             # label: zero rebuilds
    e0 = srv.embed_rows
    srv.train_and_eval()
    srv.query(budget=4, strategy="lc")
    assert srv.embed_rows == e0                       # retrain: zero embeds
    assert sess.probs_refreshes == 3                  # every populated shard
    assert sess.artifact_builds == builds + 1


def test_non_incremental_knob_full_rebuilds_same_selections():
    """incremental_artifacts: false falls back to per-shard full rebuilds —
    same selections, more embedless work, for debugging."""
    X, Y = image_pool(48, seed=5)
    inc = _mlp_server(3)
    full = _mlp_server(3, incremental_artifacts=False)
    for srv in (inc, full):
        srv.push_data(list(X[:36]))
    assert inc.query(budget=5, strategy="kcg", rng_seed=1)["keys"] == \
        full.query(budget=5, strategy="kcg", rng_seed=1)["keys"]
    for srv in (inc, full):
        srv.push_data(list(X[36:]))
    assert inc.query(budget=5, strategy="lc", rng_seed=1)["keys"] == \
        full.query(budget=5, strategy="lc", rng_seed=1)["keys"]
    # the fallback rebuilt from empty both times; the engine delta-built
    assert full.session().full_builds > inc.session().full_builds
    assert inc.session().delta_builds >= 1
    assert full.session().delta_builds == 0


def test_snapshot_pinned_across_concurrent_push():
    """Rows appended after a snapshot is pinned must be invisible to it:
    the covered-row bound filters them even though the index already knows
    them (the query ordered before the push)."""
    X, _ = image_pool(30, seed=6)
    srv = _mlp_server()
    srv.push_data(list(X[:20]))
    sess = srv.session()
    feats_l, probs_l, rows_l, index = sess._artifact_snapshot()
    srv.push_data(list(X[20:]))                       # appends AFTER the pin
    assert len(index) == 30                           # live index grew...
    covered = [k for k in sess._keys
               if k in index and index[k][1] < rows_l[0]]
    assert len(covered) == 20                         # ...snapshot did not
    assert feats_l[0].shape[0] == 20                  # view rows stable
    # and the pinned rows' contents survived the buffer growth
    np.testing.assert_array_equal(
        feats_l[0][:5], sess._artifact_snapshot()[0][0][:5])


# ------------------------------------------- random interleavings (slow) --
@pytest.mark.slow
def test_random_interleavings_bit_identical_to_from_scratch():
    """Hypothesis: ANY interleaving of push_data (sync and async), label,
    train_and_eval and query yields selections bit-identical between the
    incremental engine and ``artifact_cache: false`` from-scratch builds,
    across replicas in {1, 3}."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    X, Y = image_pool(66, seed=9)
    chunks = [list(X[i * 6:(i + 1) * 6]) for i in range(11)]
    ops_st = st.lists(
        st.one_of(
            st.tuples(st.just("push"), st.integers(0, 10)),
            st.tuples(st.just("push_async"), st.integers(0, 10)),
            st.tuples(st.just("label"), st.integers(1, 5)),
            st.tuples(st.just("train"), st.just(0)),
            st.tuples(st.just("query"), st.integers(1, 6)),
        ), min_size=3, max_size=12)

    @settings(max_examples=12, deadline=None)
    @given(ops=ops_st, replicas=st.sampled_from([1, 3]),
           seed=st.integers(0, 99))
    def run(ops, replicas, seed):
        inc = _mlp_server(replicas)
        ref = _mlp_server(replicas, artifact_cache=False)
        servers = (inc, ref)
        pushed = 0
        for op, arg in ops:
            if op == "push":
                for srv in servers:
                    srv.push_data(chunks[arg])
                pushed += 1
            elif op == "push_async":
                # both linearize at the next barrier op; single-queue
                # FIFO keeps pool order identical to the sync reference
                ts = [srv.push_data(chunks[arg], asynchronous=True)
                      for srv in servers]
                assert ts[0].keys == ts[1].keys
                pushed += 1
            elif op == "label":
                inc.flush()
                sess = inc.session()
                todo = [k for k in sess._keys
                        if k not in sess._labels][:arg]
                ys = [hash(k) % 10 for k in todo]
                for srv in servers:
                    srv.label(todo, ys)
            elif op == "train":
                for srv in servers:
                    srv.train_and_eval()
            else:
                if not pushed:
                    continue
                for strat in ("lc", "kcg"):
                    a = inc.query(budget=arg, strategy=strat, rng_seed=seed)
                    b = ref.query(budget=arg, strategy=strat, rng_seed=seed)
                    assert a["keys"] == b["keys"], \
                        f"{strat} diverged at replicas={replicas}"
        inc.flush(), ref.flush()
        a_sess, r_sess = inc.session(), ref.session()
        assert a_sess._keys == r_sess._keys           # same pool, same order
        for strat in ("lc", "kcg", "badge"):
            a = inc.query(budget=5, strategy=strat, rng_seed=seed)
            b = ref.query(budget=5, strategy=strat, rng_seed=seed)
            assert a["keys"] == b["keys"]

    run()
