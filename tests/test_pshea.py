"""PSHEA agent: predictor fit quality + Algorithm-1 controller semantics."""
import threading

import numpy as np
import pytest

from repro.core.agent.controller import run_pshea
from repro.core.agent.predictor import fit_neg_exp, predict_next


def test_predictor_recovers_neg_exp():
    r = np.arange(8)
    acc = 0.9 - 0.5 * np.exp(-0.6 * r)
    fit = fit_neg_exp(r[:5], acc[:5])
    pred = fit.predict(r[5:])
    np.testing.assert_allclose(pred, acc[5:], atol=0.02)


def test_predictor_noisy_monotone():
    rng = np.random.default_rng(0)
    r = np.arange(6)
    acc = 0.8 - 0.4 * np.exp(-0.8 * r) + rng.normal(0, 0.01, 6)
    nxt = predict_next(r, acc, 6)
    assert 0.5 < nxt <= 1.0
    assert nxt >= acc[0]


def test_predictor_short_history_fallback():
    assert predict_next([0, 1], [0.3, 0.5], 2) == 0.5


class FakeTask:
    """Deterministic curves per strategy; counts labels spent. Thread-safe
    so the parallel controller can drive it."""

    def __init__(self, curves, round_budget_cost=10):
        self.curves = curves
        self.rounds = {s: 0 for s in curves}
        self.spent = 0
        self._lock = threading.Lock()

    def initial_accuracy(self):
        return 0.1

    def select_and_label(self, strategy, round_budget):
        with self._lock:
            self.spent += round_budget
        return round_budget

    def train_and_eval(self, strategy):
        self.rounds[strategy] += 1
        r = self.rounds[strategy]
        a, b, c = self.curves[strategy]
        return a - b * np.exp(-c * r)


CURVES = {
    "good": (0.95, 0.85, 0.9),     # fast, high asymptote
    "mid": (0.80, 0.70, 0.6),
    "bad": (0.55, 0.45, 0.3),      # slow, low asymptote
}


def test_pshea_eliminates_worst_first():
    task = FakeTask(CURVES)
    res = run_pshea(task, list(CURVES), target_accuracy=2.0,
                    budget_max=10_000, round_budget=10, max_rounds=6,
                    converge_patience=100)
    assert res.eliminated[0] == "bad"
    assert res.best_strategy == "good"


def test_pshea_stops_on_target():
    task = FakeTask(CURVES)
    res = run_pshea(task, list(CURVES), target_accuracy=0.5,
                    budget_max=10_000, round_budget=10)
    assert res.stop_reason == "target_accuracy"


def test_pshea_stops_on_budget():
    task = FakeTask(CURVES)
    res = run_pshea(task, list(CURVES), target_accuracy=2.0,
                    budget_max=45, round_budget=10, converge_patience=100)
    assert res.stop_reason == "budget_exhausted"
    assert res.budget_spent >= 45


def test_pshea_converges_on_plateau():
    flat = {"s1": (0.5, 0.4, 5.0), "s2": (0.49, 0.4, 5.0)}
    task = FakeTask(flat)
    res = run_pshea(task, list(flat), target_accuracy=2.0,
                    budget_max=10_000, round_budget=10,
                    converge_eps=1e-3, converge_patience=2, max_rounds=30)
    assert res.stop_reason == "converged"
    assert res.rounds < 30


def test_pshea_parallel_bit_identical_to_serial():
    """Racing the candidates on a worker pool must reproduce the serial
    schedule exactly — budget, histories, forecasts, elimination order."""
    kw = dict(target_accuracy=2.0, budget_max=10_000, round_budget=10,
              max_rounds=6, converge_patience=100)
    serial = run_pshea(FakeTask(CURVES), list(CURVES), max_workers=1, **kw)
    for workers in (2, 8):
        parallel = run_pshea(FakeTask(CURVES), list(CURVES),
                             max_workers=workers, **kw)
        assert serial == parallel


def test_pshea_saves_budget_vs_bruteforce():
    """Successive halving must spend less than running all strategies for
    all rounds (the paper's cost-saving claim)."""
    task = FakeTask(CURVES)
    res = run_pshea(task, list(CURVES), target_accuracy=2.0,
                    budget_max=10_000, round_budget=10, max_rounds=6,
                    converge_patience=100)
    brute = len(CURVES) * res.rounds * 10
    assert res.budget_spent < brute
