"""Roofline machinery: the scan-undercount calibration that motivated the
HLO analyzer, trip-count scaling, collective parsing, dtype bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analysis
from repro.roofline.hlo_analyzer import HloCost, _shape_elems_and_bytes


def _scan_prog(n=10, d=256):
    def body(x, w):
        return jnp.dot(x, w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((n, d, d), jnp.float32)
    return jax.jit(f).lower(x, ws).compile()


def test_cost_analysis_counts_scan_once():
    """The raw XLA cost analysis undercounts while-loops — this is the
    documented reason the HLO analyzer exists (EXPERIMENTS.md §Roofline)."""
    compiled = _scan_prog(n=10, d=256)
    raw = compiled.cost_analysis()
    if isinstance(raw, (list, tuple)):
        raw = raw[0]
    ideal = 2 * 10 * 256 ** 3
    assert raw["flops"] < ideal / 5        # undercounted


def test_hlo_analyzer_scales_trip_count():
    compiled = _scan_prog(n=10, d=256)
    cost = HloCost(compiled.as_text()).entry_cost()
    ideal = 2 * 10 * 256 ** 3
    assert abs(cost.flops - ideal) / ideal < 0.05
    # bytes: ~(3 tensors rw per iter) x 10 iters, must be within 4x band
    per_iter = 3 * 256 * 256 * 4
    assert per_iter * 10 * 0.5 < cost.bytes < per_iter * 10 * 8


def test_hlo_analyzer_nested_scan():
    def inner(x, w):
        return jnp.dot(x, w), None

    def outer(x, ws):
        def step(c, _):
            y, _ = jax.lax.scan(inner, c, ws)
            return y, None
        y, _ = jax.lax.scan(step, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 128, 128), jnp.float32)
    compiled = jax.jit(outer).lower(x, ws).compile()
    cost = HloCost(compiled.as_text()).entry_cost()
    ideal = 2 * 5 * 4 * 128 ** 3
    assert abs(cost.flops - ideal) / ideal < 0.1


def test_collective_parse_psum():
    import subprocess, sys, os, textwrap
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline.hlo_analyzer import HloCost
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh((4,), ("data",))
        sh = NamedSharding(mesh, P("data"))
        rep = NamedSharding(mesh, P())
        def f(x):
            return jnp.sum(x, axis=0)
        c = jax.jit(f, in_shardings=sh, out_shardings=rep).lower(
            jax.ShapeDtypeStruct((64, 128), jnp.float32)).compile()
        cost = HloCost(c.as_text()).entry_cost()
        total = sum(cost.coll.values())
        assert total > 0, c.as_text()[:3000]
        # per-device partial is (128,) f32 = 512B operand
        assert total <= 64 * 128 * 4, total
        print("COLL_OK", cost.coll)
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-2000:])
    assert "COLL_OK" in r.stdout


def test_shape_bytes_parser():
    e, b = _shape_elems_and_bytes("bf16[16,128]{1,0}")
    assert e == 2048 and b == 4096
    e, b = _shape_elems_and_bytes("(f32[8,8], s8[4])")
    assert e == 68 and b == 260
    e, b = _shape_elems_and_bytes("pred[]")
    assert e == 1 and b == 1


def test_roofline_terms_and_bottleneck():
    r = analysis.Roofline(
        flops_per_chip=197e12, bytes_per_chip=819e9 * 2,
        coll_bytes_per_chip=50e9 * 0.5, coll_breakdown={},
        chips=256, model_flops_global=197e12 * 256 * 0.5)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.mfu_bound - 0.25) < 1e-9


def test_model_flops_moe_uses_active():
    from repro.configs import get_config, SHAPES
    cfg = get_config("deepseek-moe-16b")
    dense_equiv = cfg.n_params()
    active = cfg.active_params()
    assert active < 0.6 * dense_equiv
    mf = analysis.model_flops(cfg, SHAPES["train_4k"])
    assert abs(mf - 6.0 * active * 256 * 4096) / mf < 1e-9
