"""Asynchronous ingest: PushTicket futures, the flush/linearization
barrier, once-per-drained-batch versioning, and (hypothesis, slow lane) an
interleaving property test against a serial replay oracle — in the style
of tests/test_pshea_properties.py."""
import threading
import time

import numpy as np
import pytest

from repro.data.synthetic import image_pool
from repro.service.backends import MLPBackend
from repro.service.client import ALClient, serve_tcp
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer, PushTicket


def _mlp_server(replicas=1, **cfg):
    return ALServer(ALServiceConfig(batch_size=16, replicas=replicas, **cfg),
                    backend=MLPBackend(in_dim=192, feat_dim=32))


# ------------------------------------------------------------- basics --
def test_ticket_keys_known_immediately_and_result_returns_them():
    srv = _mlp_server(replicas=2)
    X, _ = image_pool(12, seed=0)
    sync_keys = None
    t = srv.push_data(list(X), asynchronous=True)
    assert isinstance(t, PushTicket)
    assert len(t.keys) == 12                       # content hashes, eager
    assert t.result(timeout=30) == t.keys
    assert t.done()
    srv.flush()
    # keys are content-addressed: identical to a synchronous push
    srv2 = _mlp_server()
    sync_keys = srv2.push_data(list(X))
    assert t.keys == sync_keys


def test_flush_barrier_makes_rows_visible():
    srv = _mlp_server(replicas=3)
    X, _ = image_pool(30, seed=1)
    tickets = [srv.push_data(list(X[i * 10:(i + 1) * 10]),
                             asynchronous=True) for i in range(3)]
    srv.flush()
    assert all(t.done() for t in tickets)
    st = srv.stats()
    assert st["pool"] == 30
    assert st["ingest_pending"] == 0


def test_query_and_label_linearize_after_pending_ingests():
    """query/label take the flush barrier implicitly: no explicit flush,
    yet the queried pool must contain every previously pushed row."""
    srv = _mlp_server(replicas=2)
    X, Y = image_pool(24, seed=2)
    t = srv.push_data(list(X), asynchronous=True)
    res = srv.query(budget=24, strategy="lc")      # implicit barrier
    assert sorted(res["keys"]) == sorted(t.keys)
    srv.label(t.keys[:6], Y[:6])                   # labels resolve too
    assert srv.stats()["labeled"] == 6


def test_sync_push_orders_after_pending_async():
    """A synchronous push issued after async pushes must append AFTER them
    (pool order is push order)."""
    srv = _mlp_server()
    X, _ = image_pool(20, seed=3)
    t = srv.push_data(list(X[:10]), asynchronous=True)
    sync_keys = srv.push_data(list(X[10:]))
    sess = srv.session()
    assert sess._keys[:10] == t.keys
    assert sess._keys[10:] == sync_keys


def test_version_bumps_once_per_drained_batch():
    """Many queued pushes fold into few drained batches; pool_version must
    move once per batch, monotonically, never once per push."""
    srv = _mlp_server(replicas=2)
    X, _ = image_pool(60, seed=4)
    n_push = 12
    tickets = [srv.push_data(list(X[i * 5:(i + 1) * 5]), asynchronous=True)
               for i in range(n_push)]
    srv.flush()
    assert all(t.done() for t in tickets)
    st = srv.stats()
    assert st["pool"] == 60
    assert 1 <= st["pool_version"] <= n_push
    assert st["pool_version"] == st["ingest_batches"]


def test_duplicate_pushes_do_not_duplicate_rows():
    srv = _mlp_server(replicas=2)
    X, _ = image_pool(10, seed=5)
    t1 = srv.push_data(list(X), asynchronous=True)
    t2 = srv.push_data(list(X), asynchronous=True)  # same content
    srv.flush()
    assert t1.keys == t2.keys
    assert srv.stats()["pool"] == 10


def test_ingest_error_surfaces_on_flush():
    """A push whose embedding fails must fail its ticket AND re-raise at
    the next flush barrier instead of silently dropping rows."""
    srv = _mlp_server()
    bad = [np.zeros((7,), np.float32)]             # wrong in_dim -> matmul err
    t = srv.push_data(bad, asynchronous=True)
    with pytest.raises(BaseException):
        t.result(timeout=30)
    with pytest.raises(RuntimeError, match="asynchronous ingest failed"):
        srv.flush()
    srv.flush()                                    # error reported once


def test_ingest_failure_isolated_to_the_malformed_push():
    """A malformed push coalesced into the same drained batch as valid
    pushes must not drop the valid pushes' rows: the worker re-integrates
    each push individually and only the bad ticket fails."""
    srv = _mlp_server()
    X, _ = image_pool(16, seed=7)
    # stall the worker so the good and bad pushes coalesce into one batch
    sess = srv.session()
    with sess._ingest_cv:
        good1 = sess.push_data(list(X[:8]), asynchronous=True)
        bad = sess.push_data([np.zeros((7,), np.float32)],
                             asynchronous=True)
        good2 = sess.push_data(list(X[8:]), asynchronous=True)
    assert good1.result(timeout=30) == good1.keys
    assert good2.result(timeout=30) == good2.keys
    with pytest.raises(BaseException):
        bad.result(timeout=30)
    with pytest.raises(RuntimeError, match="asynchronous ingest failed"):
        srv.flush()
    assert srv.stats()["pool"] == 16               # no valid row lost


def test_ticket_result_timeout_raises():
    """result(timeout=) must raise TimeoutError when the deadline passes,
    not block forever behind a busy/stalled worker."""
    import concurrent.futures as cf
    t = PushTicket(["k"], cf.Future(), worker_alive=lambda: True)
    t0 = time.perf_counter()
    with pytest.raises(TimeoutError, match="not integrated"):
        t.result(timeout=0.2)
    assert time.perf_counter() - t0 < 2.0
    fut = cf.Future()
    fut.set_result(None)
    assert PushTicket(["k"], fut).result(timeout=0) == ["k"]


def test_ticket_result_detects_dead_worker():
    """A dead ingest worker can never resolve the ticket: result() must
    raise promptly even with timeout=None instead of hanging the client."""
    srv = _mlp_server()
    sess = srv.session()
    sess._ingest_loop = lambda: None       # worker thread exits immediately
    X, _ = image_pool(4, seed=8)
    t = sess.push_data(list(X), asynchronous=True)
    deadline = time.time() + 10
    while sess._ingest_thread.is_alive() and time.time() < deadline:
        time.sleep(0.01)
    t0 = time.perf_counter()
    with pytest.raises(TimeoutError, match="worker died"):
        t.result()                         # no timeout: still must not hang
    assert time.perf_counter() - t0 < 5.0
    # the barrier (and so label/query/train/sync-push) fails fast too,
    # instead of waiting forever on a drain that can never happen
    t0 = time.perf_counter()
    with pytest.raises(RuntimeError, match="worker died"):
        sess.flush()
    assert time.perf_counter() - t0 < 5.0


def test_closed_session_rejects_async_push():
    srv = _mlp_server()
    sid = srv.create_session()
    sess = srv.session(sid)
    srv.close_session(sid)
    with pytest.raises(RuntimeError, match="closed"):
        sess.push_data([np.zeros((192,), np.float32)], asynchronous=True)


def test_tcp_async_push_and_flush():
    srv = _mlp_server(replicas=3)
    rpc = serve_tcp(srv)
    cli = ALClient(url=f"127.0.0.1:{rpc.port}", session="new")
    try:
        X, _ = image_pool(24, seed=6)
        tickets = [cli.push_data(list(X[i * 8:(i + 1) * 8]),
                                 asynchronous=True) for i in range(3)]
        assert all(len(t.keys) == 8 for t in tickets)
        for t in tickets:
            t.result(timeout=30)                   # server accepted
        cli.flush()                                # integration barrier
        st = cli.stats()
        assert st["pool"] == 24 and st["ingest_pending"] == 0
        res = cli.query(5, "lc")
        assert len(res["keys"]) == 5
    finally:
        cli.close()
        rpc.stop()


# --------------------------------------- interleaving property (slow) --
@pytest.mark.slow
def test_async_interleaving_matches_serial_replay():
    """Hypothesis: any interleaving of push_data(asynchronous=True), label,
    query and flush must match a serial replay oracle that pushes
    synchronously — versions monotone, no lost rows, and every barrier op
    observes all rows pushed before it."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    X, Y = image_pool(72, seed=9)
    chunks = [list(X[i * 6:(i + 1) * 6]) for i in range(12)]
    ops_st = st.lists(
        st.one_of(
            st.tuples(st.just("push"), st.integers(0, 11)),
            st.tuples(st.just("label"), st.integers(1, 4)),
            st.tuples(st.just("query"), st.integers(1, 5)),
            st.tuples(st.just("flush"), st.just(0)),
        ), min_size=1, max_size=10)

    @settings(max_examples=15, deadline=None)
    @given(ops=ops_st, replicas=st.sampled_from([2, 3]))
    def run(ops, replicas):
        asyn = _mlp_server(replicas=replicas)
        oracle = _mlp_server()
        pushed = set()
        versions = [asyn.stats()["pool_version"]]
        for op, arg in ops:
            if op == "push":
                t = asyn.push_data(chunks[arg], asynchronous=True)
                ok = oracle.push_data(chunks[arg])
                assert t.keys == ok                 # content addressing
                pushed.update(ok)
            elif op == "label":
                # deterministic pick: first `arg` unlabeled keys in pool
                # order, resolved AFTER the barrier on both servers
                asyn.flush()
                sess = asyn.session()
                todo = [k for k in sess._keys
                        if k not in sess._labels][:arg]
                ys = [hash(k) % 10 for k in todo]
                asyn.label(todo, ys)
                oracle.label(todo, ys)
            elif op == "query":
                budget = min(arg, len(pushed))
                if budget:
                    res = asyn.query(budget=budget, strategy="lc")
                    assert len(res["keys"]) == len(set(res["keys"]))
                    assert set(res["keys"]) <= pushed
            else:
                asyn.flush()
            versions.append(asyn.stats()["pool_version"])
        asyn.flush()
        # versions monotone
        assert all(a <= b for a, b in zip(versions, versions[1:]))
        # no lost rows: both servers hold exactly the pushed content, in
        # the same order (barriers linearize every push before the next op)
        a_sess, o_sess = asyn.session(), oracle.session()
        assert a_sess._keys == o_sess._keys
        assert set(a_sess._keys) == pushed
        assert a_sess._labels == o_sess._labels
        assert asyn.stats()["ingest_pending"] == 0

    run()
