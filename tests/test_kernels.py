"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracle (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# the whole module is the kernel lane: run it alone with `pytest -m interpret`
pytestmark = pytest.mark.interpret

rng = np.random.default_rng(0)


def _arr(shape, dtype, scale=1.0):
    x = rng.normal(size=shape) * scale
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------- uncertainty ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(16, 128), (5, 300), (64, 1024), (1, 37)])
def test_uncertainty_kernel(shape, dtype):
    from repro.kernels.uncertainty import ref
    from repro.kernels.uncertainty.kernel import uncertainty_stats_pallas

    lg = _arr(shape, dtype, scale=3.0)
    out = uncertainty_stats_pallas(lg, row_block=8, v_block=128,
                                   interpret=True)
    rr = ref.uncertainty_stats_ref(lg)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    for i, k in enumerate(("lc", "mc", "rc", "es")):
        np.testing.assert_allclose(out[i], rr[k], rtol=tol, atol=tol,
                                   err_msg=f"{k} {shape} {dtype}")


def test_uncertainty_extreme_logits():
    """Online stats must survive large logit magnitudes (no overflow)."""
    from repro.kernels.uncertainty import ref
    from repro.kernels.uncertainty.kernel import uncertainty_stats_pallas

    lg = _arr((8, 512), jnp.float32, scale=80.0)
    out = uncertainty_stats_pallas(lg, interpret=True)
    rr = ref.uncertainty_stats_ref(lg)
    for i, k in enumerate(("lc", "mc", "rc", "es")):
        np.testing.assert_allclose(out[i], rr[k], rtol=1e-4, atol=1e-4)


def test_uncertainty_ops_dispatch():
    from repro.kernels.uncertainty import ops

    lg = _arr((32, 256), jnp.float32, scale=2.0)
    for kind in ("lc", "mc", "rc", "es"):
        a = ops.uncertainty_scores(lg, kind, impl="ref")
        b = ops.uncertainty_scores(lg, kind, impl="interpret")
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- pairwise ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("nmd", [(64, 32, 16), (100, 70, 64), (33, 257, 128)])
def test_pairwise_kernel(nmd, dtype):
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import pairwise_min_argmin_pallas

    N, M, d = nmd
    x = _arr((N, d), dtype)
    c = _arr((M, d), dtype)
    mind, argm = pairwise_min_argmin_pallas(x, c, n_block=16, m_block=64,
                                            interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(mind, ref.pairwise_min_dist_ref(x, c),
                               rtol=tol, atol=tol)
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(argm),
                                      np.asarray(ref.pairwise_argmin_ref(x, c)))


def test_pairwise_min_and_argmin_single_launch():
    from repro.kernels.pairwise import ops, ref

    x, c = _arr((70, 24), jnp.float32), _arr((33, 24), jnp.float32)
    mind, argm = ops.pairwise_min_and_argmin(x, c, impl="interpret")
    np.testing.assert_allclose(mind, ref.pairwise_min_dist_ref(x, c),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(argm),
                                  np.asarray(ref.pairwise_argmin_ref(x, c)))
    with ops.track_ops() as stats:
        ops.pairwise_min_and_argmin(x, c, impl="ref")
    assert stats["embedding_reads"] == 1       # the pair costs ONE pool pass


# --------------------------------------------------- fused greedy round ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("nrd", [(64, 1, 16), (100, 3, 64), (33, 8, 100),
                                 (257, 5, 130)])
def test_greedy_round_kernel(nrd, dtype):
    """Interpret-mode parity vs the jnp oracle on non-block-multiple N / R
    and d not a multiple of 128."""
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import greedy_round_pallas

    N, R, d = nrd
    x = _arr((N, d), dtype)
    c = _arr((R, d), dtype)
    mind = jnp.asarray(np.abs(rng.normal(size=(N,))) * 10, jnp.float32)
    sel = jnp.asarray(rng.choice(N, R, replace=False), jnp.int32)
    nm_k, ni_k, nv_k = greedy_round_pallas(x, mind, c, sel, n_block=16,
                                           interpret=True)
    nm_r, ni_r, nv_r = ref.greedy_round_ref(x, mind, c, sel)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(nm_k, nm_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(nv_k, nv_r, rtol=tol, atol=tol)
    if dtype == jnp.float32:
        assert int(ni_k) == int(ni_r)
    # masked rows must be pinned to -1 and never win the argmax
    np.testing.assert_array_equal(np.asarray(nm_k)[np.asarray(sel)], -1.0)
    assert int(ni_k) not in set(np.asarray(sel).tolist())


def test_greedy_round_weighted_argmax():
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import greedy_round_pallas

    N, R, d = 90, 2, 48
    x = _arr((N, d), jnp.float32)
    c = _arr((R, d), jnp.float32)
    mind = jnp.asarray(np.abs(rng.normal(size=(N,))) * 10, jnp.float32)
    sel = jnp.asarray([3, 77], jnp.int32)
    w = jnp.asarray(np.abs(rng.normal(size=(N,))) + 0.1, jnp.float32)
    nm_k, ni_k, nv_k = greedy_round_pallas(x, mind, c, sel, w, n_block=32,
                                           interpret=True)
    nm_r, ni_r, nv_r = ref.greedy_round_ref(x, mind, c, sel, w)
    np.testing.assert_allclose(nm_k, nm_r, rtol=1e-4, atol=1e-4)
    assert int(ni_k) == int(ni_r)
    np.testing.assert_allclose(nv_k, nv_r, rtol=1e-4, atol=1e-4)


def test_greedy_round_no_mask_sentinel():
    """sel_idx = -1 must mask nothing."""
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import greedy_round_pallas

    x = _arr((40, 32), jnp.float32)
    c = _arr((1, 32), jnp.float32)
    mind = jnp.full((40,), 1e9, jnp.float32)
    no_mask = jnp.full((1,), -1, jnp.int32)
    nm_k, _, _ = greedy_round_pallas(x, mind, c, no_mask, n_block=16,
                                     interpret=True)
    np.testing.assert_allclose(nm_k, ref.pairwise_min_dist_ref(x, c),
                               rtol=1e-4, atol=1e-4)
    assert np.all(np.asarray(nm_k) >= 0.0)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_warm_start_chunked_matches_oneshot(impl):
    """Core-Set warm start: chunked multi-center passes == one-shot min."""
    from repro.kernels.pairwise import ops, ref

    x = _arr((123, 130), jnp.float32)        # d not a multiple of 128
    cen = _arr((37, 130), jnp.float32)       # M not a multiple of r_block
    got = ops.warm_start_min_dist(x, cen, impl=impl, r_block=10)
    np.testing.assert_allclose(got, ref.pairwise_min_dist_ref(x, cen),
                               rtol=1e-4, atol=1e-4)
    with ops.track_ops() as stats:
        ops.warm_start_min_dist(x, cen, impl=impl, r_block=10)
    assert stats["embedding_reads"] == 4     # ceil(37 / 10) pool passes


def test_greedy_round_op_accounting():
    from repro.kernels.pairwise import ops

    x = _arr((64, 16), jnp.float32)
    mind = jnp.full((64,), 1e9, jnp.float32)
    with ops.track_ops() as stats:
        for i in range(5):
            mind, nxt, _ = ops.greedy_round(
                x, mind, x[i][None, :], jnp.asarray([i], jnp.int32),
                impl="ref")
    assert stats["embedding_reads"] == 5     # exactly one pool read / round


# ------------------------------------------ fused round edge cases (PR 2) ----
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("nblock", [16, 64])
def test_greedy_round_weighted_random_parity(seed, nblock):
    """Random weights, N not divisible by n_block: kernel == oracle, with a
    bit-identical argmax."""
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import greedy_round_pallas

    r = np.random.default_rng(seed)
    N, R, d = 50, 3, 24
    x = jnp.asarray(r.normal(size=(N, d)), jnp.float32)
    c = jnp.asarray(r.normal(size=(R, d)), jnp.float32)
    mind = jnp.asarray(np.abs(r.normal(size=(N,))) * 5, jnp.float32)
    sel = jnp.asarray(r.choice(N, R, replace=False), jnp.int32)
    w = jnp.asarray(r.uniform(0.0, 2.0, size=(N,)), jnp.float32)
    nm_k, ni_k, nv_k = greedy_round_pallas(x, mind, c, sel, w,
                                           n_block=nblock, interpret=True)
    nm_r, ni_r, nv_r = ref.greedy_round_ref(x, mind, c, sel, w)
    np.testing.assert_allclose(nm_k, nm_r, rtol=1e-4, atol=1e-4)
    assert int(ni_k) == int(ni_r)
    np.testing.assert_allclose(nv_k, nv_r, rtol=1e-4, atol=1e-4)


def test_greedy_round_fully_masked_block():
    """An ENTIRE n_block of rows is selected this round: the winner must
    come from the other blocks, never the all-masked one."""
    from repro.kernels.pairwise.kernel import greedy_round_pallas

    N, d, nb = 48, 32, 16
    x = _arr((N, d), jnp.float32)
    sel = jnp.arange(16, 32, dtype=jnp.int32)          # all of block 1
    c = x[16:32]                                       # fold those 16 centers
    mind = jnp.full((N,), 1e6, jnp.float32)
    nm, ni, _ = greedy_round_pallas(x, mind, c, sel, n_block=nb,
                                    interpret=True)
    assert not (16 <= int(ni) < 32)
    np.testing.assert_array_equal(np.asarray(nm)[16:32], -1.0)
    # centers/sel length mismatch must be a loud error, not silent
    # mispadding — on the kernel AND on every ops dispatch path (the ref
    # oracle would otherwise quietly leave queued centers unmasked)
    from repro.kernels.pairwise import ops
    with pytest.raises(ValueError):
        greedy_round_pallas(x, mind, x[:1], sel, n_block=nb, interpret=True)
    with pytest.raises(ValueError):
        ops.greedy_round(x, mind, x[:1], sel, impl="ref")


@pytest.mark.parametrize("impl_interpret", [False, True])
def test_greedy_round_all_but_one_selected(impl_interpret):
    """Every row but one carries the selected -1 marker (or is masked this
    round): the argmax must return the single live row — even when its
    weight is ZERO, where the old ``-1 * w`` masking tied at -0.0 and could
    leak a masked row."""
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import greedy_round_pallas

    N, d, live = 40, 16, 23
    x = _arr((N, d), jnp.float32)
    c = _arr((1, d), jnp.float32)
    mind = jnp.full((N,), -1.0, jnp.float32).at[live].set(50.0)
    sel = jnp.full((1,), -1, jnp.int32)
    w = jnp.zeros((N,), jnp.float32)                   # zero weights
    if impl_interpret:
        _, ni, _ = greedy_round_pallas(x, mind, c, sel, w, n_block=16,
                                       interpret=True)
    else:
        _, ni, _ = ref.greedy_round_ref(x, mind, c, sel, w)
    assert int(ni) == live


def test_greedy_round_zero_weight_masked_row_never_wins():
    """Masked row 0 with weight 0 scored -0.0 under ``-1 * w`` masking and
    argmax-tied (first index wins) against legitimate zero-score rows; it
    must lose now that masked rows pin to -BIG."""
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import greedy_round_pallas

    N, d = 24, 16
    x = _arr((N, d), jnp.float32)
    x = x.at[5].set(x[0])                              # row 5 duplicates row 0
    c = x[0][None, :]
    mind = jnp.full((N,), 1e6, jnp.float32)
    sel = jnp.zeros((1,), jnp.int32)                   # mask row 0
    w = jnp.zeros((N,), jnp.float32)                   # all scores 0 or -BIG
    for got in (greedy_round_pallas(x, mind, c, sel, w, n_block=8,
                                    interpret=True)[1],
                ref.greedy_round_ref(x, mind, c, sel, w)[1]):
        assert int(got) != 0                           # never the masked row
        assert int(got) == 1                           # first live row ties win


@pytest.mark.parametrize("nblock", [8, 16, 32, 64])
def test_greedy_round_tiebreak_stable_across_n_block(nblock):
    """Exact score ties must break to the LOWEST pool index for every
    n_block (per-block argmax takes the first max, the host reduction the
    first max block) — selections must not depend on the launch tiling."""
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import greedy_round_pallas

    N, d = 64, 16
    base = _arr((N, d), jnp.float32)
    # rows 9, 27, 58 identical -> identical distance and weight -> 3-way tie
    x = base.at[27].set(base[9]).at[58].set(base[9])
    far = base[9] + 100.0                              # make them the winners
    x = x * 0.01 + 0.0
    x = x.at[9].set(far).at[27].set(far).at[58].set(far)
    c = jnp.zeros((1, d), jnp.float32)
    mind = jnp.full((N,), 1e9, jnp.float32)
    sel = jnp.full((1,), -1, jnp.int32)
    w = jnp.ones((N,), jnp.float32)
    _, ni, _ = greedy_round_pallas(x, mind, c, sel, w, n_block=nblock,
                                   interpret=True)
    _, ni_r, _ = ref.greedy_round_ref(x, mind, c, sel, w)
    assert int(ni) == int(ni_r) == 9


# ------------------------------------------------------------- autotuner ----
def test_autotune_blocks_cached_and_feasible(monkeypatch):
    from repro.kernels.pairwise import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE_DIR", "")    # hermetic: no disk
    autotune.clear_cache()
    ch = autotune.autotune_blocks(4096, 64, jnp.float32, measure=False)
    assert ch.n_block in autotune.N_BLOCK_CANDIDATES
    assert ch.r_block in autotune.R_BLOCK_CANDIDATES
    assert autotune.tile_vmem_bytes(64, 4, ch.n_block, ch.r_block) \
        <= autotune.VMEM_BUDGET_BYTES
    assert autotune.autotune_blocks(4096, 64, jnp.float32) is ch  # cached
    assert (4096, 64, "float32", "round") in autotune.report()
    # the gated (block-masked) round is a SEPARATE cache entry: its winner
    # must never alias the plain round's (the PR-6 collision bug)
    ch_gated = autotune.autotune_blocks(4096, 64, jnp.float32,
                                        measure=False, variant="gated")
    assert (4096, 64, "float32", "gated") in autotune.report()
    assert autotune.autotune_blocks(
        4096, 64, jnp.float32, variant="gated") is ch_gated
    assert autotune.autotune_blocks(4096, 64, jnp.float32) is ch
    with pytest.raises(ValueError, match="variant"):
        autotune.autotune_blocks(4096, 64, jnp.float32, variant="bogus")
    # a huge feature dim must force smaller tiles, not blow the budget
    ch_wide = autotune.autotune_blocks(4096, 8192, jnp.float32, measure=False)
    assert autotune.tile_vmem_bytes(8192, 4, ch_wide.n_block,
                                    ch_wide.r_block) \
        <= autotune.VMEM_BUDGET_BYTES
    assert ch_wide.n_block <= ch.n_block


def test_autotune_disk_cache_roundtrip(tmp_path, monkeypatch):
    """Winners persist to the result directory (one JSON per shape key) and
    reload across processes/cache clears; a corrupt entry re-tunes instead
    of crashing; disabling via empty env writes nothing."""
    from repro.kernels.pairwise import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE_DIR", str(tmp_path))
    autotune.clear_cache()
    ch = autotune.autotune_blocks(2048, 32, jnp.float32, measure=False)
    entry = tmp_path / "n2048_d32_float32_round.json"
    assert entry.exists()
    autotune.clear_cache()                       # simulate a fresh process
    assert autotune.autotune_blocks(2048, 32, jnp.float32,
                                    measure=False) == ch
    entry.write_text("not json")                 # corrupt: re-tune, rewrite
    autotune.clear_cache()
    assert autotune.autotune_blocks(2048, 32, jnp.float32,
                                    measure=False) == ch
    assert entry.read_text() != "not json"
    # variants persist to DISTINCT files; a pre-variant (format-1) entry
    # under the old aliasing name is never read
    autotune.autotune_blocks(2048, 32, jnp.float32, measure=False,
                             variant="gated")
    assert (tmp_path / "n2048_d32_float32_gated.json").exists()
    legacy = tmp_path / "n512_d8_float32.json"
    legacy.write_text('{"format": 1, "n_block": 64, "r_block": 8, '
                      '"hbm_bytes": 0.0, "wall_s": 0.0, "source": "model"}')
    autotune.clear_cache()
    autotune.autotune_blocks(512, 8, jnp.float32, measure=False)
    assert (tmp_path / "n512_d8_float32_round.json").exists()
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE_DIR", "")
    autotune.clear_cache()
    autotune.autotune_blocks(1024, 16, jnp.float32, measure=False)
    assert not (tmp_path / "n1024_d16_float32_round.json").exists()


def test_autotune_model_amortizes_r_block():
    """Bytes-per-folded-center must be non-increasing in r_block (that is
    the whole point of the multi-center warm start)."""
    from repro.kernels.pairwise import autotune

    per_center = [
        autotune.round_hbm_bytes(4096, 64, 4, 256, rb) / rb
        for rb in autotune.R_BLOCK_CANDIDATES
    ]
    assert all(a >= b for a, b in zip(per_center, per_center[1:]))


def test_greedy_round_autotuned_default_matches_ref():
    """ops.greedy_round with n_block unset (autotuned) stays bit-identical
    to the oracle on the interpret path."""
    from repro.kernels.pairwise import ops, ref

    x = _arr((100, 24), jnp.float32)
    c = _arr((2, 24), jnp.float32)
    mind = jnp.asarray(np.abs(rng.normal(size=(100,))) * 5, jnp.float32)
    sel = jnp.asarray([7, 42], jnp.int32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, size=(100,)), jnp.float32)
    nm_k, ni_k, _ = ops.greedy_round(x, mind, c, sel, weights=w,
                                     impl="interpret")
    nm_r, ni_r, _ = ref.greedy_round_ref(x, mind, c, sel, w)
    np.testing.assert_allclose(nm_k, nm_r, rtol=1e-4, atol=1e-4)
    assert int(ni_k) == int(ni_r)


# ------------------------------------------------- gated (masked) round ----
@pytest.mark.parametrize("nrd", [(64, 3, 16), (100, 5, 64), (33, 2, 100),
                                 (257, 9, 40)])
def test_gated_greedy_round_kernel(nrd):
    """Interpret-mode parity vs the oracle on ragged N with a random
    live/pending pattern: dead blocks pass mind through untouched, live
    blocks catch up only on the centers they have not folded."""
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import gated_greedy_round_pallas

    N, R, d = nrd
    nb = 16
    nn = -(-N // nb)
    x = _arr((N, d), jnp.float32)
    c = _arr((R, d), jnp.float32)
    mind = jnp.asarray(np.abs(rng.normal(size=(N,))) * 10, jnp.float32)
    live = jnp.asarray(rng.integers(0, 2, size=nn), jnp.int32)
    pend = jnp.asarray(rng.integers(0, R + 1, size=nn), jnp.int32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, size=(N,)), jnp.float32)
    for weights in (None, w):
        nm_k, ni_k, nv_k = gated_greedy_round_pallas(
            x, mind, c, live, pend, weights=weights, n_block=nb,
            interpret=True)
        nm_r, ni_r, nv_r = ref.gated_greedy_round_ref(
            x, mind, c, live, pend, weights=weights, n_block=nb)
        np.testing.assert_allclose(nm_k, nm_r, rtol=1e-4, atol=1e-4)
        assert int(ni_k) == int(ni_r)
        np.testing.assert_allclose(nv_k, nv_r, rtol=1e-4, atol=1e-4)
    # dead blocks: mind passes through bitwise
    dead_rows = np.concatenate(
        [np.arange(b * nb, min((b + 1) * nb, N))
         for b in np.nonzero(np.asarray(live) == 0)[0]]) \
        if (np.asarray(live) == 0).any() else np.zeros(0, np.int64)
    np.testing.assert_array_equal(np.asarray(nm_k)[dead_rows],
                                  np.asarray(mind)[dead_rows])


def test_gated_round_all_live_matches_plain_round():
    """Every block live with nothing pending-masked == the plain fused
    round (same floats), the degenerate-gate sanity check."""
    from repro.kernels.pairwise import ops

    x = _arr((90, 32), jnp.float32)
    c = _arr((4, 32), jnp.float32)
    mind = jnp.asarray(np.abs(rng.normal(size=(90,))) * 10, jnp.float32)
    nn = -(-90 // 16)
    nm_g, ni_g, _ = ops.gated_greedy_round(
        x, mind, c, np.ones(nn, np.int64), np.zeros(nn, np.int64),
        impl="interpret", n_block=16)
    sel = jnp.full((4,), -1, jnp.int32)
    nm_p, ni_p, _ = ops.greedy_round(x, mind, c, sel, impl="interpret")
    np.testing.assert_array_equal(np.asarray(nm_g), np.asarray(nm_p))
    assert int(ni_g) == int(ni_p)


def test_gated_round_accounting_counts_live_rows_only():
    from repro.kernels.pairwise import ops

    x = _arr((100, 8), jnp.float32)
    c = _arr((1, 8), jnp.float32)
    mind = jnp.full((100,), 1e9, jnp.float32)
    live = np.array([1, 0, 0, 1], np.int64)      # blocks of 32: 32+4 rows
    with ops.track_ops() as stats:
        ops.gated_greedy_round(x, mind, c, live, np.zeros(4, np.int64),
                               impl="ref", n_block=32)
    assert stats["pool_rows"] == 32 + 4          # last block is ragged
    with pytest.raises(ValueError, match="block_live"):
        ops.gated_greedy_round(x, mind, c, np.ones(3, np.int64),
                               np.zeros(3, np.int64), n_block=32)


# -------------------------------------------------------- flash attention ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "cfg", [
        dict(B=2, Sq=64, Skv=64, H=4, KH=2, D=32, causal=True, win=None),
        dict(B=1, Sq=48, Skv=80, H=4, KH=4, D=16, causal=True, win=16),
        dict(B=2, Sq=33, Skv=100, H=8, KH=2, D=64, causal=False, win=None),
        dict(B=1, Sq=128, Skv=128, H=8, KH=1, D=64, causal=True, win=None),
    ])
def test_flash_attention_kernel(cfg, dtype):
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import flash_attention_ref

    q = _arr((cfg["B"], cfg["Sq"], cfg["H"], cfg["D"]), dtype)
    k = _arr((cfg["B"], cfg["Skv"], cfg["KH"], cfg["D"]), dtype)
    v = _arr((cfg["B"], cfg["Skv"], cfg["KH"], cfg["D"]), dtype)
    out = flash_attention_pallas(q, k, v, causal=cfg["causal"],
                                 window=cfg["win"], q_block=16, kv_block=32,
                                 interpret=True)
    rf = flash_attention_ref(q, k, v, causal=cfg["causal"], window=cfg["win"])
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(rf, np.float32), rtol=tol, atol=tol)


# -------------------------------------------------------- decode attention ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "cfg", [
        dict(B=2, H=4, KH=2, D=32, S=128, cur=77, win=None),
        dict(B=1, H=8, KH=1, D=64, S=96, cur=96, win=None),
        dict(B=2, H=4, KH=4, D=16, S=64, cur=13, win=8),
        dict(B=3, H=16, KH=2, D=64, S=200, cur=1, win=None),
    ])
def test_decode_attention_kernel(cfg, dtype):
    from repro.kernels.decode_attention.kernel import decode_attention_pallas
    from repro.kernels.decode_attention.ref import decode_attention_ref

    q = _arr((cfg["B"], 1, cfg["H"], cfg["D"]), dtype)
    k = _arr((cfg["B"], cfg["S"], cfg["KH"], cfg["D"]), dtype)
    v = _arr((cfg["B"], cfg["S"], cfg["KH"], cfg["D"]), dtype)
    out = decode_attention_pallas(q, k, v, cfg["cur"], window=cfg["win"],
                                  kv_block=32, interpret=True)
    rf = decode_attention_ref(q, k, v, cfg["cur"], window=cfg["win"])
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(rf, np.float32), rtol=tol, atol=tol)


# ------------------------------------------------- blockwise encoder lane ----
def test_blockwise_encoder_interpret_matches_chunked():
    """The serving blockwise attention path through the Pallas flash kernel
    (interpret mode) vs the chunked-jnp production fallback: same encoder,
    same params, same blocks — features agree to fp32 kernel tolerance.
    Covers the intra/inter-block (causal, GQA, block-padded) shapes the
    TransformerBackend feeds the kernel on TPU."""
    from repro.data.synthetic import text_pool
    from repro.models import blockwise
    from repro.service.backends import TransformerBackend

    toks, _ = text_pool(6, num_classes=3, seq_len=40, vocab=512, seed=11)
    kw = dict(seq_len=40, block_size=16, kv_chunk=16)
    chunked = TransformerBackend(attention_impl="chunked", **kw)
    interp = TransformerBackend(attention_impl="interpret", **kw)
    x = chunked.preprocess(toks)
    fc = chunked.features(x)
    fi = interp.features(x)
    np.testing.assert_allclose(fi, fc, rtol=2e-4, atol=2e-4)
    # and directly at the encode level with a non-dividing block
    params = chunked.params
    cfg = chunked.cfg
    emb = blockwise.embed_tokens(cfg, params, jnp.asarray(x))
    hc = blockwise.blockwise_encode(cfg, params, emb, block=7, kv_chunk=16,
                                    impl="chunked")
    hi = blockwise.blockwise_encode(cfg, params, emb, block=7, kv_chunk=16,
                                    impl="interpret")
    np.testing.assert_allclose(np.asarray(hi), np.asarray(hc),
                               rtol=2e-4, atol=2e-4)
