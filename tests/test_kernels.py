"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracle (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(0)


def _arr(shape, dtype, scale=1.0):
    x = rng.normal(size=shape) * scale
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------- uncertainty ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(16, 128), (5, 300), (64, 1024), (1, 37)])
def test_uncertainty_kernel(shape, dtype):
    from repro.kernels.uncertainty import ref
    from repro.kernels.uncertainty.kernel import uncertainty_stats_pallas

    lg = _arr(shape, dtype, scale=3.0)
    out = uncertainty_stats_pallas(lg, row_block=8, v_block=128,
                                   interpret=True)
    rr = ref.uncertainty_stats_ref(lg)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    for i, k in enumerate(("lc", "mc", "rc", "es")):
        np.testing.assert_allclose(out[i], rr[k], rtol=tol, atol=tol,
                                   err_msg=f"{k} {shape} {dtype}")


def test_uncertainty_extreme_logits():
    """Online stats must survive large logit magnitudes (no overflow)."""
    from repro.kernels.uncertainty import ref
    from repro.kernels.uncertainty.kernel import uncertainty_stats_pallas

    lg = _arr((8, 512), jnp.float32, scale=80.0)
    out = uncertainty_stats_pallas(lg, interpret=True)
    rr = ref.uncertainty_stats_ref(lg)
    for i, k in enumerate(("lc", "mc", "rc", "es")):
        np.testing.assert_allclose(out[i], rr[k], rtol=1e-4, atol=1e-4)


def test_uncertainty_ops_dispatch():
    from repro.kernels.uncertainty import ops

    lg = _arr((32, 256), jnp.float32, scale=2.0)
    for kind in ("lc", "mc", "rc", "es"):
        a = ops.uncertainty_scores(lg, kind, impl="ref")
        b = ops.uncertainty_scores(lg, kind, impl="interpret")
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- pairwise ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("nmd", [(64, 32, 16), (100, 70, 64), (33, 257, 128)])
def test_pairwise_kernel(nmd, dtype):
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import pairwise_min_argmin_pallas

    N, M, d = nmd
    x = _arr((N, d), dtype)
    c = _arr((M, d), dtype)
    mind, argm = pairwise_min_argmin_pallas(x, c, n_block=16, m_block=64,
                                            interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(mind, ref.pairwise_min_dist_ref(x, c),
                               rtol=tol, atol=tol)
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(argm),
                                      np.asarray(ref.pairwise_argmin_ref(x, c)))


def test_pairwise_min_and_argmin_single_launch():
    from repro.kernels.pairwise import ops, ref

    x, c = _arr((70, 24), jnp.float32), _arr((33, 24), jnp.float32)
    mind, argm = ops.pairwise_min_and_argmin(x, c, impl="interpret")
    np.testing.assert_allclose(mind, ref.pairwise_min_dist_ref(x, c),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(argm),
                                  np.asarray(ref.pairwise_argmin_ref(x, c)))
    with ops.track_ops() as stats:
        ops.pairwise_min_and_argmin(x, c, impl="ref")
    assert stats["embedding_reads"] == 1       # the pair costs ONE pool pass


# --------------------------------------------------- fused greedy round ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("nrd", [(64, 1, 16), (100, 3, 64), (33, 8, 100),
                                 (257, 5, 130)])
def test_greedy_round_kernel(nrd, dtype):
    """Interpret-mode parity vs the jnp oracle on non-block-multiple N / R
    and d not a multiple of 128."""
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import greedy_round_pallas

    N, R, d = nrd
    x = _arr((N, d), dtype)
    c = _arr((R, d), dtype)
    mind = jnp.asarray(np.abs(rng.normal(size=(N,))) * 10, jnp.float32)
    sel = jnp.asarray(rng.choice(N, R, replace=False), jnp.int32)
    nm_k, ni_k, nv_k = greedy_round_pallas(x, mind, c, sel, n_block=16,
                                           interpret=True)
    nm_r, ni_r, nv_r = ref.greedy_round_ref(x, mind, c, sel)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(nm_k, nm_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(nv_k, nv_r, rtol=tol, atol=tol)
    if dtype == jnp.float32:
        assert int(ni_k) == int(ni_r)
    # masked rows must be pinned to -1 and never win the argmax
    np.testing.assert_array_equal(np.asarray(nm_k)[np.asarray(sel)], -1.0)
    assert int(ni_k) not in set(np.asarray(sel).tolist())


def test_greedy_round_weighted_argmax():
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import greedy_round_pallas

    N, R, d = 90, 2, 48
    x = _arr((N, d), jnp.float32)
    c = _arr((R, d), jnp.float32)
    mind = jnp.asarray(np.abs(rng.normal(size=(N,))) * 10, jnp.float32)
    sel = jnp.asarray([3, 77], jnp.int32)
    w = jnp.asarray(np.abs(rng.normal(size=(N,))) + 0.1, jnp.float32)
    nm_k, ni_k, nv_k = greedy_round_pallas(x, mind, c, sel, w, n_block=32,
                                           interpret=True)
    nm_r, ni_r, nv_r = ref.greedy_round_ref(x, mind, c, sel, w)
    np.testing.assert_allclose(nm_k, nm_r, rtol=1e-4, atol=1e-4)
    assert int(ni_k) == int(ni_r)
    np.testing.assert_allclose(nv_k, nv_r, rtol=1e-4, atol=1e-4)


def test_greedy_round_no_mask_sentinel():
    """sel_idx = -1 must mask nothing."""
    from repro.kernels.pairwise import ref
    from repro.kernels.pairwise.kernel import greedy_round_pallas

    x = _arr((40, 32), jnp.float32)
    c = _arr((1, 32), jnp.float32)
    mind = jnp.full((40,), 1e9, jnp.float32)
    no_mask = jnp.full((1,), -1, jnp.int32)
    nm_k, _, _ = greedy_round_pallas(x, mind, c, no_mask, n_block=16,
                                     interpret=True)
    np.testing.assert_allclose(nm_k, ref.pairwise_min_dist_ref(x, c),
                               rtol=1e-4, atol=1e-4)
    assert np.all(np.asarray(nm_k) >= 0.0)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_warm_start_chunked_matches_oneshot(impl):
    """Core-Set warm start: chunked multi-center passes == one-shot min."""
    from repro.kernels.pairwise import ops, ref

    x = _arr((123, 130), jnp.float32)        # d not a multiple of 128
    cen = _arr((37, 130), jnp.float32)       # M not a multiple of r_block
    got = ops.warm_start_min_dist(x, cen, impl=impl, r_block=10)
    np.testing.assert_allclose(got, ref.pairwise_min_dist_ref(x, cen),
                               rtol=1e-4, atol=1e-4)
    with ops.track_ops() as stats:
        ops.warm_start_min_dist(x, cen, impl=impl, r_block=10)
    assert stats["embedding_reads"] == 4     # ceil(37 / 10) pool passes


def test_greedy_round_op_accounting():
    from repro.kernels.pairwise import ops

    x = _arr((64, 16), jnp.float32)
    mind = jnp.full((64,), 1e9, jnp.float32)
    with ops.track_ops() as stats:
        for i in range(5):
            mind, nxt, _ = ops.greedy_round(
                x, mind, x[i][None, :], jnp.asarray([i], jnp.int32),
                impl="ref")
    assert stats["embedding_reads"] == 5     # exactly one pool read / round


# -------------------------------------------------------- flash attention ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "cfg", [
        dict(B=2, Sq=64, Skv=64, H=4, KH=2, D=32, causal=True, win=None),
        dict(B=1, Sq=48, Skv=80, H=4, KH=4, D=16, causal=True, win=16),
        dict(B=2, Sq=33, Skv=100, H=8, KH=2, D=64, causal=False, win=None),
        dict(B=1, Sq=128, Skv=128, H=8, KH=1, D=64, causal=True, win=None),
    ])
def test_flash_attention_kernel(cfg, dtype):
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import flash_attention_ref

    q = _arr((cfg["B"], cfg["Sq"], cfg["H"], cfg["D"]), dtype)
    k = _arr((cfg["B"], cfg["Skv"], cfg["KH"], cfg["D"]), dtype)
    v = _arr((cfg["B"], cfg["Skv"], cfg["KH"], cfg["D"]), dtype)
    out = flash_attention_pallas(q, k, v, causal=cfg["causal"],
                                 window=cfg["win"], q_block=16, kv_block=32,
                                 interpret=True)
    rf = flash_attention_ref(q, k, v, causal=cfg["causal"], window=cfg["win"])
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(rf, np.float32), rtol=tol, atol=tol)


# -------------------------------------------------------- decode attention ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "cfg", [
        dict(B=2, H=4, KH=2, D=32, S=128, cur=77, win=None),
        dict(B=1, H=8, KH=1, D=64, S=96, cur=96, win=None),
        dict(B=2, H=4, KH=4, D=16, S=64, cur=13, win=8),
        dict(B=3, H=16, KH=2, D=64, S=200, cur=1, win=None),
    ])
def test_decode_attention_kernel(cfg, dtype):
    from repro.kernels.decode_attention.kernel import decode_attention_pallas
    from repro.kernels.decode_attention.ref import decode_attention_ref

    q = _arr((cfg["B"], 1, cfg["H"], cfg["D"]), dtype)
    k = _arr((cfg["B"], cfg["S"], cfg["KH"], cfg["D"]), dtype)
    v = _arr((cfg["B"], cfg["S"], cfg["KH"], cfg["D"]), dtype)
    out = decode_attention_pallas(q, k, v, cfg["cur"], window=cfg["win"],
                                  kv_block=32, interpret=True)
    rf = decode_attention_ref(q, k, v, cfg["cur"], window=cfg["win"])
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(rf, np.float32), rtol=tol, atol=tol)
