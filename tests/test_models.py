"""Model-layer equivalences: chunked==naive attention, decode==prefill
consistency, RWKV chunked==sequential, RG-LRU scan==stepwise, MLA absorbed
decode == expanded attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(3)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


# ------------------------------------------------------------- attention ----
@pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                           (False, None)])
def test_chunked_vs_naive_attention(causal, window):
    from repro.models.layers.attention import chunked_attention, naive_attention

    q = _arr((2, 70, 4, 16))
    k = _arr((2, 70, 2, 16))
    v = _arr((2, 70, 2, 16))
    a = chunked_attention(q, k, v, causal=causal, window=window,
                          q_chunk=16, kv_chunk=32)
    b = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_chunked_attention_kv_valid():
    from repro.models.layers.attention import chunked_attention, naive_attention
    q = _arr((1, 16, 2, 8))
    k = _arr((1, 40, 2, 8))
    v = _arr((1, 40, 2, 8))
    a = chunked_attention(q, k, v, causal=False, kv_valid=25, q_chunk=8,
                          kv_chunk=16)
    b = naive_attention(q, k, v, causal=False, kv_valid=25)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------- rwkv ----
def test_rwkv_chunked_vs_sequential():
    from repro.models.layers.rwkv import wkv_chunked, wkv_sequential

    B, S, H, D = 2, 50, 3, 8
    r = _arr((B, S, H, D))
    k = _arr((B, S, H, D))
    v = _arr((B, S, H, D))
    log_w = -jnp.exp(_arr((B, S, H, D), scale=0.5))     # realistic decays
    bonus = _arr((H, D), scale=0.2)
    S0 = _arr((B, H, D, D), scale=0.3)
    o1, st1 = wkv_sequential(r, k, v, log_w, bonus, S0)
    o2, st2 = wkv_chunked(r, k, v, log_w, bonus, S0, chunk=16)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st1, st2, rtol=2e-4, atol=2e-4)


def test_rwkv_strong_decay_stability():
    """Strong data-dependent decay must not overflow the chunked path."""
    from repro.models.layers.rwkv import wkv_chunked, wkv_sequential

    B, S, H, D = 1, 64, 2, 8
    r = _arr((B, S, H, D))
    k = _arr((B, S, H, D))
    v = _arr((B, S, H, D))
    log_w = -jnp.exp(_arr((B, S, H, D), scale=1.0) + 2.0)  # decay ~ e^2..e^4
    bonus = _arr((H, D), scale=0.2)
    S0 = jnp.zeros((B, H, D, D))
    o1, _ = wkv_sequential(r, k, v, log_w, bonus, S0)
    o2, _ = wkv_chunked(r, k, v, log_w, bonus, S0, chunk=16)
    assert np.all(np.isfinite(np.asarray(o2)))
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- rg-lru ----
def test_rglru_scan_vs_stepwise():
    from repro.models.layers.rglru import rglru_scan

    B, S, W = 2, 33, 16
    log_a = -jnp.exp(_arr((B, S, W), scale=0.5))
    gated = _arr((B, S, W))
    h0 = _arr((B, W))
    h_par = rglru_scan(log_a, gated, h0)
    # sequential reference
    a = np.exp(np.asarray(log_a, np.float64))
    b = np.sqrt(np.maximum(1 - a * a, 0)) * np.asarray(gated, np.float64)
    h = np.asarray(h0, np.float64)
    for t in range(S):
        h = a[:, t] * h + b[:, t]
    np.testing.assert_allclose(h_par[:, -1], h, rtol=1e-4, atol=1e-4)


# --------------------------------------------- decode == full-forward parity --
@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-v3-671b", "rwkv6-3b",
                                  "recurrentgemma-2b", "whisper-medium"])
def test_decode_matches_full_forward(arch):
    """prefill(S) + decode(token_S) must equal last_logits over S+1 tokens.

    This pins the whole cache machinery (ring buffers, MLA latents, RWKV /
    RG-LRU states) against the stateless path."""
    from repro.common.param import init_params
    from repro.configs import get_smoke_config
    from repro.models.transformer import Model

    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    batch_full = {"tokens": toks}
    batch_pre = {"tokens": toks[:, :S]}
    if cfg.enc_dec:
        frames = _arr((B, cfg.n_enc_frames, cfg.d_model), jnp.bfloat16)
        batch_full["frames"] = frames
        batch_pre["frames"] = frames
    if cfg.n_patches:
        pe = _arr((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        batch_full["patch_embeds"] = pe
        batch_pre["patch_embeds"] = pe

    full = np.asarray(jax.jit(model.last_logits)(params, batch_full))
    cache = init_params(model.cache_decls(B, S + 4), jax.random.PRNGKey(1))
    cache, _ = jax.jit(model.prefill)(params, batch_pre, cache)
    dec, _ = jax.jit(model.decode_step)(params, cache, toks[:, S:S + 1])
    dec = np.asarray(dec)
    # bf16 params + different reduction orders: compare argmax + loose values
    assert np.mean(np.argmax(full, -1) == np.argmax(dec, -1)) >= 0.99
    np.testing.assert_allclose(dec, full, rtol=0.08, atol=0.08)


# ---------------------------------------------------------------- moe ----
def test_moe_capacity_and_combine():
    from repro.configs.base import MoEConfig
    from repro.models.layers.moe import capacity, moe_apply, moe_decls
    from repro.common.param import init_params

    mo = MoEConfig(n_routed=8, top_k=2, d_ff_expert=16, n_shared=1,
                   group_size=32, capacity_factor=1.5)
    d = 24
    params = init_params(moe_decls(d, mo), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    x = _arr((2, 32, d))
    out, aux = moe_apply(params, x, mo)
    assert out.shape == x.shape
    assert np.all(np.isfinite(np.asarray(out)))
    assert float(aux) >= 0
    assert capacity(mo, 32) == max(int(np.ceil(32 * 2 / 8 * 1.5)), 2)


def test_moe_dispatch_respects_capacity():
    from repro.configs.base import MoEConfig
    from repro.models.layers.moe import _dispatch_combine

    mo = MoEConfig(n_routed=4, top_k=2, d_ff_expert=8, group_size=16)
    probs = jax.nn.softmax(_arr((2, 16, 4), scale=2.0), axis=-1)
    C = 5
    dispatch, combine, topi, topv = _dispatch_combine(probs, mo, C)
    d = np.asarray(dispatch)
    # each (group, expert, slot) holds at most one token
    assert d.sum(axis=1).max() <= 1
    # each token occupies at most top_k slots
    assert d.sum(axis=(2, 3)).max() <= mo.top_k
    # combine weights only where dispatched
    assert np.all((np.asarray(combine) > 0) <= d.astype(bool))


# ---------------------------------------------------------------- mla ----
def test_mla_decode_matches_prefill_expansion():
    from repro.common.param import init_params
    from repro.configs import get_smoke_config
    from repro.models.layers import mla as mla_lib

    cfg = get_smoke_config("deepseek-v3-671b")
    params = init_params(mla_lib.mla_decls(cfg), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    B, S = 2, 12
    x = _arr((B, S, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out_full, (ckv, kr) = mla_lib.mla_prefill(params, x, cfg, positions,
                                              impl="naive")
    # decode the last token against the compressed cache
    out_dec = mla_lib.mla_decode(
        params, x[:, S - 1:S], cfg, ckv, kr, S,
        jnp.full((B, 1), S - 1))
    np.testing.assert_allclose(out_dec[:, 0], out_full[:, -1],
                               rtol=2e-4, atol=2e-4)
