"""Concurrency stress: multi-tenant server hammering + pipeline teardown.

Marked ``slow``: CI runs these in the tier-2 lane (`-m slow`) so tier-1
stays fast.
"""
import threading
import time

import numpy as np
import pytest

from repro.data.synthetic import image_pool
from repro.service.backends import MLPBackend
from repro.service.batcher import DynamicBatcher
from repro.service.client import ALClient, serve_tcp
from repro.service.config import ALServiceConfig
from repro.service.pipeline import Stage, StagePipeline
from repro.service.server import ALServer

pytestmark = pytest.mark.slow


def _mlp_server(**cfg):
    return ALServer(ALServiceConfig(batch_size=16, **cfg),
                    backend=MLPBackend(in_dim=192, feat_dim=32))


def _wait_threads(baseline, timeout=5.0):
    deadline = time.time() + timeout
    while threading.active_count() > baseline and time.time() < deadline:
        time.sleep(0.02)
    return threading.active_count()


# ------------------------------------------------------ tenant hammering --
def test_multitenant_hammer_no_deadlock_no_leakage():
    """N threads interleave push_data/label/query/train on one server, each
    in its own session: every thread must finish (no deadlock) and only
    ever see its own keys (no cross-session leakage)."""
    srv = _mlp_server()
    n_threads, iters, per_push = 6, 4, 20
    errors = []
    seen = {}

    def tenant(tid):
        try:
            sid = srv.create_session()
            mine = set()
            X, Y = image_pool(iters * per_push, seed=100 + tid)
            for it in range(iters):
                xs = list(X[it * per_push:(it + 1) * per_push])
                ys = Y[it * per_push:(it + 1) * per_push]
                keys = srv.push_data(xs, session=sid)
                mine.update(keys)
                res = srv.query(budget=4, strategy="lc", session=sid)
                assert set(res["keys"]) <= mine, "cross-session leakage"
                srv.label(keys[:4], ys[:4], session=sid)
                srv.train_and_eval(session=sid)
            assert srv.stats(session=sid)["pool"] == len(mine)
            seen[tid] = mine
        except Exception as e:  # surfaced below; keep other threads going
            errors.append((tid, e))

    before = threading.active_count()
    threads = [threading.Thread(target=tenant, args=(i,), daemon=True)
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not any(t.is_alive() for t in threads), "hammer deadlocked"
    assert not errors, errors
    # distinct seeds -> distinct content keys -> fully disjoint pools
    all_keys = [k for s in seen.values() for k in s]
    assert len(all_keys) == len(set(all_keys))
    assert srv.stats()["pool"] == 0                   # default untouched
    assert _wait_threads(before) <= before + 1        # no thread leak


def test_multitenant_hammer_sharded_eviction_async():
    """The hammer at replicas=3 with a cache too small for any tenant's
    pool: interleaved async pushes, sharded queries (uncertainty AND
    k-center families, forcing per-shard recompute of evicted embeddings
    from raw copies), labels and training must all complete with no
    deadlock, no leakage and no lost rows."""
    srv = _mlp_server(replicas=3, cache_bytes=12 * 32 * 4)
    n_threads, iters, per_push = 4, 3, 18
    errors = []
    seen = {}

    def tenant(tid):
        try:
            sid = srv.create_session()
            mine = set()
            X, Y = image_pool(iters * per_push, seed=300 + tid)
            for it in range(iters):
                xs = list(X[it * per_push:(it + 1) * per_push])
                ys = Y[it * per_push:(it + 1) * per_push]
                ticket = srv.push_data(xs, asynchronous=(it % 2 == 0),
                                       session=sid)
                keys = (ticket.result(timeout=60)
                        if it % 2 == 0 else ticket)
                mine.update(keys)
                for strat in ("lc", "kcg"):
                    res = srv.query(budget=4, strategy=strat, session=sid)
                    assert set(res["keys"]) <= mine, "cross-session leakage"
                srv.label(keys[:4], ys[:4], session=sid)
                srv.train_and_eval(session=sid)
            srv.flush(session=sid)
            st = srv.stats(session=sid)
            assert st["pool"] == len(mine), "lost rows"
            assert st["ingest_pending"] == 0
            seen[tid] = mine
        except Exception as e:
            errors.append((tid, e))

    before = threading.active_count()
    threads = [threading.Thread(target=tenant, args=(i,), daemon=True)
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180)
    assert not any(t.is_alive() for t in threads), "sharded hammer deadlocked"
    assert not errors, errors
    assert srv.cache.stats()["entries"] < n_threads * iters * per_push, \
        "eviction never happened; shrink cache_bytes"
    all_keys = [k for s in seen.values() for k in s]
    assert len(all_keys) == len(set(all_keys))        # disjoint pools
    assert srv.stats()["pool"] == 0                   # default untouched
    # only long-lived infrastructure may outlive the tenants: one parked
    # ingest daemon per session that pushed async, plus the server's
    # shard-executor workers (<= replicas) — anything beyond that leaked
    budget = before + n_threads + srv.config.replicas
    assert _wait_threads(budget) <= budget


def test_tcp_concurrent_clients_no_deadlock():
    """Same interleaving through the TCP transport's worker pool."""
    srv = _mlp_server()
    rpc = serve_tcp(srv, max_workers=8)
    url = f"127.0.0.1:{rpc.port}"
    errors = []

    def client(tid):
        try:
            cli = ALClient(url=url, session="new")
            X, Y = image_pool(30, seed=200 + tid)
            keys = cli.push_data(list(X))
            res = cli.query(budget=5, strategy="mc")
            assert set(res["keys"]) <= set(keys)
            cli.label(res["keys"], [0] * len(res["keys"]))
            cli.train_eval()
            assert cli.stats()["pool"] == 30
            cli.close()
        except Exception as e:
            errors.append((tid, e))

    threads = [threading.Thread(target=client, args=(i,), daemon=True)
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    try:
        assert not any(t.is_alive() for t in threads), "TCP clients hung"
        assert not errors, errors
        assert srv.session_ids() == ["default"]
    finally:
        rpc.stop()


# ------------------------------------- pipeline + batcher failure storms --
def test_pipeline_under_batcher_random_failure_clean_teardown():
    """StagePipeline whose infer stage rides a DynamicBatcher, with a stage
    failing at a random item each iteration: every iteration must raise the
    injected error and tear down cleanly (no leaked worker threads, batcher
    close() returns)."""
    rng = np.random.default_rng(0)
    baseline = threading.active_count()
    for it in range(8):
        fail_at = int(rng.integers(0, 40))
        batcher = DynamicBatcher(
            lambda stacked, n: [stacked[i] * 2 for i in range(n)],
            max_batch=8, timeout_s=0.005)

        def flaky(x, fail_at=fail_at):
            if x == fail_at:
                raise ValueError(f"boom@{fail_at}")
            return x

        stages = [Stage("pre", lambda x: x), Stage("flaky", flaky),
                  Stage("infer",
                        lambda x: batcher.score([np.full(4, x)])[0])]
        pipe = StagePipeline(stages, max_queue=2)
        outcome = {}

        def drive():
            try:
                pipe.run(list(range(60)))
                outcome["r"] = "returned"
            except ValueError as e:
                outcome["r"] = f"raised:{e}"

        t = threading.Thread(target=drive, daemon=True)
        t.start()
        t.join(timeout=15)
        assert not t.is_alive(), f"iteration {it} deadlocked"
        assert outcome["r"] == f"raised:boom@{fail_at}"
        batcher.close()
        assert not batcher._thread.is_alive()
        after = _wait_threads(baseline)
        assert after <= baseline, \
            f"iteration {it} leaked threads ({after} > {baseline})"


def test_parallel_pshea_on_server_matches_serial():
    """End-to-end on a real (cheap-backend) server: the racing controller
    must reproduce the serial schedule bit-for-bit."""
    X, Y = image_pool(160, seed=5)
    EX, EY = image_pool(80, seed=6)
    srv = _mlp_server()
    keys = srv.push_data(list(X))
    key2y = dict(zip(keys, Y))
    srv.attach_oracle(lambda ks: [key2y[k] for k in ks], EX, EY)
    srv.label(keys[:16], Y[:16])
    srv.train_and_eval()
    serial = srv.query(budget=112, strategy="auto", target_accuracy=0.99,
                       pshea_workers=1)
    parallel = srv.query(budget=112, strategy="auto", target_accuracy=0.99,
                         pshea_workers=7)
    assert serial == parallel
