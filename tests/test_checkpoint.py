"""Checkpoint manager: roundtrip (incl. bf16 + scalars), atomicity, GC,
async, elastic restore across device counts (subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree():
    return {
        "w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 4)),
                         jnp.bfloat16),
        "b": jnp.arange(4, dtype=jnp.float32),
        "step": jnp.asarray(7, jnp.int32),
        "nested": [{"m": jnp.ones((3,), jnp.float32)}],
    }


def test_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path))
    t = _tree()
    m.save(3, t, metadata={"note": "x"})
    out, step, meta = m.restore(t)
    assert step == 3 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_roundtrip_compressed(tmp_path):
    m = CheckpointManager(str(tmp_path), compress=True)
    t = _tree()
    m.save(1, t)
    out, _, _ = m.restore(t)
    np.testing.assert_array_equal(np.asarray(t["w"], np.float32),
                                  np.asarray(out["w"], np.float32))


def test_gc_keeps_last_k(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        m.save(s, t)
    assert m.all_steps() == [3, 4]


def test_async_save(tmp_path):
    m = CheckpointManager(str(tmp_path))
    t = _tree()
    m.save_async(5, t)
    m.wait()
    assert m.latest_step() == 5


def test_incomplete_tmp_ignored(tmp_path):
    m = CheckpointManager(str(tmp_path))
    t = _tree()
    m.save(1, t)
    os.makedirs(tmp_path / "step_000000000009.tmp")   # simulated crash
    os.makedirs(tmp_path / "step_000000000010")        # no manifest
    assert m.latest_step() == 1
    out, step, _ = m.restore(t)
    assert step == 1


_ELASTIC = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.manager import CheckpointManager
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh((%d, %d), ("data", "model"))
    sh = NamedSharding(mesh, P("data", "model"))
    t = {"w": jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                             sh)}
    m = CheckpointManager(sys.argv[1])
    if sys.argv[2] == "save":
        m.save(1, t)
    else:
        out, _, _ = m.restore(t, shardings={"w": sh})
        np.testing.assert_array_equal(
            np.asarray(out["w"]),
            np.arange(64, dtype=np.float32).reshape(8, 8))
        assert out["w"].sharding == sh
        print("RESTORE_OK")
""")


@pytest.mark.slow
def test_elastic_restore_across_device_counts(tmp_path):
    """Save under a 4-device (2,2) mesh, restore under 8-device (4,2)."""
    env = dict(os.environ, PYTHONPATH="src")
    d = str(tmp_path / "ck")
    r = subprocess.run([sys.executable, "-c", _ELASTIC % (4, 2, 2), d, "save"],
                       capture_output=True, text=True, env=env, cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    r = subprocess.run([sys.executable, "-c", _ELASTIC % (8, 4, 2), d,
                        "restore"],
                       capture_output=True, text=True, env=env, cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "RESTORE_OK" in r.stdout
