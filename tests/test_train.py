"""Training substrate: optimizers, compression, fault tolerance, e2e loop."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.distributed.fault_tolerance import (FailureInjector,
                                               SimulatedFailure,
                                               StragglerMonitor, supervise)
from repro.optim.compression import (int8_dequantize, int8_quantize,
                                     topk_sparsify)
from repro.optim.optimizer import (Adafactor, AdamW, clip_by_global_norm,
                                   cosine_schedule)


# -------------------------------------------------------------- optimizers --
def _quadratic_progress(opt):
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    state = opt.init(params)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    return l0, float(loss(params))


def test_adamw_decreases_loss():
    l0, l1 = _quadratic_progress(AdamW(lr=cosine_schedule(0.05, 5, 1000),
                                       weight_decay=0.0))
    assert l1 < 0.3 * l0


def test_adafactor_decreases_loss():
    l0, l1 = _quadratic_progress(Adafactor(lr=cosine_schedule(0.05, 5, 1000)))
    assert l1 < 0.5 * l0


def test_adafactor_state_is_factored():
    opt = Adafactor()
    params = {"w": jnp.zeros((64, 32), jnp.float32)}
    st = opt.init(params)
    pp = st["per_param"]["w"]
    assert "vr" in pp and "vc" in pp and "v" not in pp
    assert pp["vr"].shape == (64,) and pp["vc"].shape == (32,)
    assert pp["m"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == 20.0
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(clipped["a"])), 1.0, rtol=1e-5)


# -------------------------------------------------------------- compression --
def test_topk_error_feedback_identity():
    g = jnp.asarray(np.random.default_rng(1).normal(size=(256,)), jnp.float32)
    sparse, err = topk_sparsify(g, 0.1)
    np.testing.assert_allclose(np.asarray(sparse + err), np.asarray(g),
                               rtol=1e-6, atol=1e-6)
    assert np.count_nonzero(np.asarray(sparse)) <= 26 + 1


def test_topk_error_feedback_converges():
    """Over steps, transmitted mass approaches the true accumulated grad."""
    rng = np.random.default_rng(2)
    err = jnp.zeros((128,))
    total_sent = jnp.zeros((128,))
    total_true = jnp.zeros((128,))
    for _ in range(50):
        g = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
        total_true = total_true + g
        sparse, err = topk_sparsify(g, 0.2, err)
        total_sent = total_sent + sparse
    resid = np.linalg.norm(np.asarray(total_sent + err - total_true))
    assert resid < 1e-4


def test_int8_quantization_error_bound():
    g = jnp.asarray(np.random.default_rng(3).normal(size=(1024,)),
                    jnp.float32)
    q, scale = int8_quantize(g)
    back = int8_dequantize(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) / 2 + 1e-6


# ---------------------------------------------------------- fault tolerance --
def test_straggler_monitor_flags_outlier():
    m = StragglerMonitor(threshold=2.0, warmup=3)
    for i in range(10):
        ev = m.observe(i, 0.1)
        assert ev is None
    ev = m.observe(10, 0.5)
    assert ev is not None and ev.ratio > 2.0
    # EMA not poisoned by the straggler
    assert abs(m.ema - 0.1) < 0.02


def test_failure_injector_fires_once():
    inj = FailureInjector([3])
    inj.maybe_fail(2)
    with pytest.raises(SimulatedFailure):
        inj.maybe_fail(3)
    inj.maybe_fail(3)   # second time: no-op


def test_supervise_restarts_until_done():
    state = {"ckpt": 0, "attempts": 0}

    def train_round(start):
        state["attempts"] += 1
        for step in range(start, 20):
            if step == 12 and state["attempts"] == 1:
                raise SimulatedFailure("boom")
            if step % 5 == 0:
                state["ckpt"] = step
        state["ckpt"] = 20
        return 20

    rep = supervise(train_round, total_steps=20,
                    latest_step=lambda: state["ckpt"])
    assert rep.restarts == 1 and rep.final_step == 20


# ------------------------------------------------------------------- e2e ----
@pytest.mark.slow
def test_training_loss_decreases(tmp_path):
    from repro.launch.train import run_training

    rep = run_training("qwen1.5-4b", smoke=True, steps=30, batch=4, seq=32,
                       pool_size=64, log_every=0, lr=1e-3, warmup=5)
    first = np.mean(rep.losses[:5])
    last = np.mean(rep.losses[-5:])
    assert last < first - 0.05, (first, last)


@pytest.mark.slow
def test_training_failure_resume(tmp_path):
    from repro.launch.train import run_training

    rep = run_training("qwen1.5-4b", smoke=True, steps=16, batch=2, seq=32,
                       pool_size=32, ckpt_dir=str(tmp_path / "ck"),
                       ckpt_every=5, fail_at=[8], log_every=0)
    assert rep.restarts == 1
    assert rep.steps == 16
    assert rep.ckpt_steps[-1] == 16
