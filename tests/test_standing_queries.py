"""Standing queries (streaming continuous AL) + persisted k-center
strategy state.

The contracts under test, each against its knob-as-oracle twin:

- every standing-query emit is the EXACT selection a one-shot ``query()``
  returns over the pool at that moment, so the final emit after the
  stream settles is bit-identical to a one-shot over the final pool
  (``standing_replay: false`` forces full re-selections — same keys);
- persisted min-dist state (``strategy_state_cache: true``) re-folds only
  the rows/centers appended since the last warm query and selects
  bit-identically to the ``false`` from-scratch oracle;
- the invalidation matrix: a push extends only the shards it touched, a
  retrain drops every shard's min-dist but re-embeds nothing, a label
  drops nothing (op-accounted in embed rows + KCenterStateCache
  counters);
- the feature path is batch-insensitive: the same pool pushed in any
  chunking yields bitwise-identical feats columns and selections, even
  with a tiny EmbeddingCache forcing evicted-entry recomputes;
- close_session / a dead ingest worker cancel standing queries cleanly
  (polls raise ticket-style; no orphaned emits).
"""
import time

import numpy as np
import pytest

from repro.core.selection import replica_of
from repro.data.synthetic import image_pool
from repro.kernels.pairwise import ops
from repro.service.backends import MLPBackend
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer


def _mlp_server(replicas=1, **cfg):
    return ALServer(ALServiceConfig(batch_size=16, replicas=replicas, **cfg),
                    backend=MLPBackend(in_dim=192, feat_dim=32))


def _near_dups(X, n, scale=1e-4, seed=0):
    """Tiny perturbations of existing rows: new content keys, but their
    min-dist to the already-labeled centers is ~0, so they can never
    displace a recorded per-slot winner (the replay-eligible delta)."""
    rng = np.random.default_rng(seed)
    return [np.asarray(X[i % len(X)], np.float32)
            + rng.normal(scale=scale, size=np.shape(X[0])).astype(np.float32)
            for i in range(n)]


# ------------------------------------------------ streamed == one-shot --
@pytest.mark.parametrize("replicas", (1, 3))
def test_standing_stream_matches_one_shot(replicas):
    """Register once, stream pushes/labels/retrains; every poll's
    cumulative selection equals a one-shot query at that moment, and the
    final emit equals a one-shot over the final pool on a FRESH server
    with every incremental engine disabled."""
    X, Y = image_pool(60, seed=11)
    srv = _mlp_server(replicas)
    keys = srv.push_data(list(X[:24]))
    srv.label(keys[:6], Y[:6])
    srv.train_and_eval()
    reg = srv.standing_register(budget=5, strategy="coreset", rng_seed=3)
    assert reg["keys"] == srv.query(budget=5, strategy="coreset",
                                    rng_seed=3)["keys"]
    seen = reg["seq"]
    cumulative = list(reg["keys"])
    for lo, hi in ((24, 36), (36, 48), (48, 60)):
        srv.push_data(list(X[lo:hi]), asynchronous=True).result()
        r = srv.standing_poll(reg["query_id"], since=seen)
        # the emit log replays to the cumulative selection via added/removed
        for e in r["emits"]:
            cumulative = [k for k in cumulative
                          if k not in set(e["removed"])] + list(e["added"])
            assert sorted(cumulative) == sorted(e["keys"])
        seen = r["seq"]
        assert r["keys"] == srv.query(budget=5, strategy="coreset",
                                      rng_seed=3)["keys"]
    # sync mutations emit lazily at the next poll
    srv.label(keys[6:12], Y[6:12])
    srv.train_and_eval()
    final = srv.standing_poll(reg["query_id"], since=seen)
    assert final["seq"] > seen
    # oracle: one-shot over the final pool, all incremental engines off
    ref = _mlp_server(replicas, artifact_cache=False,
                      strategy_state_cache=False, standing_replay=False)
    rkeys = ref.push_data(list(X))
    assert rkeys == srv.session()._keys
    ref.label(keys[:12], Y[:12])
    ref.train_and_eval()
    assert final["keys"] == ref.query(budget=5, strategy="coreset",
                                      rng_seed=3)["keys"]


@pytest.mark.parametrize("replicas", (1, 3))
def test_standing_replay_fires_and_matches_oracle(replicas):
    """Near-duplicate deltas take the O(delta) replay path (mode
    ``replay``, no full re-selection) and the emitted keys still match
    the ``standing_replay: false`` full-emit oracle bit for bit."""
    X, Y = image_pool(40, seed=12)
    dups = _near_dups(X[:8], 10, seed=12)
    on = _mlp_server(replicas)
    off = _mlp_server(replicas, standing_replay=False)
    regs = {}
    for srv in (on, off):
        keys = srv.push_data(list(X))
        srv.label(keys[:8], Y[:8])
        srv.train_and_eval()
        regs[srv] = srv.standing_register(budget=5, strategy="coreset")
    for srv in (on, off):
        srv.push_data(dups[:5], asynchronous=True).result()
        srv.push_data(dups[5:], asynchronous=True).result()
    a = on.standing_poll(regs[on]["query_id"])
    b = off.standing_poll(regs[off]["query_id"])
    assert a["keys"] == b["keys"]
    assert any(e["mode"] == "replay" for e in a["emits"])
    assert all(e["mode"] == "full" for e in b["emits"])
    sa, sb = (s.stats()["standing_queries"] for s in (on, off))
    assert sa["replay_emits"] >= 1
    assert sb["replay_emits"] == 0 and sb["full_emits"] == sb["emits"]


def test_standing_replay_diverges_to_full_emit():
    """A delta row that DOES displace a winner must force an honest full
    re-selection (replay detects the divergence and bows out)."""
    X, Y = image_pool(30, seed=13)
    srv = _mlp_server()
    keys = srv.push_data(list(X))
    srv.label(keys[:6], Y[:6])
    srv.train_and_eval()
    reg = srv.standing_register(budget=4, strategy="coreset")
    # far-out rows: guaranteed to beat every recorded winner score
    far = [np.full_like(np.asarray(X[0], np.float32), 40.0 + i)
           for i in range(3)]
    srv.push_data(far, asynchronous=True).result()
    r = srv.standing_poll(reg["query_id"], since=reg["seq"])
    assert [e["mode"] for e in r["emits"]] == ["full"]
    assert set(e for em in r["emits"] for e in em["added"]) & set(
        srv.session()._keys[-3:])          # the new rows actually won
    assert r["keys"] == srv.query(budget=4, strategy="coreset")["keys"]


def test_standing_register_validation():
    srv = _mlp_server()
    srv.push_data(list(image_pool(8, seed=1)[0]))
    with pytest.raises(ValueError, match="concrete strategy"):
        srv.standing_register(budget=2, strategy="auto")
    with pytest.raises(KeyError):
        srv.standing_register(budget=2, strategy="nope")
    with pytest.raises(ValueError, match="budget"):
        srv.standing_register(budget=0, strategy="lc")
    with pytest.raises(KeyError, match="unknown standing query"):
        srv.standing_poll("deadbeef")


# ------------------------------------- persisted k-center min-dist state --
@pytest.mark.parametrize("replicas", (1, 3))
@pytest.mark.parametrize("strategy", ("coreset", "weighted_kcenter"))
def test_persisted_state_bit_identical_to_cold(replicas, strategy):
    """Warm-started selections with the persisted min-dist state must be
    bit-identical to the ``strategy_state_cache: false`` from-scratch
    oracle across pushes, labels and retrains — and the cache must show
    O(delta) work (extends, not rebuilds) on the push-then-query step."""
    X, Y = image_pool(56, seed=14)
    warm = _mlp_server(replicas)
    cold = _mlp_server(replicas, strategy_state_cache=False)
    for srv in (warm, cold):
        keys = srv.push_data(list(X[:40]))
        srv.label(keys[:10], Y[:10])
        srv.train_and_eval()
    for seed in (0, 1):
        assert warm.query(budget=6, strategy=strategy,
                          rng_seed=seed)["keys"] == \
            cold.query(budget=6, strategy=strategy,
                       rng_seed=seed)["keys"]
    st = warm.stats()["strategy_state"]
    assert st["enabled"] and st["rebuilds"] >= 1 and st["hits"] >= 1
    for srv in (warm, cold):
        srv.push_data(list(X[40:]))
    assert warm.query(budget=6, strategy=strategy, rng_seed=2)["keys"] == \
        cold.query(budget=6, strategy=strategy, rng_seed=2)["keys"]
    st2 = warm.stats()["strategy_state"]
    assert st2["extends"] >= 1                    # delta rows appended...
    assert st2["rebuilds"] == st["rebuilds"]      # ...nothing re-folded
    assert st2["rows_extended"] >= 16
    for srv in (warm, cold):
        srv.label(keys[10:16], Y[10:16])
        srv.train_and_eval()
    assert warm.query(budget=6, strategy=strategy, rng_seed=3)["keys"] == \
        cold.query(budget=6, strategy=strategy, rng_seed=3)["keys"]


def test_state_invalidation_matrix():
    """The spec's matrix, counter by counter, at replicas=3:

    push    -> extends ONLY the touched shards' vectors (embeds only the
               delta rows);
    train   -> drops every shard's min-dist, re-embeds NOTHING;
    label   -> drops nothing — the new centers fold into the live vectors
               (center_extends), no rebuild, no invalidation."""
    X, Y = image_pool(48, seed=15)
    srv = _mlp_server(3)
    sess = srv.session()
    keys = srv.push_data(list(X[:36]))
    srv.label(keys[:8], Y[:8])
    srv.train_and_eval()
    srv.query(budget=4, strategy="coreset")          # state warm
    s0 = srv.stats()["strategy_state"]
    assert s0["rebuilds"] == 3                       # one cold fold per shard

    # -- push: O(delta) embeds, extends only the touched shards ----------
    e0 = srv.embed_rows
    new_keys = srv.push_data(list(X[36:]))
    assert srv.embed_rows - e0 == 12
    srv.query(budget=4, strategy="coreset")
    s1 = srv.stats()["strategy_state"]
    touched = {replica_of(k, 3) for k in new_keys}
    assert s1["rebuilds"] == s0["rebuilds"]          # no from-scratch folds
    assert s1["invalidations"] == s0["invalidations"]
    assert s1["extends"] - s0["extends"] == len(touched)
    assert s1["rows_extended"] - s0["rows_extended"] == 12

    # -- train: min-dist dropped everywhere, zero re-embeds --------------
    e1 = srv.embed_rows
    srv.train_and_eval()
    srv.query(budget=4, strategy="coreset")
    s2 = srv.stats()["strategy_state"]
    assert srv.embed_rows == e1                      # retrain embeds nothing
    assert s2["invalidations"] > s1["invalidations"]
    assert s2["rebuilds"] == s1["rebuilds"] + 3      # cold again, all shards

    # -- label: nothing dropped, new centers fold into live vectors ------
    srv.label(new_keys[:4], Y[36:40])
    srv.query(budget=4, strategy="coreset")
    s3 = srv.stats()["strategy_state"]
    assert srv.embed_rows == e1
    assert s3["invalidations"] == s2["invalidations"]
    assert s3["rebuilds"] == s2["rebuilds"]
    assert s3["center_extends"] - s2["center_extends"] == 3
    assert sess.artifact_builds == srv.stats()["artifacts"]["builds"]


def test_standing_emit_cost_is_o_delta():
    """Replay emits are op-accounted O(new rows): pool_rows touched by a
    near-duplicate delta emit must be a small multiple of the delta size,
    far below the full O(pool x budget) re-selection cost."""
    X, Y = image_pool(48, seed=16)
    srv = _mlp_server()
    keys = srv.push_data(list(X))
    srv.label(keys[:8], Y[:8])
    srv.train_and_eval()
    reg = srv.standing_register(budget=6, strategy="coreset")
    delta = _near_dups(X[:8], 4, seed=16)
    # SYNC push: no worker-thread emit (track_ops is process-global), the
    # next poll emits on THIS thread inside the tracked window
    srv.push_data(delta)
    with ops.track_ops() as stats:
        r = srv.standing_poll(reg["query_id"], since=reg["seq"])
    stats = dict(stats)          # track_ops yields the live global dict
    assert [e["mode"] for e in r["emits"]] == ["replay"]
    n_pool, n_delta, budget = 48 + 4, len(delta), 6
    # prepare() extends the cached vector over the delta rows, the replay
    # folds budget-1 centers over the delta rows — all O(delta)
    assert stats["pool_rows"] <= 3 * n_delta * (budget + 1)
    assert stats["pool_rows"] < n_pool * budget // 2
    # reference: the same emit with replay disabled is a full re-selection
    srv2 = _mlp_server(standing_replay=False)
    k2 = srv2.push_data(list(X))
    srv2.label(k2[:8], Y[:8])
    srv2.train_and_eval()
    reg2 = srv2.standing_register(budget=6, strategy="coreset")
    srv2.push_data(delta)
    with ops.track_ops() as full_stats:
        r2 = srv2.standing_poll(reg2["query_id"], since=reg2["seq"])
    assert r2["keys"] == r["keys"]
    assert full_stats["pool_rows"] >= (n_pool - 8) * (budget - 1)
    assert full_stats["pool_rows"] > 4 * stats["pool_rows"]


# ------------------------------------------------- batch-insensitivity --
@pytest.mark.parametrize("replicas", (1, 3))
def test_feature_path_batch_insensitive(replicas):
    """The same pool pushed in chunk sizes {1, 3, 17, all} — under a tiny
    EmbeddingCache that forces evicted-entry recomputes — must yield
    bitwise-identical feats columns and identical selections. This is the
    invariant that lets a streamed pool select exactly like a one-shot
    pool (rows never see their co-batch)."""
    X, Y = image_pool(34, seed=17)
    n = len(X)
    servers, snaps = [], []
    for chunk in (1, 3, 17, n):
        srv = _mlp_server(replicas, cache_bytes=1 << 10)
        for lo in range(0, n, chunk):
            srv.push_data(list(X[lo:lo + chunk]))
        keys = srv.session()._keys
        srv.label(keys[:7], Y[:7])
        srv.train_and_eval()
        servers.append(srv)
        feats_l, _, rows_l, _ = srv.session()._artifact_snapshot()
        snaps.append([np.asarray(f[:r]) for f, r in zip(feats_l, rows_l)])
    ref = snaps[0]
    for snap in snaps[1:]:
        for a, b in zip(ref, snap):
            np.testing.assert_array_equal(a, b)      # bitwise, per shard
    sels = [srv.query(budget=5, strategy="coreset", rng_seed=4)["keys"]
            for srv in servers]
    assert all(s == sels[0] for s in sels)
    sels_lc = [srv.query(budget=5, strategy="lc", rng_seed=4)["keys"]
               for srv in servers]
    assert all(s == sels_lc[0] for s in sels_lc)


# ------------------------------------------- cancellation / fault paths --
def test_close_session_cancels_standing_queries():
    """Closing a session cancels its standing queries first: the draining
    worker must not emit to a subscription whose owner is gone, and polls
    on a kept reference raise with the close reason."""
    X, Y = image_pool(24, seed=18)
    srv = _mlp_server()
    sid = srv.create_session()
    sess = srv.session(sid)
    keys = srv.push_data(list(X[:16]), session=sid)
    srv.label(keys[:4], Y[:4], session=sid)
    reg = srv.standing_register(budget=3, strategy="coreset", session=sid)
    emits_before = sess.standing_emits
    srv.close_session(sid)
    with pytest.raises(RuntimeError, match="session closed"):
        sess.standing_poll(reg["query_id"])
    with pytest.raises(KeyError):                    # session itself gone
        srv.standing_poll(reg["query_id"], session=sid)
    assert sess.standing_emits == emits_before       # no orphaned emits
    assert sess._standing[reg["query_id"]].cancelled == "session closed"


def test_dead_ingest_worker_fails_polls_ticket_style():
    """A dead worker with pushes pending must surface at the next poll
    exactly like ``flush()`` (fail fast, no stale selection served)."""
    X, Y = image_pool(20, seed=19)
    srv = _mlp_server()
    sess = srv.session()
    keys = srv.push_data(list(X[:16]))
    srv.label(keys[:4], Y[:4])
    reg = srv.standing_register(budget=3, strategy="coreset")
    sess._ingest_loop = lambda: None       # worker thread exits immediately
    sess.push_data(list(X[16:]), asynchronous=True)
    deadline = time.time() + 10
    while sess._ingest_thread.is_alive() and time.time() < deadline:
        time.sleep(0.01)
    t0 = time.perf_counter()
    with pytest.raises(RuntimeError, match="worker died"):
        srv.standing_poll(reg["query_id"])
    assert time.perf_counter() - t0 < 5.0


def test_failed_emit_parks_on_query_not_worker(monkeypatch):
    """An emit that raises must not kill the ingest worker: the error
    parks on the standing query and the NEXT poll raises it, while other
    session ops keep working."""
    X, Y = image_pool(24, seed=20)
    srv = _mlp_server()
    sess = srv.session()
    keys = srv.push_data(list(X[:16]))
    srv.label(keys[:4], Y[:4])
    reg = srv.standing_register(budget=3, strategy="coreset")
    boom = RuntimeError("emit exploded")
    monkeypatch.setattr(sess, "_standing_emit_locked",
                        lambda sq: (_ for _ in ()).throw(boom))
    sess.push_data(list(X[16:]), asynchronous=True).result()
    srv.flush()                                      # worker survived
    with pytest.raises(RuntimeError, match="emit failed"):
        srv.standing_poll(reg["query_id"])
    monkeypatch.undo()
    r = srv.standing_poll(reg["query_id"])           # error cleared on success
    assert r["keys"] == srv.query(budget=3, strategy="coreset")["keys"]
    assert srv.stats()["pool"] == 24                 # no rows lost


# ------------------------------------------- random interleavings (slow) --
@pytest.mark.slow
def test_random_streams_standing_equals_one_shot():
    """Hypothesis: under ANY interleaving of push (sync and async), label,
    train and poll, at replicas in {1, 3}, every standing-query emit
    equals the one-shot selection at that moment, and the final cumulative
    selection is bit-identical to a one-shot coreset query over the final
    pool on a fresh all-oracles-off server."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    X, Y = image_pool(66, seed=21)
    chunks = [list(X[i * 6:(i + 1) * 6]) for i in range(11)]
    ops_st = st.lists(
        st.one_of(
            st.tuples(st.just("push"), st.integers(0, 10)),
            st.tuples(st.just("push_async"), st.integers(0, 10)),
            st.tuples(st.just("label"), st.integers(1, 5)),
            st.tuples(st.just("train"), st.just(0)),
            st.tuples(st.just("poll"), st.just(0)),
        ), min_size=4, max_size=12)

    @settings(max_examples=10, deadline=None)
    @given(ops=ops_st, replicas=st.sampled_from([1, 3]),
           seed=st.integers(0, 99))
    def run(ops, replicas, seed):
        srv = _mlp_server(replicas)
        # mirror server: every cache under test off — each poll's
        # selection is checked against it, so the persisted min-dist
        # state is oracle-tested under the same interleaving
        cold = _mlp_server(replicas, strategy_state_cache=False,
                           standing_replay=False)
        sess = srv.session()
        keys0 = srv.push_data(chunks[0])
        cold.push_data(chunks[0])
        for s in (srv, cold):
            s.label(keys0[:3], [hash(k) % 10 for k in keys0[:3]])
            s.train_and_eval()
        reg = srv.standing_register(budget=4, strategy="coreset",
                                    rng_seed=seed)
        labeled_log = [(k, hash(k) % 10) for k in keys0[:3]]
        for op, arg in ops:
            if op == "push":
                srv.push_data(chunks[arg])
                cold.push_data(chunks[arg])
            elif op == "push_async":
                srv.push_data(chunks[arg], asynchronous=True)
                cold.push_data(chunks[arg], asynchronous=True)
            elif op == "label":
                srv.flush()
                todo = [k for k in sess._keys
                        if k not in sess._labels][:arg]
                ys = [hash(k) % 10 for k in todo]
                srv.label(todo, ys)
                cold.label(todo, ys)
                labeled_log += list(zip(todo, ys))
            elif op == "train":
                srv.train_and_eval()
                cold.train_and_eval()
            else:
                r = srv.standing_poll(reg["query_id"])
                assert r["keys"] == srv.query(
                    budget=4, strategy="coreset",
                    rng_seed=seed)["keys"]
                assert r["keys"] == cold.query(
                    budget=4, strategy="coreset",
                    rng_seed=seed)["keys"]
        final = srv.standing_poll(reg["query_id"])
        cold.flush()
        assert cold.session()._keys == sess._keys
        assert final["keys"] == cold.query(
            budget=4, strategy="coreset", rng_seed=seed)["keys"]
        # fresh oracle server: one-shot over the final pool, caches off
        ref = _mlp_server(replicas, artifact_cache=False,
                          strategy_state_cache=False, standing_replay=False)
        for lo in range(0, len(sess._keys), 16):
            ref.push_data([sess._raw[k]
                           for k in sess._keys[lo:lo + 16]])
        assert ref.session()._keys == sess._keys
        ref.label(*zip(*labeled_log))
        ref.train_and_eval()
        assert final["keys"] == ref.query(
            budget=4, strategy="coreset", rng_seed=seed)["keys"]

    run()
