"""Hypothesis property tests on system invariants (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# CI installs hypothesis; skip the module cleanly where it is absent
# instead of failing the whole tier-1 collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim.compression import topk_sparsify
from repro.service.batcher import bucket_size
from repro.service.cache import EmbeddingCache
from repro.service.config import parse_yaml

SET = settings(max_examples=25, deadline=None)


# ---------------------------------------------------- online softmax inv ----
@SET
@given(
    sq=st.integers(1, 40), skv=st.integers(1, 60),
    h=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2]),
    qc=st.integers(1, 16), kc=st.integers(1, 16),
    causal=st.booleans(), seed=st.integers(0, 100),
)
def test_chunked_attention_equals_naive(sq, skv, h, g, qc, kc, causal, seed):
    from repro.models.layers.attention import (chunked_attention,
                                               naive_attention)
    rng = np.random.default_rng(seed)
    D = 8
    q = jnp.asarray(rng.normal(size=(1, sq, h * g, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, skv, h, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, skv, h, D)), jnp.float32)
    a = chunked_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    b = naive_attention(q, k, v, causal=causal)
    # fully-masked causal rows (none exist here since Skv>=1 and q_pos>=0)
    np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)


# ------------------------------------------------------ uncertainty inv ----
@SET
@given(n=st.integers(1, 32), v=st.integers(2, 300), seed=st.integers(0, 50),
       scale=st.floats(0.1, 30.0))
def test_uncertainty_kernel_matches_ref(n, v, seed, scale):
    from repro.kernels.uncertainty import ref
    from repro.kernels.uncertainty.kernel import uncertainty_stats_pallas
    rng = np.random.default_rng(seed)
    lg = jnp.asarray(rng.normal(size=(n, v)) * scale, jnp.float32)
    out = uncertainty_stats_pallas(lg, row_block=8, v_block=64,
                                   interpret=True)
    rr = ref.uncertainty_stats_ref(lg)
    for i, k in enumerate(("lc", "mc", "rc", "es")):
        np.testing.assert_allclose(out[i], rr[k], rtol=2e-4, atol=2e-4)


@SET
@given(n=st.integers(1, 64), v=st.integers(2, 64), seed=st.integers(0, 50))
def test_uncertainty_score_ranges(n, v, seed):
    from repro.kernels.uncertainty import ref
    rng = np.random.default_rng(seed)
    lg = jnp.asarray(rng.normal(size=(n, v)) * 5, jnp.float32)
    s = ref.uncertainty_stats_ref(lg)
    assert np.all((np.asarray(s["lc"]) >= -1e-6)
                  & (np.asarray(s["lc"]) <= 1 - 1 / v + 1e-5))
    assert np.all((np.asarray(s["rc"]) >= -1e-6)
                  & (np.asarray(s["rc"]) <= 1 + 1e-5))
    assert np.all((np.asarray(s["es"]) >= -1e-5)
                  & (np.asarray(s["es"]) <= np.log(v) + 1e-4))
    assert np.all(np.asarray(s["mc"]) <= 1e-6)


# ----------------------------------------------------------- selection ----
@SET
@given(n=st.integers(10, 200), b=st.integers(1, 10), seed=st.integers(0, 20),
       name=st.sampled_from(["lc", "mc", "es", "rc", "random", "kcg",
                             "coreset", "badge"]))
def test_selection_budget_unique_inrange(n, b, seed, name):
    from repro.core.strategies.zoo import get_strategy
    rng = np.random.default_rng(seed)
    b = min(b, n)
    logits = rng.normal(size=(n, 8)) * 2
    probs = jnp.asarray(np.exp(logits) / np.exp(logits).sum(-1, keepdims=True))
    emb = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)
    idx = np.asarray(get_strategy(name).select(
        jax.random.PRNGKey(seed), b, probs=probs, embeddings=emb,
        labeled_embeddings=None))
    assert idx.shape == (b,)
    assert len(set(idx.tolist())) == b
    assert idx.min() >= 0 and idx.max() < n


# ------------------------------------------------- weighted fused round ----
@SET
@given(n=st.integers(4, 120), d=st.integers(2, 48), r=st.integers(1, 6),
       seed=st.integers(0, 50), zero_frac=st.floats(0.0, 0.5))
def test_weighted_round_ref_invariants(n, d, r, seed, zero_frac):
    """For ANY weights (including zeros): the argmax never lands on a
    selected row, the returned min-dist ignores weights entirely, and
    weights=None equals all-ones weights (the PR-1 regression anchor)."""
    from repro.kernels.pairwise import ref
    rng = np.random.default_rng(seed)
    r = min(r, n - 1)                      # keep at least one live row
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(r, d)), jnp.float32)
    mind = jnp.asarray(np.abs(rng.normal(size=(n,))) * 5, jnp.float32)
    sel = jnp.asarray(rng.choice(n, r, replace=False), jnp.int32)
    w = rng.uniform(0.0, 2.0, size=(n,))
    w[rng.uniform(size=n) < zero_frac] = 0.0
    w = jnp.asarray(w, jnp.float32)

    nm_w, ni_w, _ = ref.greedy_round_ref(x, mind, c, sel, w)
    nm_u, ni_u, _ = ref.greedy_round_ref(x, mind, c, sel, None)
    nm_1, ni_1, _ = ref.greedy_round_ref(x, mind, c, sel,
                                         jnp.ones((n,), jnp.float32))
    sel_set = set(np.asarray(sel).tolist())
    assert int(ni_w) not in sel_set
    assert int(ni_u) not in sel_set
    # min-dist is weight-independent; ones-weights reproduce unweighted
    np.testing.assert_array_equal(np.asarray(nm_w), np.asarray(nm_u))
    assert int(ni_1) == int(ni_u)
    # numpy oracle for the weighted argmax
    nm = np.asarray(nm_w)
    score = np.where(nm < 0.0, -np.inf, nm * np.asarray(w))
    assert int(ni_w) == int(np.argmax(score))


@SET
@given(n=st.integers(8, 80), b=st.integers(2, 8), seed=st.integers(0, 30))
def test_weighted_kcg_selection_invariants(n, b, seed):
    """Weighted fused k-center: budget unique in-range indices for random
    weights, bit-identical between the ref dispatch and the oracle loop."""
    from repro.core.strategies.diversity import k_center_greedy
    rng = np.random.default_rng(seed)
    b = min(b, n)
    emb = jnp.asarray(rng.normal(size=(n, 6)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.01, 1.0, size=(n,)), jnp.float32)
    idx = np.asarray(k_center_greedy(jax.random.PRNGKey(seed), b, emb,
                                     weights=w, impl="ref"))
    assert idx.shape == (b,)
    assert len(set(idx.tolist())) == b
    assert idx.min() >= 0 and idx.max() < n


# -------------------------------------------------------------- cache ------
@SET
@given(ops=st.lists(st.tuples(st.integers(0, 30), st.integers(1, 64)),
                    min_size=1, max_size=60),
       max_items=st.integers(1, 12))
def test_cache_never_exceeds_budget_and_serves_hits(ops, max_items):
    item_bytes = 32 * 4
    c = EmbeddingCache(max_bytes=max_items * item_bytes)
    live = {}
    for key_i, val in ops:
        k = f"k{key_i}"
        v = np.full(32, val, np.float32)
        c.put(k, v)
        live[k] = v
        assert c.stats()["bytes"] <= max_items * item_bytes
        got = c.get(k)                     # just-put must be present
        np.testing.assert_array_equal(got, v)
    for k, v in live.items():              # any hit must be correct
        got = c.get(k)
        if got is not None:
            np.testing.assert_array_equal(got, v)


# ------------------------------------------------------------ compression --
@SET
@given(n=st.integers(8, 512), frac=st.floats(0.01, 1.0),
       seed=st.integers(0, 50))
def test_topk_identity_and_sparsity(n, frac, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    sparse, err = topk_sparsify(g, frac)
    np.testing.assert_allclose(np.asarray(sparse + err), np.asarray(g),
                               rtol=1e-6, atol=1e-6)
    k = max(int(n * frac), 1)
    nz = np.count_nonzero(np.asarray(sparse))
    assert nz >= min(k, n) * 0.5           # ties may add a few
    # kept entries dominate dropped ones
    s = np.asarray(sparse)
    e = np.asarray(err)
    if nz < n:
        assert np.abs(s[s != 0]).min() >= np.abs(e[e != 0]).max() - 1e-6


# ------------------------------------------------------------- batcher -----
@SET
@given(n=st.integers(1, 300), mx=st.sampled_from([1, 2, 8, 64, 128]))
def test_bucket_size_props(n, mx):
    b = bucket_size(n, mx)
    assert b <= mx
    assert b & (b - 1) == 0 or b == mx     # pow2 or capped
    assert b >= min(n, mx) or b == mx


# ---------------------------------------------------------------- yaml -----
@SET
@given(d=st.dictionaries(
    st.text(alphabet="abcdefgh", min_size=1, max_size=6),
    st.one_of(st.integers(-100, 100), st.booleans(),
              st.text(alphabet="xyz", min_size=1, max_size=5),
              st.dictionaries(st.text(alphabet="mnop", min_size=1,
                                      max_size=4),
                              st.integers(0, 9), max_size=3)),
    min_size=1, max_size=6))
def test_yaml_parser_roundtrip(d):
    def emit(obj, indent=0):
        lines = []
        for k, v in obj.items():
            if isinstance(v, dict):
                lines.append("  " * indent + f"{k}:")
                if v:
                    lines.extend(emit(v, indent + 1))
                else:
                    lines[-1] = "  " * indent + f"{k}: {{}}"
            elif isinstance(v, bool):
                lines.append("  " * indent + f"{k}: {'true' if v else 'false'}")
            elif isinstance(v, str):
                lines.append("  " * indent + f'{k}: "{v}"')
            else:
                lines.append("  " * indent + f"{k}: {v}")
        return lines

    d = {k: v for k, v in d.items() if not (isinstance(v, dict) and not v)}
    if not d:
        return
    text = "\n".join(emit(d))
    assert parse_yaml(text) == d


# ---------------------------------------------------------- neg-exp fit ----
@SET
@given(a=st.floats(0.5, 1.0), b=st.floats(0.1, 0.8), c=st.floats(0.1, 2.0),
       n=st.integers(3, 10))
def test_negexp_fit_recovers_clean_curves(a, b, c, n):
    from repro.core.agent.predictor import fit_neg_exp
    r = np.arange(n, dtype=np.float64)
    y = a - b * np.exp(-c * r)
    fit = fit_neg_exp(r, y)
    pred = fit.predict(np.array([n, n + 1]))
    truth = a - b * np.exp(-c * np.array([n, n + 1]))
    np.testing.assert_allclose(pred, truth, atol=0.03)
