"""AL strategy zoo behaviour (paper Fig. 4 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategies.zoo import ZOO, get_strategy

rng = np.random.default_rng(5)
KEY = jax.random.PRNGKey(0)


def _artifacts(n=200, c=10, d=16):
    logits = rng.normal(size=(n, c)) * 2
    probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    emb = rng.normal(size=(n, d)).astype(np.float32)
    return jnp.asarray(probs), jnp.asarray(emb)


@pytest.mark.parametrize("name", sorted(ZOO))
def test_budget_and_uniqueness(name):
    probs, emb = _artifacts()
    strat = get_strategy(name)
    idx = np.asarray(strat.select(KEY, 32, probs=probs, embeddings=emb,
                                  labeled_embeddings=emb[:5]))
    assert idx.shape == (32,)
    assert len(set(idx.tolist())) == 32, f"{name} returned duplicates"
    assert idx.min() >= 0 and idx.max() < probs.shape[0]


def test_lc_picks_most_uncertain():
    n, c = 100, 10
    probs = np.full((n, c), 1.0 / c)
    confident = rng.choice(n, 50, replace=False)
    for i in confident:
        probs[i] = 0.001
        probs[i, 0] = 1 - 0.001 * (c - 1)
    idx = np.asarray(get_strategy("lc").select(KEY, 40,
                                               probs=jnp.asarray(probs)))
    assert len(set(idx) & set(confident.tolist())) == 0


def test_margin_vs_entropy_differ():
    probs, emb = _artifacts(500)
    a = set(np.asarray(get_strategy("mc").select(KEY, 50, probs=probs)).tolist())
    b = set(np.asarray(get_strategy("es").select(KEY, 50, probs=probs)).tolist())
    assert a != b


def test_kcenter_covers_clusters():
    """k-center greedy must hit every well-separated cluster."""
    from repro.core.strategies.diversity import k_center_greedy
    centers = rng.normal(size=(8, 16)) * 20
    pts = np.concatenate([centers[i] + rng.normal(size=(30, 16)) * 0.1
                          for i in range(8)])
    lab = np.repeat(np.arange(8), 30)
    idx = np.asarray(k_center_greedy(KEY, 8, jnp.asarray(pts, jnp.float32)))
    assert len(set(lab[idx].tolist())) == 8


def test_coreset_avoids_labeled_regions():
    from repro.core.strategies.diversity import k_center_greedy
    a = rng.normal(size=(50, 8)) + 10      # region A (labeled)
    b = rng.normal(size=(50, 8)) - 10      # region B (unexplored)
    pool = jnp.asarray(np.concatenate([a, b]), jnp.float32)
    idx = np.asarray(k_center_greedy(KEY, 5, pool,
                                     init_centers=jnp.asarray(a[:20],
                                                              jnp.float32)))
    assert np.mean(idx >= 50) >= 0.8       # mostly from region B


def test_dbal_diversity():
    """DBAL selections must span clusters even when uncertainty is uniform."""
    from repro.core.strategies.zoo import get_strategy
    centers = rng.normal(size=(4, 16)) * 15
    pts = np.concatenate([centers[i] + rng.normal(size=(50, 16)) * 0.2
                          for i in range(4)]).astype(np.float32)
    lab = np.repeat(np.arange(4), 50)
    perm = rng.permutation(200)        # pools are not cluster-ordered
    pts, lab = pts[perm], lab[perm]
    probs = jnp.asarray(np.full((200, 10), 0.1))
    idx = np.asarray(get_strategy("dbal").select(
        KEY, 4, probs=probs, embeddings=jnp.asarray(pts)))
    assert len(set(lab[idx].tolist())) >= 3


def test_random_is_seeded():
    probs, _ = _artifacts()
    s = get_strategy("random")
    a = np.asarray(s.select(jax.random.PRNGKey(1), 20, probs=probs))
    b = np.asarray(s.select(jax.random.PRNGKey(1), 20, probs=probs))
    c = np.asarray(s.select(jax.random.PRNGKey(2), 20, probs=probs))
    assert np.array_equal(a, b) and not np.array_equal(a, c)


def test_scores_from_logits_matches_probs_path():
    from repro.core.strategies.uncertainty import (SCORE_FNS,
                                                   scores_from_logits)
    logits = jnp.asarray(rng.normal(size=(64, 50)) * 3, jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    for kind in ("lc", "mc", "rc", "es"):
        a = scores_from_logits(logits, kind, impl="ref")
        b = SCORE_FNS[kind](probs)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
