"""AL strategy zoo behaviour (paper Fig. 4 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategies.zoo import ZOO, get_strategy

rng = np.random.default_rng(5)
KEY = jax.random.PRNGKey(0)


def _artifacts(n=200, c=10, d=16):
    logits = rng.normal(size=(n, c)) * 2
    probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    emb = rng.normal(size=(n, d)).astype(np.float32)
    return jnp.asarray(probs), jnp.asarray(emb)


@pytest.mark.parametrize("name", sorted(ZOO))
def test_budget_and_uniqueness(name):
    probs, emb = _artifacts()
    strat = get_strategy(name)
    idx = np.asarray(strat.select(KEY, 32, probs=probs, embeddings=emb,
                                  labeled_embeddings=emb[:5]))
    assert idx.shape == (32,)
    assert len(set(idx.tolist())) == 32, f"{name} returned duplicates"
    assert idx.min() >= 0 and idx.max() < probs.shape[0]


def test_lc_picks_most_uncertain():
    n, c = 100, 10
    probs = np.full((n, c), 1.0 / c)
    confident = rng.choice(n, 50, replace=False)
    for i in confident:
        probs[i] = 0.001
        probs[i, 0] = 1 - 0.001 * (c - 1)
    idx = np.asarray(get_strategy("lc").select(KEY, 40,
                                               probs=jnp.asarray(probs)))
    assert len(set(idx) & set(confident.tolist())) == 0


def test_margin_vs_entropy_differ():
    probs, emb = _artifacts(500)
    a = set(np.asarray(get_strategy("mc").select(KEY, 50, probs=probs)).tolist())
    b = set(np.asarray(get_strategy("es").select(KEY, 50, probs=probs)).tolist())
    assert a != b


def test_kcenter_covers_clusters():
    """k-center greedy must hit every well-separated cluster."""
    from repro.core.strategies.diversity import k_center_greedy
    centers = rng.normal(size=(8, 16)) * 20
    pts = np.concatenate([centers[i] + rng.normal(size=(30, 16)) * 0.1
                          for i in range(8)])
    lab = np.repeat(np.arange(8), 30)
    idx = np.asarray(k_center_greedy(KEY, 8, jnp.asarray(pts, jnp.float32)))
    assert len(set(lab[idx].tolist())) == 8


def test_coreset_avoids_labeled_regions():
    from repro.core.strategies.diversity import k_center_greedy
    a = rng.normal(size=(50, 8)) + 10      # region A (labeled)
    b = rng.normal(size=(50, 8)) - 10      # region B (unexplored)
    pool = jnp.asarray(np.concatenate([a, b]), jnp.float32)
    idx = np.asarray(k_center_greedy(KEY, 5, pool,
                                     init_centers=jnp.asarray(a[:20],
                                                              jnp.float32)))
    assert np.mean(idx >= 50) >= 0.8       # mostly from region B


def _prefusion_k_center_greedy(key, budget, embeddings, init_centers=None):
    """The pre-fusion reference loop (argmax pass + distance pass + minimum
    pass + scatter per round), kept verbatim as the parity oracle."""
    from repro.kernels.pairwise import ref
    N, _ = embeddings.shape
    emb = embeddings.astype(jnp.float32)
    selected = jnp.zeros((budget,), jnp.int32)
    start = 0
    if init_centers is not None and init_centers.shape[0] > 0:
        mindist = ref.pairwise_min_dist_ref(emb,
                                            init_centers.astype(jnp.float32))
    else:
        first = jax.random.randint(key, (), 0, N).astype(jnp.int32)
        selected = selected.at[0].set(first)
        mindist = jnp.sum((emb - emb[first]) ** 2, axis=-1).at[first].set(-1.0)
        start = 1

    def body(i, carry):
        mindist, selected = carry
        idx = jnp.argmax(mindist).astype(jnp.int32)
        selected = selected.at[i].set(idx)
        d = jnp.sum((emb - emb[idx][None, :]) ** 2, axis=-1)
        mindist = jnp.minimum(mindist, d).at[idx].set(-1.0)
        return mindist, selected

    _, selected = jax.lax.fori_loop(start, budget, body, (mindist, selected))
    return selected


@pytest.mark.parametrize("warm", [False, True])
def test_kcg_matches_prefusion_reference(warm):
    """Fused k-center greedy must pick the exact same centers as the
    pre-fusion loop on identical seeds (cold and Core-Set warm start)."""
    from repro.core.strategies.diversity import k_center_greedy
    _, emb = _artifacts(300, d=24)
    init = emb[:13] if warm else None
    got = np.asarray(k_center_greedy(KEY, 48, emb, init_centers=init))
    want = np.asarray(_prefusion_k_center_greedy(KEY, 48, emb,
                                                 init_centers=init))
    np.testing.assert_array_equal(got, want)


@pytest.mark.interpret
@pytest.mark.parametrize("warm", [False, True])
def test_kcg_interpret_no_duplicates(warm):
    """Fused Pallas round (interpret mode): budget unique in-range indices,
    for both the cold-start and warm-start (init_centers) paths."""
    from repro.core.strategies.diversity import k_center_greedy
    _, emb = _artifacts(120, d=20)
    init = emb[:9] if warm else None
    idx = np.asarray(k_center_greedy(KEY, 32, emb, init_centers=init,
                                     impl="interpret"))
    assert idx.shape == (32,)
    assert len(set(idx.tolist())) == 32
    assert idx.min() >= 0 and idx.max() < 120
    ref_idx = np.asarray(k_center_greedy(KEY, 32, emb, init_centers=init,
                                         impl="ref"))
    np.testing.assert_array_equal(idx, ref_idx)


def _ref_weighted_kcg(key, budget, embeddings, w):
    """Pure-oracle weighted loop (ref.greedy_round_ref per round) — the
    parity target for the fused weighted path."""
    from repro.kernels.pairwise import ref
    N, _ = embeddings.shape
    emb = embeddings.astype(jnp.float32)
    first = jax.random.randint(key, (), 0, N).astype(jnp.int32)
    mind = jnp.sum((emb - emb[first]) ** 2, axis=-1).at[first].set(-1.0)
    score = jnp.where(mind < 0.0, -ref.BIG, mind * w)
    nxt = jnp.argmax(score).astype(jnp.int32)
    sel = [int(first)]
    for _ in range(budget - 1):
        sel.append(int(nxt))
        mind, nxt, _ = ref.greedy_round_ref(emb, mind, emb[nxt][None, :],
                                            nxt[None], w)
    return np.asarray(sel, np.int32)


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_weighted_kcg_matches_ref_loop(seed):
    """Weighted fused selection must be bit-identical to the pure-oracle
    weighted loop on the CPU ref path."""
    from repro.core.strategies.diversity import k_center_greedy
    r = np.random.default_rng(seed)
    emb = jnp.asarray(r.normal(size=(180, 20)), jnp.float32)
    w = jnp.asarray(r.uniform(0.01, 1.0, size=(180,)), jnp.float32)
    key = jax.random.PRNGKey(seed)
    got = np.asarray(k_center_greedy(key, 24, emb, weights=w, impl="ref"))
    want = _ref_weighted_kcg(key, 24, emb, w)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("warm", [False, True])
def test_kcg_weights_none_is_unweighted_anchor(warm):
    """weights=None must reproduce the PR-1 unweighted selections exactly,
    and all-ones weights must not change them either (the weighted score
    path degenerates to the unweighted one)."""
    from repro.core.strategies.diversity import k_center_greedy
    _, emb = _artifacts(250, d=24)
    init = emb[:11] if warm else None
    base = np.asarray(k_center_greedy(KEY, 40, emb, init_centers=init))
    anchor = np.asarray(_prefusion_k_center_greedy(KEY, 40, emb,
                                                   init_centers=init))
    np.testing.assert_array_equal(base, anchor)
    ones = np.asarray(k_center_greedy(KEY, 40, emb, init_centers=init,
                                      weights=jnp.ones((250,), jnp.float32)))
    np.testing.assert_array_equal(base, ones)


def test_weighted_kcenter_prefers_uncertain_regions():
    """weighted_kcenter must spend most of its budget where uncertainty is
    high while plain k-center splits evenly between the two blobs."""
    r = np.random.default_rng(9)
    a = r.normal(size=(60, 12)) + 8.0       # confident region
    b = r.normal(size=(60, 12)) - 8.0       # uncertain region
    emb = jnp.asarray(np.concatenate([a, b]), jnp.float32)
    probs = np.zeros((120, 10))
    probs[:60, 0] = 0.99; probs[:60, 1:] = 0.01 / 9     # confident
    probs[60:] = 0.1                                    # maximally uncertain
    idx = np.asarray(get_strategy("weighted_kcenter").select(
        KEY, 10, probs=jnp.asarray(probs), embeddings=emb))
    assert np.mean(idx >= 60) >= 0.7, idx


def test_margin_density_budget_and_diversity():
    """margin_density rides the weighted fused round: unique indices and
    no top-k clumping (selections must span more than one tight cluster)."""
    r = np.random.default_rng(4)
    centers = r.normal(size=(6, 16)) * 15
    pts = np.concatenate([c + r.normal(size=(40, 16)) * 0.2
                          for c in centers]).astype(np.float32)
    lab = np.repeat(np.arange(6), 40)
    probs, _ = _artifacts(240)
    idx = np.asarray(get_strategy("margin_density").select(
        KEY, 12, probs=probs, embeddings=jnp.asarray(pts)))
    assert len(set(idx.tolist())) == 12
    assert len(set(lab[idx].tolist())) >= 4      # spans clusters


def test_density_scores_permutation_invariant_in_expectation():
    """The density reference subset is rng-drawn, not embeddings[:256], so
    E[density] must not depend on pool order: averaging over seeds, the
    per-row density of a permuted pool matches the permuted density."""
    from repro.core.strategies.hybrid import density_scores
    r = np.random.default_rng(2)
    emb = jnp.asarray(r.normal(size=(300, 12)), jnp.float32)
    perm = r.permutation(300)
    emb_p = emb[perm]
    n_seeds = 30
    d0 = np.zeros(300)
    d1 = np.zeros(300)
    for s in range(n_seeds):
        d0 += np.asarray(density_scores(jax.random.PRNGKey(s), emb,
                                        n_ref=64))
        d1 += np.asarray(density_scores(jax.random.PRNGKey(1000 + s), emb_p,
                                        n_ref=64))
    d0, d1 = d0 / n_seeds, d1 / n_seeds
    # compare the SAME rows: permute the unpermuted estimate
    corr = np.corrcoef(d0[perm], d1)[0, 1]
    assert corr > 0.95, corr
    np.testing.assert_allclose(d0[perm], d1, atol=0.12)


def test_badge_kmeanspp_is_d2_sampling():
    """Gumbel-max fused sampling must behave like D^2 sampling: an isolated
    far point must be picked as the second center almost always."""
    from repro.core.strategies.hybrid import kmeans_pp_sample
    r = np.random.default_rng(6)
    x = np.asarray(r.normal(size=(100, 8)), np.float32) * 0.01
    x[77] += 100.0                          # lone far outlier
    x = jnp.asarray(x)
    hits = sum(
        77 in np.asarray(kmeans_pp_sample(jax.random.PRNGKey(s), x, 2))
        for s in range(30))
    assert hits >= 28, hits


def test_kmeans_seeding_ignores_unfilled_centroids():
    """Zero-initialized centroid rows must NOT act as phantom centers at
    the origin: a cluster sitting near the origin would otherwise never be
    picked by farthest-point seeding."""
    from repro.core.strategies.diversity import _kmeans
    r = np.random.default_rng(3)
    far = r.normal(size=(40, 8)) * 0.5 + 10.0     # cluster far from origin
    near = r.normal(size=(40, 8)) * 0.02 + 0.05   # cluster AT the origin
    x = jnp.asarray(np.concatenate([far, near]), jnp.float32)
    # huge weight pins the first (random) seed inside the far cluster
    w = jnp.ones((80,), jnp.float32).at[0].set(1e6)
    cents = np.asarray(_kmeans(jax.random.PRNGKey(0), x, 2, iters=0,
                               weights=w))
    d_near = np.linalg.norm(cents - np.full(8, 0.05), axis=1).min()
    assert d_near < 1.0, f"seeding never reached the near-origin cluster: " \
                         f"{d_near}"


def test_dbal_diversity():
    """DBAL selections must span clusters even when uncertainty is uniform."""
    from repro.core.strategies.zoo import get_strategy
    centers = rng.normal(size=(4, 16)) * 15
    pts = np.concatenate([centers[i] + rng.normal(size=(50, 16)) * 0.2
                          for i in range(4)]).astype(np.float32)
    lab = np.repeat(np.arange(4), 50)
    perm = rng.permutation(200)        # pools are not cluster-ordered
    pts, lab = pts[perm], lab[perm]
    probs = jnp.asarray(np.full((200, 10), 0.1))
    idx = np.asarray(get_strategy("dbal").select(
        KEY, 4, probs=probs, embeddings=jnp.asarray(pts)))
    assert len(set(lab[idx].tolist())) >= 3


def test_random_is_seeded():
    probs, _ = _artifacts()
    s = get_strategy("random")
    a = np.asarray(s.select(jax.random.PRNGKey(1), 20, probs=probs))
    b = np.asarray(s.select(jax.random.PRNGKey(1), 20, probs=probs))
    c = np.asarray(s.select(jax.random.PRNGKey(2), 20, probs=probs))
    assert np.array_equal(a, b) and not np.array_equal(a, c)


def test_scores_from_logits_matches_probs_path():
    from repro.core.strategies.uncertainty import (SCORE_FNS,
                                                   scores_from_logits)
    logits = jnp.asarray(rng.normal(size=(64, 50)) * 3, jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    for kind in ("lc", "mc", "rc", "es"):
        a = scores_from_logits(logits, kind, impl="ref")
        b = SCORE_FNS[kind](probs)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
