"""Service layer: pipeline overlap, cache, batcher, config, server, TCP."""
import threading
import time

import numpy as np
import pytest

from repro.data.synthetic import image_pool
from repro.service.batcher import DynamicBatcher, bucket_size
from repro.service.cache import EmbeddingCache, content_key
from repro.service.client import ALClient, serve_tcp
from repro.service.config import ALServiceConfig, parse_yaml
from repro.service.pipeline import Stage, StagePipeline
from repro.service.server import ALServer


# --------------------------------------------------------------- pipeline --
def test_pipeline_overlap_beats_serial():
    """3 stages x 10 items x 10ms: serial ~300ms, pipelined ~>=120ms."""
    def mk():
        return [Stage(n, lambda x, n=n: (time.sleep(0.01), x)[1])
                for n in ("a", "b", "c")]

    items = list(range(10))
    p1 = StagePipeline(mk())
    t0 = time.perf_counter()
    out1 = p1.run_serial(items)
    t_serial = time.perf_counter() - t0
    p2 = StagePipeline(mk())
    t0 = time.perf_counter()
    out2 = p2.run(items)
    t_pipe = time.perf_counter() - t0
    assert out1 == items and out2 == items
    assert t_pipe < t_serial * 0.75, (t_pipe, t_serial)


def test_pipeline_preserves_order_and_stats():
    sq = Stage("sq", lambda x: x * x)
    p = StagePipeline([sq])
    assert p.run(list(range(20))) == [x * x for x in range(20)]
    assert p.stats()[0]["items"] == 20


def test_pipeline_propagates_errors():
    def boom(x):
        raise ValueError("boom")
    p = StagePipeline([Stage("b", boom)])
    with pytest.raises(ValueError):
        p.run([1])


def test_pipeline_midstage_error_no_deadlock():
    """A mid-stage exception with bounded queues and many queued items:
    upstream stages must be torn down (not left blocked on a full queue)
    and run() must raise the original error instead of deadlocking."""
    def mid(x):
        if x == 10:
            raise ValueError("boom@10")
        return x

    stages = [Stage("a", lambda x: x), Stage("b", mid),
              Stage("c", lambda x: x)]
    p = StagePipeline(stages, max_queue=2)
    result = {}

    def drive():
        try:
            p.run(list(range(200)))
            result["outcome"] = "returned"
        except ValueError as e:
            result["outcome"] = f"raised:{e}"
        except BaseException as e:  # pragma: no cover - diagnostic
            result["outcome"] = f"other:{e!r}"

    t = threading.Thread(target=drive, daemon=True)
    t.start()
    t.join(timeout=10)
    assert not t.is_alive(), "pipeline deadlocked after mid-stage exception"
    assert result["outcome"] == "raised:boom@10"


def test_pipeline_feeder_error_no_deadlock():
    """The items ITERABLE raising mid-iteration (lazy loader hits a bad
    record) must abort the pipeline like a stage error — not strand the
    workers waiting on an input queue that will never see a sentinel."""
    def gen():
        for i in range(50):
            if i == 7:
                raise OSError("bad record")
            yield i

    p = StagePipeline([Stage("a", lambda x: x)], max_queue=2)
    result = {}

    def drive():
        try:
            p.run(gen())
            result["outcome"] = "returned"
        except OSError:
            result["outcome"] = "raised"

    t = threading.Thread(target=drive, daemon=True)
    t.start()
    t.join(timeout=10)
    assert not t.is_alive(), "pipeline deadlocked after feeder exception"
    assert result["outcome"] == "raised"


def test_pipeline_error_in_last_stage_no_deadlock():
    """Same, with the FAILING stage at the end: the feeder and both live
    stages are parked on bounded queues when the error hits."""
    def last(x):
        time.sleep(0.001)
        if x == 5:
            raise RuntimeError("tail")
        return x

    p = StagePipeline([Stage("a", lambda x: x), Stage("z", last)],
                      max_queue=1)
    done = []

    def drive():
        with pytest.raises(RuntimeError):
            p.run(iter(range(500)))
        done.append(True)

    t = threading.Thread(target=drive, daemon=True)
    t.start()
    t.join(timeout=10)
    assert not t.is_alive() and done


# ------------------------------------------------------------------ cache --
def test_cache_hit_miss_lru():
    c = EmbeddingCache(max_bytes=10 * 8 * 4)      # ~10 float32[8]
    arrs = {f"k{i}": np.full(8, i, np.float32) for i in range(15)}
    for k, v in arrs.items():
        c.put(k, v)
    assert c.stats()["bytes"] <= 10 * 8 * 4
    assert c.get("k14") is not None               # recent survives
    assert c.get("k0") is None                    # evicted (no spill)
    assert c.stats()["misses"] >= 1


def test_cache_spill_roundtrip(tmp_path):
    c = EmbeddingCache(max_bytes=4 * 8 * 4, spill_dir=str(tmp_path))
    for i in range(10):
        c.put(f"k{i}", np.full(8, i, np.float32))
    v = c.get("k0")                               # evicted -> spilled -> back
    assert v is not None and v[0] == 0
    assert c.stats()["spills"] >= 1


def test_cache_spill_runs_outside_lock(tmp_path):
    """Compression + disk writes must never happen while holding the cache
    lock (readers would stall behind every spill)."""
    c = EmbeddingCache(max_bytes=4 * 8 * 4, spill_dir=str(tmp_path))
    lock_held_during_spill = []
    orig = c._spill

    def spy(key, value):
        lock_held_during_spill.append(c._lock.locked())
        orig(key, value)

    c._spill = spy
    for i in range(10):
        c.put(f"k{i}", np.full(8, i, np.float32))
    assert lock_held_during_spill, "expected evictions to spill"
    assert not any(lock_held_during_spill)
    assert c.get("k0") is not None                # spilled entries retrievable


def test_content_key_stability():
    a = np.arange(12, dtype=np.float32).reshape(3, 4)
    assert content_key(a) == content_key(a.copy())
    assert content_key(a) != content_key(a.T.copy())
    assert content_key(a) != content_key(a.astype(np.float64))


def test_cache_require_raises_clear_keyerror():
    """A no-spill-dir eviction makes get() return None; require() must turn
    that into an actionable KeyError instead of letting np.stack crash."""
    c = EmbeddingCache(max_bytes=2 * 8 * 4)
    for i in range(6):
        c.put(f"k{i}", np.full(8, i, np.float32))
    assert c.get("k0") is None
    with pytest.raises(KeyError, match="evicted .* spill_dir"):
        c.require("k0")
    np.testing.assert_array_equal(c.require("k5"), np.full(8, 5, np.float32))


# ---------------------------------------------------------------- batcher --
def test_bucket_size():
    assert [bucket_size(n, 64) for n in (1, 2, 3, 5, 33, 64, 200)] == \
        [1, 2, 4, 8, 64, 64, 64]


def test_batcher_timeout_flush():
    """Fewer items than max_batch must still flush once timeout_s elapses —
    the batcher may not hold a partial batch waiting for a full one."""
    b = DynamicBatcher(lambda stacked, n: [stacked[i] for i in range(n)],
                       max_batch=64, timeout_s=0.02)
    try:
        t0 = time.perf_counter()
        futs = [b.submit(np.full(4, i, np.float32)) for i in range(3)]
        outs = [f.result(timeout=2.0) for f in futs]
        dt = time.perf_counter() - t0
        for i, o in enumerate(outs):
            np.testing.assert_array_equal(o, np.full(4, i, np.float32))
        assert dt < 1.0, f"timeout flush took {dt:.3f}s"
        assert b.stats()["batches"] == 1      # one partial batch, one flush
        assert b.stats()["items"] == 3
    finally:
        b.close()


def test_batcher_close_serves_pending():
    """close() with requests still queued must drain them (every future
    resolves) before the worker thread exits — no dropped work."""
    def slow(stacked, n):
        time.sleep(0.02)
        return [stacked[i] * 2 for i in range(n)]

    b = DynamicBatcher(slow, max_batch=4, timeout_s=0.5)
    xs = [np.full(4, i, np.float32) for i in range(12)]
    futs = [b.submit(x) for x in xs]
    b.close()                                  # pending batches still queued
    assert not b._thread.is_alive()
    for i, f in enumerate(futs):
        assert f.done(), f"future {i} dropped on close"
        np.testing.assert_array_equal(f.result(timeout=0), xs[i] * 2)


def test_batcher_batches_and_results():
    seen = []

    def fn(stacked, n):
        seen.append((stacked.shape[0], n))
        return [stacked[i] * 2 for i in range(n)]

    b = DynamicBatcher(fn, max_batch=8, timeout_s=0.02)
    xs = [np.full(4, i, np.float32) for i in range(20)]
    out = b.score(xs)
    b.close()
    for i, o in enumerate(out):
        np.testing.assert_array_equal(o, xs[i] * 2)
    assert all(s[0] in (1, 2, 4, 8) for s in seen)   # pow-2 buckets
    assert max(s[1] for s in seen) > 1               # actually batched


# ----------------------------------------------------------------- config --
def test_yaml_subset_parser_paper_example():
    text = """
name: "IMG_CLASSIFICATION"
version: 0.1
active_learning:
  strategy:
    type: "auto"
  model:
    name: "resnet18"
    hub_name: "pytorch/vision:release/0.12"
    batch_size: 1
  device: CPU
al_worker:
  protocol: "grpc"
  host: "0.0.0.0"
  port: 60035
  replicas: 1
"""
    d = parse_yaml(text)
    assert d["name"] == "IMG_CLASSIFICATION"
    assert d["active_learning"]["strategy"]["type"] == "auto"
    assert d["active_learning"]["model"]["batch_size"] == 1
    assert d["al_worker"]["port"] == 60035
    cfg = ALServiceConfig.from_dict(d)
    assert cfg.strategy == "auto" and cfg.model_name == "resnet18"
    assert cfg.port == 60035


def test_yaml_lists():
    d = parse_yaml("xs:\n  - 1\n  - 2\nys:\n  - a: 1\n  - b: 2\n")
    assert d["xs"] == [1, 2]
    assert d["ys"][0] == {"a": 1}


def test_yaml_prefilter_and_spill_knobs():
    text = """
active_learning:
  prefilter: true
  prefilter_slack: 0.1
  prefilter_clusters: 32
  prefilter_min_rows: 128
al_worker:
  replicas: 3
  shard_ram_bytes: 4096
  shard_spill_dir: "/tmp/spill"
"""
    cfg = ALServiceConfig.from_dict(parse_yaml(text))
    assert cfg.prefilter is True and cfg.prefilter_slack == 0.1
    assert cfg.prefilter_clusters == 32 and cfg.prefilter_min_rows == 128
    assert cfg.shard_ram_bytes == 4096
    assert cfg.shard_spill_dir == "/tmp/spill"
    # defaults: gate off (the oracle), unlimited RAM (no spill)
    d = ALServiceConfig()
    assert d.prefilter is False and d.shard_ram_bytes == 0
    assert d.shard_spill_dir is None


def test_yaml_strategy_state_and_standing_knobs():
    """The standing-query / persisted-state knobs round-trip through the
    YAML subset, and both default ON (their ``false`` settings are the
    bit-identity oracles, not the production path)."""
    text = """
active_learning:
  strategy_state_cache: false
  standing_replay: false
"""
    cfg = ALServiceConfig.from_dict(parse_yaml(text))
    assert cfg.strategy_state_cache is False
    assert cfg.standing_replay is False
    d = ALServiceConfig()
    assert d.strategy_state_cache is True and d.standing_replay is True


def test_yaml_transformer_model_knobs():
    """The transformer-backend knobs round-trip through the YAML subset
    under ``active_learning.model`` (the committed configs/*.yml files
    exercise the same schema end to end)."""
    text = """
active_learning:
  model:
    name: transformer
    batch_size: 8
    block_size: 32
    seq_len: 96
    pooling: last
    modality: audio
    input_dim: 12
"""
    cfg = ALServiceConfig.from_dict(parse_yaml(text))
    assert cfg.model_name == "transformer"
    assert cfg.model_block_size == 32 and cfg.model_seq_len == 96
    assert cfg.model_pooling == "last" and cfg.model_modality == "audio"
    assert cfg.model_input_dim == 12
    d = ALServiceConfig()
    assert (d.model_block_size, d.model_seq_len, d.model_pooling,
            d.model_modality, d.model_input_dim) == (64, 128, "mean",
                                                     "text", 0)


def test_yaml_shard_worker_knobs():
    """The shard-worker runtime knobs round-trip through the YAML subset
    under ``al_worker``; defaults are thread lanes with a 30s presumed-
    dead timeout and 2 bounded retries."""
    text = """
al_worker:
  replicas: 3
  backend: process
  timeout_s: 5.5
  retries: 4
  backoff_s: 0.25
"""
    cfg = ALServiceConfig.from_dict(parse_yaml(text))
    assert cfg.worker_backend == "process"
    assert cfg.worker_timeout_s == 5.5
    assert cfg.worker_retries == 4 and cfg.worker_backoff_s == 0.25
    d = ALServiceConfig()
    assert (d.worker_backend, d.worker_timeout_s,
            d.worker_retries, d.worker_backoff_s) == ("thread", 30.0, 2,
                                                      0.05)


def test_yaml_overload_serving_knobs():
    """The overload-safe-serving knobs round-trip through the YAML subset:
    admission (nested map incl. per-tenant fairness weights), socket
    idle/send timeouts, and the bounded-ingest cap/policy. Defaults keep
    every overload behaviour OFF — the bit-identity oracle."""
    text = """
al_worker:
  idle_timeout_s: 12.5
  send_timeout_s: 3.5
  ingest_max_rows: 1024
  ingest_max_bytes: 1048576
  ingest_policy: shed
  admission:
    enabled: true
    max_inflight: 32
    tenant_rate: 50.0
    tenant_burst: 16
    weights:
      tenant_a: 3.0
      tenant_b: 1
"""
    cfg = ALServiceConfig.from_dict(parse_yaml(text))
    assert cfg.admission is True
    assert cfg.admission_max_inflight == 32
    assert cfg.admission_tenant_rate == 50.0
    assert cfg.admission_tenant_burst == 16.0
    assert cfg.fairness_weights == {"tenant_a": 3.0, "tenant_b": 1.0}
    assert cfg.idle_timeout_s == 12.5 and cfg.send_timeout_s == 3.5
    assert cfg.ingest_max_rows == 1024
    assert cfg.ingest_max_bytes == 1048576
    assert cfg.ingest_policy == "shed"
    d = ALServiceConfig()
    assert d.admission is False and d.fairness_weights is None
    assert d.idle_timeout_s == 0.0 and d.send_timeout_s == 30.0
    assert (d.ingest_max_rows, d.ingest_max_bytes) == (0, 0)
    assert d.ingest_policy == "block"


# ----------------------------------------------------------------- server --
@pytest.fixture(scope="module")
def pool():
    X, Y = image_pool(240, seed=0)
    EX, EY = image_pool(120, seed=1)
    return X, Y, EX, EY


def _server(pool):
    X, Y, EX, EY = pool
    srv = ALServer(ALServiceConfig(batch_size=32))
    keys = srv.push_data(list(X))
    key2y = dict(zip(keys, Y))
    srv.attach_oracle(lambda ks: [key2y[k] for k in ks], EX, EY)
    return srv, keys, key2y


def test_server_round_improves_over_init(pool):
    srv, keys, key2y = _server(pool)
    res = srv.query(budget=60, strategy="lc")
    assert len(set(res["keys"])) == 60
    srv.label(res["keys"], [key2y[k] for k in res["keys"]])
    acc = srv.train_and_eval()
    assert acc > 0.2     # 10-class problem, must beat chance by 2x


def test_server_cache_hits_on_repush(pool):
    srv, keys, _ = _server(pool)
    h0 = srv.cache.stats()
    srv.push_data(list(pool[0][:50]))             # same content -> all cached
    assert srv.cache.stats()["entries"] == h0["entries"]


def test_server_pshea_auto(pool):
    srv, keys, key2y = _server(pool)
    res = srv.query(budget=120, strategy="auto", target_accuracy=0.99)
    assert res["strategy"] in ("lc", "mc", "rc", "es", "kcg", "coreset",
                               "dbal")
    assert len(res["eliminated"]) >= 1
    assert res["stop_reason"] in ("budget_exhausted", "target_accuracy",
                                  "converged", "max_rounds")


def test_server_pshea_hybrid_registry(pool):
    """auto_candidates="hybrid" races the weighted fused-round hybrids in
    the PSHEA agent alongside the paper's seven."""
    from repro.core.strategies.zoo import HYBRIDS, PAPER_SEVEN
    X, Y, EX, EY = pool
    srv = ALServer(ALServiceConfig(batch_size=32, auto_candidates="hybrid"))
    keys = srv.push_data(list(X))
    key2y = dict(zip(keys, Y))
    srv.attach_oracle(lambda ks: [key2y[k] for k in ks], EX, EY)
    res = srv.query(budget=150, strategy="auto", target_accuracy=0.99)
    assert res["strategy"] in PAPER_SEVEN + HYBRIDS
    assert set(res["history"]) == set(PAPER_SEVEN + HYBRIDS)
    # a candidate-set typo must fail loudly, not degrade to the default
    bad = ALServer(ALServiceConfig(auto_candidates="hybrids"))
    with pytest.raises(ValueError):
        bad._auto_candidates()


def test_tcp_roundtrip(pool):
    srv, keys, key2y = _server(pool)
    rpc = serve_tcp(srv)
    cli = ALClient(url=f"127.0.0.1:{rpc.port}")
    try:
        st = cli.stats()
        assert st["pool"] == 240
        res = cli.query(5, "mc")
        assert len(res["keys"]) == 5
        cli.label(res["keys"], [key2y[k] for k in res["keys"]])
        acc = cli.train_eval()
        assert 0.0 <= acc <= 1.0
    finally:
        cli.close()
        rpc.stop()


def test_pipelined_push_equals_serial_push(pool):
    X = list(pool[0][:64])
    s1 = ALServer(ALServiceConfig(batch_size=16))
    k1 = s1.push_data(X, pipelined=True)
    s2 = ALServer(ALServiceConfig(batch_size=16))
    k2 = s2.push_data(X, pipelined=False)
    assert k1 == k2
    f1 = np.stack([s1.cache.get(k) for k in k1])
    f2 = np.stack([s2.cache.get(k) for k in k2])
    np.testing.assert_allclose(f1, f2, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- sessions --
def _mlp_server(**cfg):
    """Cheap multi-tenant server (random-projection backend, no resnet)."""
    from repro.service.backends import MLPBackend
    return ALServer(ALServiceConfig(batch_size=16, **cfg),
                    backend=MLPBackend(in_dim=192, feat_dim=32))


def test_sessions_are_isolated(pool):
    X, Y = pool[0], pool[1]
    srv = _mlp_server()
    a = srv.create_session()
    b = srv.create_session()
    ka = srv.push_data(list(X[:40]), session=a)
    kb = srv.push_data(list(X[40:70]), session=b)
    assert srv.stats(session=a)["pool"] == 40
    assert srv.stats(session=b)["pool"] == 30
    assert srv.stats()["pool"] == 0                   # default untouched
    srv.label(ka[:10], Y[:10], session=a)
    assert srv.stats(session=a)["labeled"] == 10
    assert srv.stats(session=b)["labeled"] == 0
    res = srv.query(budget=5, strategy="lc", session=b)
    assert set(res["keys"]) <= set(kb)                # b never sees a's pool
    assert srv.train_and_eval(session=a) >= 0.0
    assert srv.train_and_eval(session=b) == 0.0       # b has no labels


def test_session_lifecycle_errors():
    srv = _mlp_server()
    with pytest.raises(KeyError, match="unknown session"):
        srv.query(1, strategy="lc", session="nope")
    with pytest.raises(ValueError):
        srv.create_session("default")                 # already exists
    with pytest.raises(ValueError):
        srv.close_session("default")                  # cannot close default
    sid = srv.create_session()
    srv.close_session(sid)
    assert sid not in srv.session_ids()


def test_tcp_sessions_isolated(pool):
    X = pool[0]
    srv = _mlp_server()
    rpc = serve_tcp(srv)
    url = f"127.0.0.1:{rpc.port}"
    a = ALClient(url=url, session="new")
    b = ALClient(url=url, session="new")
    try:
        a.push_data(list(X[:24]))
        b.push_data(list(X[24:40]))
        assert a.stats()["pool"] == 24
        assert b.stats()["pool"] == 16
        assert a.session != b.session
        res = a.query(4, "mc")
        assert len(res["keys"]) == 4
    finally:
        a.close()
        b.close()
        rpc.stop()
    assert srv.session_ids() == ["default"]           # close() cleaned up


def test_tcp_disconnect_reclaims_session(pool):
    """A client that vanishes without close_session must not leak its
    server-side session (raw pool copies and all)."""
    srv = _mlp_server()
    rpc = serve_tcp(srv)
    try:
        cli = ALClient(url=f"127.0.0.1:{rpc.port}", session="new")
        cli.push_data(list(pool[0][:8]))
        assert len(srv.session_ids()) == 2
        cli._rpc.close()                              # crash: no close_session
        deadline = time.time() + 5
        while len(srv.session_ids()) > 1 and time.time() < deadline:
            time.sleep(0.02)
        assert srv.session_ids() == ["default"]
    finally:
        rpc.stop()


# --------------------------------------------------------- artifact cache --
def test_artifact_cache_invalidation_matrix(pool):
    """The incremental invalidation matrix: repeated queries hit; a push
    delta-builds only the appended rows; label invalidates NOTHING (the
    unlabeled set is a query-time mask); train_and_eval refreshes probs
    only, with zero re-embeds."""
    X, Y = pool[0], pool[1]
    srv = _mlp_server()
    keys = srv.push_data(list(X[:60]))
    sess = srv.session()

    srv.query(budget=5, strategy="lc")
    assert sess.artifact_builds == 1
    assert (sess.full_builds, sess.delta_builds) == (1, 0)
    srv.query(budget=5, strategy="mc")
    srv.query(budget=5, strategy="kcg")
    assert sess.artifact_builds == 1                  # hits across strategies

    srv.push_data(list(pool[2][:4]))                  # new rows -> delta
    e0 = srv.embed_rows
    srv.query(budget=5, strategy="lc")
    assert sess.artifact_builds == 2
    assert (sess.full_builds, sess.delta_builds) == (1, 1)
    assert sess._columns[0].feats_rows == 64          # extended in place
    assert srv.embed_rows == e0                       # delta came from cache

    srv.label(keys[:10], Y[:10])                      # label -> NO rebuild
    srv.query(budget=5, strategy="lc")
    assert sess.artifact_builds == 2
    assert sess.labels_version == 1

    srv.train_and_eval()                              # new head -> probs only
    e1 = srv.embed_rows
    srv.query(budget=5, strategy="lc")
    assert sess.artifact_builds == 3
    assert sess.probs_refreshes == 1
    assert srv.embed_rows == e1                       # zero re-embeds
    srv.query(budget=5, strategy="es")
    assert sess.artifact_builds == 3

    st = srv.stats()                                  # observability payload
    assert st["artifacts"]["builds"] == 3
    assert st["artifacts"]["shard_builds"] == [3]
    assert st["artifacts"]["full_builds"] == 1
    assert st["artifacts"]["delta_builds"] == 1
    assert st["artifacts"]["probs_refreshes"] == 1
    assert st["labels_version"] == 1
    assert st["embeds"]["rows"] == 64                 # 60 + 4 pushed rows
    assert st["cache"]["hits"] > 0


def test_query_on_fully_labeled_pool_returns_empty(pool):
    """Regression: with every pool row labeled, budget clamps to 0 and the
    unsharded path used to crash embedding strategies (.at[0] on a (0,)
    selection buffer) instead of returning an empty selection like the
    sharded path."""
    X, Y = pool[0], pool[1]
    for replicas in (1, 3):
        srv = _mlp_server(replicas=replicas)
        keys = srv.push_data(list(X[:12]))
        srv.label(keys, Y[:12])
        for strategy in ("lc", "kcg"):
            res = srv.query(budget=4, strategy=strategy)
            assert res["keys"] == [] and res["indices"] == []


def test_artifact_cache_off_matches_on(pool):
    """Cache on/off must produce bit-identical selections (both build over
    the full pool; off just doesn't memoize)."""
    X, Y = pool[0], pool[1]
    picks = {}
    for cached in (True, False):
        srv = _mlp_server(artifact_cache=cached)
        keys = srv.push_data(list(X[:80]))
        srv.label(keys[:12], Y[:12])
        srv.train_and_eval()
        picks[cached] = {
            s: srv.query(budget=8, strategy=s, rng_seed=3)["keys"]
            for s in ("lc", "kcg", "coreset")}
    assert picks[True] == picks[False]
    srv_off = _mlp_server(artifact_cache=False)
    srv_off.push_data(list(X[:30]))
    sess = srv_off.session()
    srv_off.query(budget=4, strategy="lc")
    srv_off.query(budget=4, strategy="lc")
    assert sess.artifact_builds == 2                  # one build per query


def test_tiny_cache_recomputes_evicted_embeddings(pool):
    """Regression: with cache_bytes smaller than the pool and no spill dir,
    eviction used to make EmbeddingCache.get return None and crash
    np.stack inside query/train paths; the session now recomputes from its
    raw copies (or raises a clear KeyError)."""
    X, Y = pool[0], pool[1]
    srv = _mlp_server(cache_bytes=10 * 32 * 4)        # ~10 of 60 feats fit
    keys = srv.push_data(list(X[:60]))
    assert srv.cache.stats()["entries"] < 60          # eviction happened
    res = srv.query(budget=6, strategy="lc")          # full-pool artifacts
    assert len(res["keys"]) == 6
    srv.label(keys[:20], Y[:20])
    acc = srv.train_and_eval()                        # labeled-feats path
    assert 0.0 <= acc <= 1.0
    # raw copy gone AND evicted -> clear KeyError, not a np.stack crash
    sess = srv.session()
    missing = [k for k in keys if srv.cache.get(k) is None]
    if missing:
        del sess._raw[missing[0]]
        with pytest.raises(KeyError, match="evicted"):
            sess._feats_for([missing[0]])
