"""TransformerBackend: blockwise-chunked embedding for text/audio AL.

The contract under test (the PR-7 batch-insensitivity contract extended to
the sequence axis):

- the block size is bitwise-invisible: chunked == unchunked feature bytes
  at ANY block size, dividing or not;
- the forward is row-local, so canonical-padding batch composition never
  changes a sample's feature bytes (content-addressed cache safety);
- text-AL and audio-AL run end to end through ALServer/ALClient — replicas
  {1,3} select bit-identically, standing queries stream the exact one-shot
  selections;
- the analytic activation accounting is flat in sequence length at a fixed
  block size (the memory claim table2/transformer_embed re-asserts).
"""
import numpy as np
import pytest

from repro.data.synthetic import audio_pool, text_pool
from repro.models import blockwise
from repro.service.backends import TransformerBackend, make_backend
from repro.service.client import ALClient, serve_tcp
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer

SEQ = 48


def _text_backend(block, **kw):
    kw.setdefault("seq_len", SEQ)
    kw.setdefault("kv_chunk", 16)
    return TransformerBackend(block_size=block, **kw)


# ------------------------------------------------------ bitwise chunking --
@pytest.mark.parametrize("modality", ["text", "audio"])
def test_block_size_bitwise_invisible(modality):
    """blocks {5 (non-dividing), 16, 48 (=S), 64 (>S, unchunked)} produce
    the same feature bytes."""
    if modality == "text":
        raw, _ = text_pool(10, num_classes=4, seq_len=SEQ, vocab=512, seed=0)
        kw = {}
    else:
        raw, _ = audio_pool(10, num_classes=4, n_frames=SEQ, n_mels=8, seed=0)
        kw = {"modality": "audio", "input_dim": 8}
    feats = {}
    for block in (5, 16, SEQ, 64):
        be = _text_backend(block, **kw)
        feats[block] = be.features(be.preprocess(raw))
    ref = feats[5]
    assert ref.dtype == np.float32 and ref.shape == (10, be.feat_dim)
    for block, f in feats.items():
        assert np.array_equal(ref, f), f"block={block} changed feature bytes"


def test_batch_composition_row_local():
    """A sample's feature bytes survive any batchmates under the canonical
    batch_size padding (zero rows), exactly like the ResNet path."""
    raw, _ = text_pool(8, num_classes=4, seq_len=SEQ, vocab=512, seed=1)
    be = _text_backend(16)
    x = be.preprocess(raw)
    together = be.features(x[:4])
    alone = be.features(
        np.concatenate([x[:1], np.zeros((3,) + x.shape[1:], x.dtype)]))
    assert np.array_equal(together[0], alone[0])


def test_right_padding_invisible():
    """Shorter raw rows and pre-padded rows preprocess to the same
    canonical item, and pad positions never leak into pooled features."""
    raw, _ = text_pool(6, num_classes=3, seq_len=30, vocab=512, seed=2)
    padded = np.full((6, SEQ), -1, np.int32)
    padded[:, :30] = raw
    be = _text_backend(16)
    a = be.features(be.preprocess(raw))
    b = be.features(be.preprocess(padded))
    assert np.array_equal(a, b)


def test_pooling_knobs():
    raw, _ = text_pool(6, num_classes=3, seq_len=SEQ, vocab=512, seed=3)
    mean = _text_backend(16, pooling="mean")
    last = _text_backend(16, pooling="last")
    fm = mean.features(mean.preprocess(raw))
    fl = last.features(last.preprocess(raw))
    assert fm.shape == fl.shape and not np.array_equal(fm, fl)
    with pytest.raises(ValueError, match="pooling"):
        TransformerBackend(pooling="max")
    with pytest.raises(ValueError, match="modality"):
        TransformerBackend(modality="video")


def test_preprocess_validation():
    be = _text_backend(16)
    with pytest.raises(ValueError, match="int"):
        be.preprocess(np.zeros((4, 10), np.float32))
    with pytest.raises(ValueError, match="out of range"):
        be.preprocess(np.full((2, 4), 2_000_000, np.int64))
    with pytest.raises(ValueError, match="tokens"):
        be.preprocess(np.zeros((4,), np.int32))
    aud = TransformerBackend(modality="audio", input_dim=8, seq_len=32)
    with pytest.raises(ValueError, match="frames"):
        aud.preprocess(np.zeros((4, 32, 5), np.float32))


# ------------------------------------------------------------- accounting --
def test_activation_accounting_flat_in_seq_len():
    cfg = blockwise.tiny_encoder_config()
    accts = {S: blockwise.activation_accounting(cfg, 16, S, 128, 128)
             for S in (512, 2048, 8192)}
    peaks = [a["peak_activation_bytes"] for a in accts.values()]
    assert len(set(peaks)) == 1, f"peak activation not flat: {peaks}"
    # the O(S) state grows, the unchunked peak grows quadratically — the
    # blockwise forward is what keeps the working set flat
    unchunked = [a["unchunked_peak_bytes"] for a in accts.values()]
    assert unchunked[-1] > unchunked[0] * 100
    assert accts[8192]["state_bytes"] > accts[512]["state_bytes"]
    assert peaks[0] < unchunked[0]


# ------------------------------------------------------------ end to end --
def _text_config(**kw):
    kw.setdefault("model_name", "transformer")
    kw.setdefault("batch_size", 8)
    kw.setdefault("model_block_size", 16)
    kw.setdefault("model_seq_len", SEQ)
    kw.setdefault("strategy", "coreset")
    return ALServiceConfig(**kw)


def test_text_al_replicas_bit_identical():
    """Full text-AL loop via the config-built transformer backend: push,
    label, head train, coreset + lc queries — replicas {1,3} select the
    same keys (benchmark criterion (c), tier-1 sized)."""
    toks, y = text_pool(60, num_classes=4, seq_len=SEQ, vocab=512, seed=0)
    picks = {}
    for reps in (1, 3):
        srv = ALServer(config=_text_config(replicas=reps))
        assert isinstance(srv.backend, TransformerBackend)
        keys = srv.push_data(list(toks))
        srv.label(keys[:10], list(y[:10]))
        acc = srv.train_and_eval()
        assert 0.0 <= acc <= 1.0
        picks[reps] = {s: srv.query(8, s)["keys"] for s in ("coreset", "lc")}
    assert picks[1] == picks[3]


def test_audio_al_tcp_with_standing_query():
    """Audio-AL over the TCP client, standing query streaming as the pool
    grows; every cumulative selection matches the one-shot query."""
    x, y = audio_pool(48, num_classes=4, n_frames=32, n_mels=8, seed=5)
    srv = ALServer(config=_text_config(
        model_modality="audio", model_input_dim=8, model_seq_len=32,
        model_block_size=8))
    rpc = serve_tcp(srv)
    cli = ALClient(url=f"127.0.0.1:{rpc.port}")
    try:
        keys = cli.push_data(list(x[:24]))
        assert len(keys) == 24
        cli.label(keys[:8], list(y[:8]))
        assert 0.0 <= cli.train_eval() <= 1.0
        reg = cli.standing_register(budget=5, strategy="coreset")
        seen = reg["seq"]
        cli.push_data(list(x[24:]))
        r = cli.standing_poll(reg["query_id"], since=seen)
        assert r["emits"], "no emit after the streamed push"
        assert r["keys"] == cli.query(5, "coreset")["keys"]
        cli.standing_cancel(reg["query_id"])
    finally:
        cli.close()
        rpc.stop()


def test_yaml_config_drives_transformer_backend():
    yml = """
name: TEXT_AL
active_learning:
  strategy:
    type: lc
  model:
    name: transformer
    batch_size: 8
    block_size: 16
    seq_len: 48
    pooling: mean
    modality: text
al_worker:
  replicas: 2
"""
    cfg = ALServiceConfig.from_yaml(yml)
    srv = ALServer(config=cfg)
    be = srv.backend
    assert isinstance(be, TransformerBackend)
    assert (be.block_size, be.seq_len, be.pooling, be.modality) == \
        (16, 48, "mean", "text")
    toks, y = text_pool(30, num_classes=3, seq_len=SEQ, vocab=512, seed=7)
    keys = srv.push_data(list(toks))
    srv.label(keys[:6], list(y[:6]))
    srv.train_and_eval()
    assert len(srv.query(5, "lc")["keys"]) == 5


def test_committed_config_examples_build_backends():
    """The worked configs/ examples stay loadable and build the backend
    they document."""
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[1] / "configs"
    text = ALServiceConfig.from_yaml(str(root / "text_al.yml"))
    assert (text.model_name, text.model_modality) == ("transformer", "text")
    audio = ALServiceConfig.from_yaml(str(root / "audio_al.yml"))
    be = make_backend(audio.model_name, config=audio)
    assert isinstance(be, TransformerBackend)
    assert (be.modality, be.input_dim, be.pooling) == ("audio", 16, "last")


def test_make_backend_registry():
    be = make_backend("transformer", seq_len=16, block_size=4)
    assert isinstance(be, TransformerBackend)
    with pytest.raises(KeyError):
        make_backend("transformer9000")
