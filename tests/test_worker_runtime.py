"""Shard-worker runtime (distributed.worker): supervision, failure
injection, kill-recovery bit-identity, and the fault_tolerance bugfixes.

The fault matrix is the tentpole contract: a worker killed during EMBED,
PROPOSE, or INGEST-DRAIN is detected, its shard recovers (columns reset,
re-embedded from raw + content keys on retry), and the session's
selections stay bit-identical to a clean run — with the restart and
recovery counters surfaced through ``stats()``.
"""
import time

import numpy as np
import pytest

from repro.distributed.elastic import largest_mesh_shape
from repro.distributed.fault_tolerance import (SimulatedFailure,
                                               StragglerMonitor, supervise)
from repro.distributed.worker import (PhaseFailureInjector, ShardWorkerPool,
                                      WorkerDeath)
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer


# ---------------------------------------------------------------------------
# pool-level supervision (no AL service involved)
# ---------------------------------------------------------------------------

def test_map_runs_items_on_lanes_and_counts_tasks():
    pool = ShardWorkerPool(3, backoff_s=0.0)
    try:
        out = pool.map(lambda x: x * 2, [1, 2, 3])
        assert out == [2, 4, 6]
        st = pool.stats()
        assert st["tasks"] == 3 and st["restarts"] == 0
        assert st["backend"] == "thread" and st["lanes"] == 3
    finally:
        pool.shutdown()


def test_injected_death_restarts_lane_and_retries():
    inj = PhaseFailureInjector({"embed": [1]})
    pool = ShardWorkerPool(2, injector=inj, backoff_s=0.0)
    deaths = []
    try:
        ex = pool.scoped("embed", on_death=deaths.append)
        assert ex.map(lambda x: x + 10, [1, 2]) == [11, 12]
        st = pool.stats()
        assert st["restarts"] == 1
        assert st["generations"] == [0, 1]     # item 1 rode lane 1
        assert deaths == [1]                   # recovery hook saw the shard
        assert inj.fired == [("embed", 1)]
    finally:
        pool.shutdown()


def test_injector_fires_once_per_scheduled_index():
    inj = PhaseFailureInjector({"p": [0]})
    with pytest.raises(SimulatedFailure):
        inj.maybe_fail("p")
    inj.maybe_fail("p")            # index 1: clean
    inj.maybe_fail("q")            # other phases: never scheduled


def test_death_every_attempt_exhausts_bounded_retries():
    # attempts consume phase indices 0,1,2 — all scheduled to die
    inj = PhaseFailureInjector({"embed": [0, 1, 2]})
    pool = ShardWorkerPool(1, injector=inj, max_retries=2, backoff_s=0.0)
    try:
        with pytest.raises(WorkerDeath, match="after 3 attempts"):
            pool.scoped("embed").map(lambda x: x, [0])
    finally:
        pool.shutdown()


def test_hung_task_detected_by_timeout_and_retried():
    calls = []
    pool = ShardWorkerPool(1, timeout_s=0.2, backoff_s=0.0)

    def fn(x):
        calls.append(x)
        if len(calls) == 1:
            time.sleep(1.2)        # hang well past the timeout
        return x + 1

    try:
        assert pool.map(fn, [5]) == [6]
        st = pool.stats()
        assert st["restarts"] == 1 and st["generations"] == [1]
    finally:
        pool.shutdown()


def test_task_raising_timeouterror_propagates_not_retried():
    # a task's own TimeoutError must not be mistaken for a hang
    def fn(x):
        raise TimeoutError("from the task itself")

    pool = ShardWorkerPool(1, backoff_s=0.0)
    try:
        with pytest.raises(TimeoutError, match="from the task itself"):
            pool.map(fn, [0])
        assert pool.stats()["restarts"] == 0
    finally:
        pool.shutdown()


def test_kill_marks_lane_dead_probe_detects_next_task_recovers():
    pool = ShardWorkerPool(2, backoff_s=0.0)
    try:
        pool.kill(0)
        assert pool.probe() == [False, True]
        deaths = []
        out = pool.scoped("shard", on_death=deaths.append).map(
            lambda x: x, ["a", "b"])
        assert out == ["a", "b"]
        assert deaths == [0]
        assert pool.probe() == [True, True]    # restarted lane is live
    finally:
        pool.shutdown()


# ---------------------------------------------------------------------------
# fault_tolerance bugfixes (satellites)
# ---------------------------------------------------------------------------

def test_straggler_outlier_during_warmup_does_not_poison_ema():
    mon = StragglerMonitor(threshold=2.5, alpha=0.5, warmup=5)
    mon.observe(0, 1.0)
    mon.observe(1, 1.0)
    assert mon.observe(2, 100.0) is None      # warmup: no event...
    assert mon.ema == pytest.approx(1.0)      # ...and no EMA poisoning
    for s in range(3, 8):
        mon.observe(s, 1.0)
    ev = mon.observe(8, 100.0)                # past warmup: real event
    assert ev is not None and ev.ratio > 2.5
    assert mon.ema == pytest.approx(1.0)      # outlier still never folds
    assert len(mon.events) == 1


def test_supervise_reports_straggler_events_from_monitor():
    mon = StragglerMonitor(threshold=2.0, warmup=1)
    state = {"step": 0}

    def train_round(start):
        for s in range(start, 4):
            mon.observe(s, 10.0 if s == 3 else 0.01)
            state["step"] = s + 1
        return 4

    rep = supervise(train_round, total_steps=4,
                    latest_step=lambda: state["step"], monitor=mon)
    assert rep.straggler_events == len(mon.events) == 1
    assert rep.restarts == 0
    # and without a monitor the field is an honest 0, not a dead field
    state["step"] = 0
    rep0 = supervise(train_round, total_steps=4,
                     latest_step=lambda: state["step"])
    assert rep0.straggler_events == 0


def test_largest_mesh_shape_validates_inputs():
    with pytest.raises(ValueError, match="model_parallel"):
        largest_mesh_shape(8, model_parallel=0)
    with pytest.raises(ValueError, match="model_parallel"):
        largest_mesh_shape(8, model_parallel=-2)
    with pytest.raises(ValueError, match="n_devices"):
        largest_mesh_shape(0, model_parallel=1)
    assert largest_mesh_shape(8, 4) == (2, 4)
    assert largest_mesh_shape(6, 4) == (2, 3)   # clamped to a divisor
    assert largest_mesh_shape(4, 9) == (1, 4)   # model > n clamps to n


# ---------------------------------------------------------------------------
# fault-injection matrix against the AL service (the tentpole contract)
# ---------------------------------------------------------------------------

def _pool(n=36, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, 8, 8, 3)).astype(np.float32)


def _build(injector=None, **cfg_kw):
    cfg = ALServiceConfig(replicas=3, batch_size=8, worker_backoff_s=0.0,
                          **cfg_kw)
    srv = ALServer(config=cfg, failure_injector=injector)
    keys = srv.push_data(list(_pool()))
    srv.label(keys[:6], [0, 1, 0, 1, 0, 1])
    srv.train_and_eval()
    return srv, keys


@pytest.fixture(scope="module")
def clean_selection():
    srv, _ = _build()
    return {s: srv.query(6, strategy=s, rng_seed=7)["keys"]
            for s in ("coreset", "mc")}


@pytest.mark.parametrize("phase", ["embed", "propose"])
def test_kill_during_query_phase_recovers_bit_identical(phase,
                                                        clean_selection):
    inj = PhaseFailureInjector({phase: [0]})
    srv, _ = _build(injector=inj)
    for strat in ("coreset", "mc"):
        got = srv.query(6, strategy=strat, rng_seed=7)["keys"]
        assert got == clean_selection[strat], (
            f"kill during {phase} diverged the {strat} selection")
    st = srv.stats()
    assert inj.fired and st["workers"]["restarts"] >= 1
    assert st["worker_recoveries"] >= 1
    assert st["workers"]["straggler_events"] == len(
        srv.shard_runtime().monitor.events)


def test_kill_during_ingest_drain_loses_no_rows(clean_selection):
    inj = PhaseFailureInjector({"ingest": [0]})
    cfg = ALServiceConfig(replicas=3, batch_size=8, worker_backoff_s=0.0)
    srv = ALServer(config=cfg, failure_injector=inj)
    tickets = [srv.push_data([x], asynchronous=True) for x in _pool()]
    srv.flush()
    uniq = {k for t in tickets for k in t.keys}
    st = srv.stats()
    assert inj.fired == [("ingest", 0)]
    assert st["pool"] == len(uniq), "kill during ingest drain lost rows"
    assert st["workers"]["restarts"] >= 1
    # and the recovered pool still selects exactly like the clean run
    srv.label([t.keys[0] for t in tickets[:6]], [0, 1, 0, 1, 0, 1])
    srv.train_and_eval()
    got = srv.query(6, strategy="coreset", rng_seed=7)["keys"]
    assert got == clean_selection["coreset"]


def test_recovery_reembeds_from_raw_when_cache_evicted(clean_selection):
    # a 1-byte embedding cache evicts everything: the reset shard can only
    # rebuild through the raw copies + content keys — the data layer's
    # re-embed path — and must still match the clean selection
    inj = PhaseFailureInjector({"embed": [0]})
    srv, _ = _build(injector=inj, cache_bytes=1)
    got = srv.query(6, strategy="coreset", rng_seed=7)["keys"]
    assert got == clean_selection["coreset"]
    assert srv.stats()["worker_recoveries"] >= 1


# ---------------------------------------------------------------------------
# process-backed lanes (real OS workers; spawn + jax import => slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_process_lane_kill_probe_restart_roundtrip():
    pool = ShardWorkerPool(2, kind="process", timeout_s=60.0, backoff_s=0.0)
    try:
        assert pool.run_job(0, "echo", {"v": 42}) == {"v": 42}
        pool.kill(0)
        deadline = time.monotonic() + 5.0
        while pool.probe()[0] and time.monotonic() < deadline:
            time.sleep(0.05)
        assert pool.probe() == [False, True]
        assert pool.run_job(0, "echo", 7) == 7     # restarted + retried
        st = pool.stats()
        assert st["restarts"] >= 1 and st["generations"][0] >= 1
    finally:
        pool.shutdown()


@pytest.mark.slow
def test_process_backend_selections_match_thread_backend():
    sel = {}
    for kind in ("thread", "process"):
        # cache_bytes=1 forces every artifact build through the re-embed
        # path, which is what ships to the worker processes
        cfg = ALServiceConfig(replicas=2, batch_size=8, worker_backend=kind,
                              cache_bytes=1, worker_timeout_s=120.0)
        srv = ALServer(config=cfg)
        keys = srv.push_data(list(_pool(24, seed=3)))
        srv.label(keys[:4], [0, 1, 0, 1])
        srv.train_and_eval()
        sel[kind] = srv.query(5, strategy="coreset", rng_seed=3)["keys"]
        assert srv.stats()["workers"]["backend"] == kind
    assert sel["process"] == sel["thread"]
