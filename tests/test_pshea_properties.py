"""PSHEA invariants as properties (hypothesis; skips cleanly when absent).

Marked ``slow``: CI runs these in the tier-2 lane (`-m slow`).
"""
import threading

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.agent.controller import run_pshea
from repro.core.agent.predictor import predict_next

pytestmark = pytest.mark.slow

SET = settings(max_examples=20, deadline=None)


class CurveTask:
    """Deterministic neg-exp curves per strategy; thread-safe accounting of
    every (strategy, budget) charge so parallel runs can be audited."""

    def __init__(self, curves):
        self.curves = curves
        self.rounds = {s: 0 for s in curves}
        self.charges = []
        self._lock = threading.Lock()

    def initial_accuracy(self):
        return 0.1

    def select_and_label(self, strategy, round_budget):
        with self._lock:
            self.charges.append((strategy, round_budget))
        return round_budget

    def train_and_eval(self, strategy):
        self.rounds[strategy] += 1
        a, b, c = self.curves[strategy]
        return float(a - b * np.exp(-c * self.rounds[strategy]))


def curves_strategy():
    curve = st.tuples(st.floats(0.3, 0.99), st.floats(0.05, 0.8),
                      st.floats(0.05, 3.0))
    return st.lists(curve, min_size=2, max_size=8).map(
        lambda cs: {f"s{i}": c for i, c in enumerate(cs)})


PSHEA_KW = st.fixed_dictionaries({
    "round_budget": st.integers(1, 20),
    "budget_max": st.integers(10, 400),
    "target_accuracy": st.floats(0.3, 2.0),
    "max_rounds": st.integers(1, 12),
    "converge_patience": st.integers(1, 100),
})


@SET
@given(curves=curves_strategy(), kw=PSHEA_KW, workers=st.sampled_from([2, 4, 8]))
def test_parallel_bit_identical_to_serial(curves, kw, workers):
    serial = run_pshea(CurveTask(curves), list(curves), max_workers=1, **kw)
    parallel = run_pshea(CurveTask(curves), list(curves),
                         max_workers=workers, **kw)
    assert serial == parallel          # dataclass eq: every field, bitwise


@SET
@given(curves=curves_strategy(), kw=PSHEA_KW)
def test_eliminated_plus_survivors_partition_candidates(curves, kw):
    res = run_pshea(CurveTask(curves), list(curves), **kw)
    candidates = set(curves)
    eliminated = res.eliminated
    survivors = [s for s in curves if s not in eliminated]
    assert len(eliminated) == len(set(eliminated))    # no double elimination
    assert set(eliminated) <= candidates
    assert set(eliminated) | set(survivors) == candidates
    assert set(eliminated).isdisjoint(survivors)
    assert len(survivors) >= 1                        # never eliminate all
    assert set(res.history) == candidates             # history covers all
    assert res.best_strategy in candidates


@SET
@given(curves=curves_strategy(), kw=PSHEA_KW,
       workers=st.sampled_from([1, 4]))
def test_budget_spent_matches_per_round_sums(curves, kw, workers):
    task = CurveTask(curves)
    res = run_pshea(task, list(curves), max_workers=workers, **kw)
    # every charge the task saw is accounted, and equals the per-round sum
    # of live-candidate charges reconstructed from the histories
    assert res.budget_spent == sum(b for _, b in task.charges)
    per_strategy_rounds = {s: len(h) - 1 for s, h in res.history.items()}
    assert res.budget_spent == \
        sum(r * kw["round_budget"] for r in per_strategy_rounds.values())
    assert sum(per_strategy_rounds.values()) == len(task.charges)


@SET
@given(accs=st.lists(st.floats(0.0, 1.0), min_size=1, max_size=2))
def test_predict_next_short_history_last_value_fallback(accs):
    nxt = predict_next(range(len(accs)), accs, len(accs))
    assert nxt == accs[-1]             # <3 points: no reliable fit


@SET
@given(accs=st.lists(st.floats(-5.0, 5.0), min_size=3, max_size=12),
       horizon=st.integers(0, 20))
def test_predict_next_clipped_to_unit_interval(accs, horizon):
    nxt = predict_next(range(len(accs)), accs, horizon)
    assert 0.0 <= nxt <= 1.0
