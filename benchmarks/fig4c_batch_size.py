"""Paper Fig. 4c — end-to-end AL throughput vs inference batch size.

Reproduces the paper's observed regimes: flat at tiny batches (transfer
dominated), steep gains in the middle, saturation once compute capacity is
reached."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import make_pool, make_server, row


def run() -> list:
    X, Y, EX, EY = make_pool(n=512)
    out = []
    for bs in (1, 2, 4, 8, 16, 32, 64):
        srv, _ = make_server(X, Y, EX, EY, batch_size=bs,
                             fetch_latency_s=0.005, push=False)
        t0 = time.perf_counter()
        srv.push_data(list(X), pipelined=True)
        dt = time.perf_counter() - t0
        thr = len(X) / dt
        out.append(row(f"fig4c/bs{bs}", dt * 1e6 / len(X),
                       f"throughput_img_s={thr:.1f}"))
    return out
