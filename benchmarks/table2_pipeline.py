"""Paper Table 2 — one-round AL latency/throughput: pipelined ALaaS vs the
serial execution model of prior tools (DeepAL/ModAL/ALiPy/libact run
fetch -> preprocess -> infer strictly in sequence).

Same data, same backend, same strategy (least confidence, as in the paper);
only the execution model differs — so the speedup isolates the paper's
stage-level-parallelism + batching contribution. A synthetic fetch latency
emulates the S3-download stage of the paper's cloud setup.

Accuracy parity is also checked (paper Table 2: identical accuracy across
tools running the same strategy).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import make_pool, make_server, row


def run() -> list:
    X, Y, EX, EY = make_pool(n=512)
    out = []
    accs = {}
    times = {}
    for mode in ("serial", "pipelined"):
        srv, key2y = make_server(X, Y, EX, EY, batch_size=32,
                                 fetch_latency_s=0.02, push=False)
        t0 = time.perf_counter()
        keys = srv.push_data(list(X), pipelined=(mode == "pipelined"))
        key2y = dict(zip(keys, Y))
        srv.attach_oracle(lambda ks: [key2y[k] for k in ks], EX, EY)
        res = srv.query(budget=128, strategy="lc")
        srv.label(res["keys"], [key2y[k] for k in res["keys"]])
        acc = srv.train_and_eval()
        dt = time.perf_counter() - t0
        accs[mode] = acc
        times[mode] = dt
        thr = len(X) / dt
        out.append(row(f"table2/{mode}_one_round", dt * 1e6,
                       f"latency_s={dt:.2f};throughput_img_s={thr:.1f};"
                       f"top1_acc={acc:.3f}"))
    speed = times["serial"] / times["pipelined"]
    par = abs(accs["serial"] - accs["pipelined"]) < 1e-6
    out.append(row("table2/speedup", 0.0,
                   f"pipelined_over_serial={speed:.2f}x;accuracy_parity={par}"))
    return out
