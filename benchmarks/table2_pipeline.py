"""Paper Table 2 — serving-layer latency/throughput, four experiments:

1. one-round AL: pipelined ALaaS vs the serial execution model of prior
   tools (DeepAL/ModAL/ALiPy/libact run fetch -> preprocess -> infer
   strictly in sequence). Same data/backend/strategy; only the execution
   model differs, so the speedup isolates stage-level parallelism +
   batching. A synthetic fetch latency emulates the S3-download stage of
   the paper's cloud setup. Accuracy parity is checked (paper Table 2).

2. concurrent clients: N tenants, each with its own server-side session,
   drive one TCP server concurrently vs one-after-another — the
   multi-tenant throughput column. Session isolation is asserted.

3. parallel PSHEA racing: the agent's candidates advance concurrently, so
   a round costs max(candidate) not sum(candidate). The oracle's
   annotation round-trip is emulated with a sleep (as fetch_latency_s
   emulates S3) and calibrated to the measured compute so the asserted
   ratio is machine-independent; the pure-compute ratio is reported too
   (the CPU-ref selection kernels are dispatch-bound — ROADMAP PR-1 —
   so compute-side racing pays off on the TPU path, not here).
   Asserted: parallel round wall clock < 0.6x serial with >= 4 live
   candidates, and serial/parallel results bit-identical.

4. pool-artifact cache: with the versioned (feats, probs) memo the whole
   PSHEA run does ONE artifact build per (pool_version, head_version)
   where cache-off builds once per candidate query — both asserted, with
   cache-on/off selections bit-identical.

5. replica sharding: selections with the pool hash-sharded across
   replicas=4 are asserted bit-identical to replicas=1 for four
   strategies spanning the uncertainty / k-center / D²-sampling families,
   and ingest throughput with push_data(asynchronous=True) (server-side
   queue, per-shard parallel embedding, one version bump per drained
   batch) is asserted >= 1.3x the synchronous push loop at 4 shards.

6. incremental pool artifacts: op-accounted invalidation matrix of the
   per-shard epoch-versioned artifact columns — a B-row push embeds
   exactly B rows and rebuilds only the shards those rows hash to,
   train_and_eval re-embeds nothing (head-only prob refresh), label
   rebuilds nothing, and every stage's selections are bit-identical to
   ``artifact_cache: false`` from-scratch builds at replicas 1 and 4.
   CI re-asserts the emitted counts from the uploaded JSON
   (scripts/assert_table2_incremental.py), so an O(N)-rebuild regression
   fails the lane rather than just slowing it.

7. centroid-gated prefilter: on a redundancy-heavy pool (most rows are
   near-duplicates inside tight clumps, the regime the paper's
   data-centric framing targets) where the labeled set covers the dense
   mass, ``prefilter: true`` selections are asserted bit-identical to the
   ``prefilter: false`` full-scan oracle at >=10x fewer pool rows touched
   for least-confidence top-k AND the warm-started Core-Set greedy —
   op-accounted in ``ops.track_ops`` pool-row units. A degenerate-slack
   run (bound never prunes) is asserted bit-identical too, and k-center
   greedy WITHOUT a warm start is reported unasserted: its uncovered
   clusters stay competitive every round, so gating only defers their
   catch-up folds (the honest negative result). CI re-asserts the ratios
   from the uploaded JSON (scripts/assert_table2_prefilter.py).

8. mmap shard spill: a server whose artifact columns spill to
   memmap-backed files (``shard_ram_bytes`` far below the pool size) is
   driven through an interleaved push/query/label/retrain/push script and
   asserted bit-identical to the RAM-resident server at replicas 1 and 3,
   with the spill counters asserted nonzero (the spill path actually ran).

9. standing queries: a registered ``(budget, coreset)`` subscription is
   streamed near-duplicate deltas; every emit rides the O(delta) replay
   engine (persisted per-shard min-dist state + recorded per-slot winner
   scores), op-accounted in pool-row units and asserted at >=10x fewer
   rows than the full re-selection an emit costs with
   ``standing_replay: false`` — while the final streamed selection is
   asserted bit-identical to a one-shot query over the final pool on a
   fresh server with every incremental engine off. CI re-asserts the
   ratio from the uploaded JSON (scripts/assert_table2_standing.py).

10. blockwise transformer embedding: the text/audio ingest backbone
   (models/blockwise.py) is asserted (a) bitwise chunk-invisible —
   chunked == unchunked feature bytes across block sizes including a
   non-dividing one, (b) memory-flat — the analytic per-block activation
   accounting is identical across sequence lengths {512, 2048, 8192} at a
   fixed block while the unchunked comparator grows quadratically, and
   (c) a text-AL scenario through ALServer (push, label, head train,
   coreset + lc queries, a standing query streaming a delta) selects
   bit-identically at replicas {1,3}. CI re-asserts (a)+(b) from the
   uploaded JSON (scripts/assert_table2_transformer.py).
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import make_pool, make_server, row, warm_start
from repro.service.client import ALClient, serve_tcp
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer


def _pipeline_vs_serial() -> list:
    X, Y, EX, EY = make_pool(n=512)
    out = []
    accs = {}
    times = {}
    for mode in ("serial", "pipelined"):
        srv, key2y = make_server(X, Y, EX, EY, batch_size=32,
                                 fetch_latency_s=0.02, push=False)
        t0 = time.perf_counter()
        keys = srv.push_data(list(X), pipelined=(mode == "pipelined"))
        key2y = dict(zip(keys, Y))
        srv.attach_oracle(lambda ks: [key2y[k] for k in ks], EX, EY)
        res = srv.query(budget=128, strategy="lc")
        srv.label(res["keys"], [key2y[k] for k in res["keys"]])
        acc = srv.train_and_eval()
        dt = time.perf_counter() - t0
        accs[mode] = acc
        times[mode] = dt
        thr = len(X) / dt
        out.append(row(f"table2/{mode}_one_round", dt * 1e6,
                       f"latency_s={dt:.2f};throughput_img_s={thr:.1f};"
                       f"top1_acc={acc:.3f}"))
    speed = times["serial"] / times["pipelined"]
    par = abs(accs["serial"] - accs["pipelined"]) < 1e-6
    out.append(row("table2/speedup", 0.0,
                   f"pipelined_over_serial={speed:.2f}x;accuracy_parity={par}"))
    return out


def _concurrent_clients(n_clients: int = 4, per_client: int = 96) -> list:
    """N tenants on one server: sequential vs concurrent wall clock."""
    X, Y, _, _ = make_pool(n=n_clients * per_client)
    slices = [(list(X[i * per_client:(i + 1) * per_client]),
               list(Y[i * per_client:(i + 1) * per_client]))
              for i in range(n_clients)]

    def one_tenant(url, xs, ys):
        cli = ALClient(url=url, session="new")
        try:
            keys = cli.push_data(xs)
            res = cli.query(budget=16, strategy="lc")
            key2y = dict(zip(keys, ys))
            cli.label(res["keys"], [key2y[k] for k in res["keys"]])
            cli.train_eval()
            return cli.stats()["pool"]
        finally:
            cli.close()

    times = {}
    for mode in ("sequential", "concurrent"):
        srv = ALServer(ALServiceConfig(batch_size=32), fetch_latency_s=0.02)
        rpc = serve_tcp(srv)
        url = f"127.0.0.1:{rpc.port}"
        pools = [None] * n_clients
        try:
            t0 = time.perf_counter()
            if mode == "sequential":
                for i, (xs, ys) in enumerate(slices):
                    pools[i] = one_tenant(url, xs, ys)
            else:
                def drive(i, xs, ys):
                    pools[i] = one_tenant(url, xs, ys)
                ts = [threading.Thread(target=drive, args=(i, xs, ys))
                      for i, (xs, ys) in enumerate(slices)]
                for t in ts:
                    t.start()
                for t in ts:
                    t.join()
            times[mode] = time.perf_counter() - t0
        finally:
            rpc.stop()
        # session isolation: every tenant saw exactly its own pool, and the
        # default session saw none of it
        assert pools == [per_client] * n_clients, pools
        assert srv.stats()["pool"] == 0
    total = n_clients * per_client
    speed = times["sequential"] / times["concurrent"]
    return [
        row("table2/clients_sequential", times["sequential"] * 1e6,
            f"clients={n_clients};throughput_img_s="
            f"{total / times['sequential']:.1f}"),
        row("table2/clients_concurrent", times["concurrent"] * 1e6,
            f"clients={n_clients};throughput_img_s="
            f"{total / times['concurrent']:.1f}"),
        row("table2/clients_speedup", 0.0,
            f"concurrent_over_sequential={speed:.2f}x;isolation=True"),
    ]


def _pshea_task_calls(res: dict) -> int:
    """Candidate-rounds executed = per-strategy history growth."""
    return sum(len(h) - 1 for h in res["history"].values())


def _parallel_pshea(n: int = 320, budget: int = 280) -> list:
    X, Y, EX, EY = make_pool(n=n)
    srv, _ = make_server(X, Y, EX, EY, batch_size=32, push=False)
    keys = srv.push_data(list(X))
    key2y = dict(zip(keys, Y))
    latency = {"s": 0.0}

    def oracle(ks):
        if latency["s"]:
            time.sleep(latency["s"])     # annotation-service round trip
        return [key2y[k] for k in ks]

    srv.attach_oracle(oracle, EX, EY)
    warm_start(srv, key2y)

    def run(workers):
        t0 = time.perf_counter()
        res = srv.query(budget=budget, strategy="auto",
                        target_accuracy=0.995, pshea_workers=workers)
        return res, time.perf_counter() - t0

    run(1)                               # jit warmup (same shapes as below)
    # pure-compute ratio (informational: dispatch-bound on CPU-ref kernels)
    res_s0, t_s0 = run(1)
    res_p0, t_p0 = run(7)
    calls = _pshea_task_calls(res_s0)
    rounds = res_s0["rounds"]
    # calibrate the emulated annotator RTT to the measured compute so the
    # asserted ratio holds on any CPU: serial pays `calls` RTTs, parallel
    # overlaps them to ~`rounds` RTTs
    latency["s"] = max(0.3, t_s0 / (calls / 2))
    res_s, t_s = run(1)
    res_p, t_p = run(7)
    assert res_s == res_p == res_s0 == res_p0, \
        "parallel PSHEA must be bit-identical to the serial schedule"
    live_last = len(res_s["history"]) - (rounds - 1)  # 1 eliminated/round
    assert live_last >= 4, f"need >=4 live candidates, got {live_last}"
    ratio = t_p / t_s                    # per-round ratio == total ratio
    assert ratio < 0.6, (
        f"parallel PSHEA round wall clock {ratio:.2f}x serial (need <0.6x); "
        f"serial={t_s:.2f}s parallel={t_p:.2f}s rounds={rounds}")
    return [
        row("table2/pshea_serial", t_s / rounds * 1e6,
            f"rounds={rounds};candidate_rounds={calls};wall_s={t_s:.2f};"
            f"oracle_rtt_s={latency['s']:.2f}"),
        row("table2/pshea_parallel", t_p / rounds * 1e6,
            f"rounds={rounds};workers=7;wall_s={t_p:.2f};"
            f"bit_identical=True"),
        row("table2/pshea_speedup", 0.0,
            f"parallel_over_serial_round={ratio:.2f}x;"
            f"pure_compute_ratio={t_p0 / t_s0:.2f}x;asserted_lt=0.6x"),
    ]


def _artifact_cache_matrix(n: int = 256, budget: int = 140) -> list:
    X, Y, EX, EY = make_pool(n=n)
    results = {}
    builds = {}
    for cached in (True, False):
        srv, _ = make_server(X, Y, EX, EY, batch_size=32, push=False,
                             artifact_cache=cached)
        keys = srv.push_data(list(X))
        key2y = dict(zip(keys, Y))
        srv.attach_oracle(lambda ks: [key2y[k] for k in ks], EX, EY)
        warm_start(srv, key2y)
        before = srv.session().artifact_builds
        res = srv.query(budget=budget, strategy="auto",
                        target_accuracy=0.995)
        results[cached] = res
        builds[cached] = srv.session().artifact_builds - before
    calls = _pshea_task_calls(results[True])
    # the whole run happens at ONE (pool_version, head_version): cache-on
    # builds the (feats, probs) artifact exactly once; cache-off rebuilds
    # it for every candidate query of every round
    assert builds[True] == 1, builds
    assert builds[False] == calls, (builds, calls)
    assert results[True] == results[False], \
        "artifact cache must not change selections"
    return [row(
        "table2/artifact_cache", 0.0,
        f"builds_cached={builds[True]};builds_uncached={builds[False]};"
        f"candidate_rounds={calls};bit_identical=True")]


def _replica_sharding(n: int = 240, budget: int = 24,
                      n_push: int = 40, per_push: int = 8) -> list:
    """Sharding section: bit-identical sharded selection + async ingest
    throughput (both asserted)."""
    X, Y, EX, EY = make_pool(n=n)
    # -- selection equivalence: replicas=4 vs replicas=1 ------------------
    picks = {}
    for replicas in (1, 4):
        srv, key2y = make_server(X, Y, EX, EY, batch_size=32,
                                 replicas=replicas)
        warm_start(srv, key2y)
        picks[replicas] = {
            s: srv.query(budget=budget, strategy=s, rng_seed=7)["keys"]
            for s in ("lc", "kcg", "coreset", "badge")}
    assert picks[4] == picks[1], \
        "sharded selections must be bit-identical to replicas=1"
    out = [row("table2/sharded_selection", 0.0,
               f"replicas=4;strategies=lc+kcg+coreset+badge;"
               f"budget={budget};bit_identical=True")]

    # -- ingest throughput: async (queued, per-shard parallel) vs sync ----
    # the synchronous loop pays the emulated S3-fetch RTT once per push;
    # the ingest queue folds queued pushes into large drained batches, so
    # the RTT is paid once per batch-chunk and overlaps shard embedding
    PX, _, _, _ = make_pool(seed=7, n=n_push * per_push)
    chunks = [list(PX[i * per_push:(i + 1) * per_push])
              for i in range(n_push)]
    times = {}
    for mode in ("sync", "async"):
        srv = ALServer(ALServiceConfig(batch_size=32, replicas=4),
                       fetch_latency_s=0.05)
        t0 = time.perf_counter()
        if mode == "sync":
            for ch in chunks:
                srv.push_data(ch)
        else:
            tickets = [srv.push_data(ch, asynchronous=True)
                       for ch in chunks]
            srv.flush()
            assert all(t.done() for t in tickets)
        times[mode] = time.perf_counter() - t0
        st = srv.stats()
        assert st["pool"] == n_push * per_push, st
        if mode == "async":
            # one version bump per row-appending drained batch, never per
            # push (all chunks here are distinct and no ingest fails, so
            # the bound is tight)
            assert 1 <= st["pool_version"] <= st["ingest_batches"] < n_push
    total = n_push * per_push
    speed = times["sync"] / times["async"]
    assert speed >= 1.3, (
        f"async ingest {speed:.2f}x sync at 4 shards (need >=1.3x); "
        f"sync={times['sync']:.2f}s async={times['async']:.2f}s")
    return out + [
        row("table2/ingest_sync", times["sync"] / n_push * 1e6,
            f"pushes={n_push};throughput_img_s={total / times['sync']:.1f}"),
        row("table2/ingest_async", times["async"] / n_push * 1e6,
            f"pushes={n_push};throughput_img_s="
            f"{total / times['async']:.1f}"),
        row("table2/ingest_speedup", 0.0,
            f"async_over_sync={speed:.2f}x;replicas=4;asserted_ge=1.3x"),
    ]


def _incremental_artifacts(n: int = 192, push_b: int = 3,
                           budget: int = 16) -> list:
    """6. incremental pool artifacts (all asserted, op-accounted): pushing
    ``push_b`` rows into a 4-shard pool embeds exactly ``push_b`` rows and
    rebuilds only the shards those rows hash to; ``train_and_eval``
    triggers zero embeds (head-only prob refresh over cached feats);
    ``label`` triggers zero artifact rebuilds; and every selection stays
    bit-identical to ``artifact_cache: false`` from-scratch builds at
    replicas 1 and 4.

    The timed row compares the artifact work of one small push on each
    engine, XLA-warmed by a first identical push: the delta refresh
    re-uses its chunk shapes, while the from-scratch rebuild re-gathers
    and re-forwards the whole pool at a never-seen-before pool size — a
    retrace cost the O(delta) path structurally avoids (informational;
    the asserted contract is the op counts, which are machine-free)."""
    from repro.core.selection import replica_of

    STRATS = ("lc", "kcg", "coreset", "badge")
    X, Y, EX, EY = make_pool(n=n + 2 * push_b)
    base_x, base_y = list(X[:n]), list(Y[:n])
    extra_x = list(X[n:])          # two B-row pushes: accounted, then timed
    picks = {}           # (replicas, cached) -> [stage selections]
    timings = {}         # (replicas, cached) -> query-after-push seconds
    acct = None          # op accounting from the cached replicas=4 run

    for replicas in (1, 4):
        for cached in (True, False):
            srv, key2y = make_server(base_x, base_y, EX, EY, batch_size=32,
                                     push=True, replicas=replicas,
                                     artifact_cache=cached)
            sess = srv.session()
            stages = []

            def queries(seed):
                return [srv.query(budget=budget, strategy=s,
                                  rng_seed=seed)["keys"] for s in STRATS]

            stages.append(queries(3))                  # cold full build
            warm_start(srv, key2y)                     # label 30 + retrain
            e_train = srv.embed_rows
            stages.append(queries(5))                  # probs-only refresh
            probs_embeds = srv.embed_rows - e_train
            # label-only step: deterministic pick, same on every server
            more = [k for k in sess._keys if k not in sess._labels][:10]
            srv.label(more, [key2y[k] for k in more])
            b_label = sess.artifact_builds
            stages.append(queries(6))                  # must be a pure hit
            label_rebuilds = sess.artifact_builds - b_label
            b_shard = [c.builds for c in sess._columns]
            e_push = srv.embed_rows
            new_keys = srv.push_data(extra_x[:push_b])  # the B-row delta
            push_embeds = srv.embed_rows - e_push
            sess._artifact_snapshot()                  # delta refresh
            rebuilt = {si for si, (a, b) in enumerate(
                zip([c.builds for c in sess._columns], b_shard)) if a > b}
            delta_builds = (sess.delta_builds
                            if cached else len(rebuilt))
            # time the artifact work of a SECOND small push, now that the
            # delta/build shapes are XLA-warm: a delta refresh (cached) vs
            # a from-scratch O(pool) rebuild (uncached) of the same change
            srv.push_data(extra_x[push_b:])
            t0 = time.perf_counter()
            sess._artifact_snapshot()
            timings[(replicas, cached)] = time.perf_counter() - t0
            stages.append(queries(7))                  # scores post-push pool
            picks[(replicas, cached)] = stages
            if replicas == 4 and cached:
                acct = {
                    "label_rebuilds": label_rebuilds,
                    "probs_embeds": probs_embeds,
                    "probs_refreshes": sess.probs_refreshes,
                    "push_embeds": push_embeds,
                    "touched": sorted({replica_of(k, 4) for k in new_keys}),
                    "rebuilt": sorted(rebuilt),
                    "delta_builds": delta_builds,
                }

    for replicas in (1, 4):
        assert picks[(replicas, True)] == picks[(replicas, False)], \
            f"incremental engine diverged from from-scratch at {replicas}"
    assert picks[(1, True)] == picks[(4, True)], \
        "sharded selections diverged from replicas=1"
    assert acct["label_rebuilds"] == 0, acct
    assert acct["probs_embeds"] == 0, acct
    assert acct["probs_refreshes"] == 4, acct         # every populated shard
    assert acct["push_embeds"] == push_b, acct
    assert acct["rebuilt"] == acct["touched"], acct
    # a 3-row push cannot touch all 4 shards: the untouched-shard cache
    # hit is exercised for real, not vacuously
    assert len(acct["touched"]) < 4, acct
    assert acct["delta_builds"] == len(acct["touched"]), acct
    speed = (timings[(4, False)] / timings[(4, True)]
             if timings[(4, True)] > 0 else float("inf"))
    return [
        row("table2/incremental_push", 0.0,
            f"push_rows={push_b};embed_rows={acct['push_embeds']};"
            f"touched_shards={len(acct['touched'])};"
            f"rebuilt_shards={len(acct['rebuilt'])};"
            f"delta_builds={acct['delta_builds']}"),
        row("table2/incremental_retrain", 0.0,
            f"embed_rows={acct['probs_embeds']};"
            f"probs_refreshes={acct['probs_refreshes']}"),
        row("table2/incremental_label", 0.0,
            f"artifact_rebuilds={acct['label_rebuilds']}"),
        row("table2/incremental_bit_identity", 0.0,
            f"replicas=1+4;strategies={'+'.join(STRATS)};stages=3;"
            f"bit_identical=True"),
        row("table2/incremental_refresh_after_push",
            timings[(4, True)] * 1e6,
            f"delta_refresh_s={timings[(4, True)]:.4f};"
            f"from_scratch_s={timings[(4, False)]:.4f};"
            f"speedup={speed:.2f}x"),
    ]


def _dupe_pool(n: int, clumps: int, d: int, seed: int = 11):
    """Redundancy-heavy vector pool: 97% of rows are near-duplicates inside
    ``clumps`` tight clusters, 3% spread wide. Returns (rows, clump_of)
    with clump_of = -1 for the spread rows; order is shuffled so clump
    membership never correlates with shard assignment or pool position."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(clumps, d)) * 6.0
    n_dupe = int(n * 0.97)
    assign = rng.integers(0, clumps, size=n_dupe)
    dup = centers[assign] + 0.03 * rng.normal(size=(n_dupe, d))
    spread = 8.0 * rng.normal(size=(n - n_dupe, d))
    x = np.concatenate([dup, spread]).astype(np.float32)
    clump_of = np.concatenate([assign, np.full(n - n_dupe, -1)])
    perm = rng.permutation(n)
    return x[perm], clump_of[perm]


def _prefilter_gated(n: int = 12288, clumps: int = 48, d: int = 192) -> list:
    """7. centroid-gated prefilter (all selection comparisons asserted)."""
    from repro.kernels.pairwise import ops
    from repro.service.backends import MLPBackend

    X, clump_of = _dupe_pool(n, clumps, d)
    # 4 labeled members per clump: in steady-state AL the labeled set
    # covers the dense mass, which is exactly what lets the Core-Set warm
    # start prune dense clusters via the triangle bound before reading a
    # single row of them
    lab = [int(m) for c in range(clumps)
           for m in np.nonzero(clump_of == c)[0][:4]]

    def drive(prefilter: bool, slack: float = 0.05):
        cfg = dict(batch_size=64, replicas=3)
        if prefilter:
            cfg.update(prefilter=True, prefilter_slack=slack,
                       prefilter_clusters=128, prefilter_min_rows=64)
        srv = ALServer(ALServiceConfig(**cfg),
                       backend=MLPBackend(in_dim=d, feat_dim=32))
        keys = srv.push_data(list(X))
        srv.label([keys[i] for i in lab],
                  [i % 4 for i in range(len(lab))])
        srv.train_and_eval()
        # warm queries: artifact columns, centroid summaries, jit caches
        # AND the persisted k-center min-dist state build OUTSIDE the
        # tracked window — the summary's one-off k-means and the state's
        # one-off warm fold are amortized across every later query, so
        # neither is billed to the pass it serves
        srv.query(budget=1, strategy="lc")
        srv.query(budget=1, strategy="coreset")
        picks, rows = {}, {}
        for strat, budget in (("lc", 16), ("es", 16),
                              ("coreset", 48), ("kcg", 48)):
            ops.reset_op_stats()
            with ops.track_ops():
                picks[strat] = srv.query(budget=budget, strategy=strat,
                                         rng_seed=7)["keys"]
            rows[strat] = ops.op_stats()["pool_rows"]
        srv.session().close()
        return picks, rows

    base_picks, base_rows = drive(False)
    gate_picks, gate_rows = drive(True)
    loose_picks, _ = drive(True, slack=1e9)   # bound never prunes
    assert gate_picks == base_picks, \
        "gated selections must be bit-identical to the full-scan oracle"
    assert loose_picks == base_picks, \
        "degenerate slack must reproduce the full scan bit-for-bit"
    ratio = {s: base_rows[s] / max(gate_rows[s], 1) for s in base_rows}
    for strat in ("lc", "coreset"):
        assert ratio[strat] >= 10.0, (
            f"{strat}: gated pass touched {gate_rows[strat]} pool rows vs "
            f"{base_rows[strat]} full-scan (ratio {ratio[strat]:.1f}x, "
            f"need >=10x)")
    return [
        row("table2/prefilter", 0.0,
            f"pool={n};replicas=3;clusters=128;"
            f"lc_rows_ratio={ratio['lc']:.1f}x;"
            f"es_rows_ratio={ratio['es']:.1f}x;"
            f"coreset_rows_ratio={ratio['coreset']:.1f}x;"
            f"bit_identical=True;loose_slack_identical=True;"
            f"asserted_ge=10x"),
        row("table2/prefilter_kcg_unwarmed", 0.0,
            f"kcg_rows_ratio={ratio['kcg']:.2f}x;asserted=False;"
            f"note=uncovered-clusters-stay-competitive"),
    ]


def _shard_spill(n: int = 240, d: int = 192) -> list:
    """8. mmap shard spill: RAM-resident vs spilled columns, bit-identical
    selections across an interleaved op script at replicas 1 and 3."""
    from repro.service.backends import MLPBackend

    rng = np.random.default_rng(23)
    X = rng.normal(size=(n, d)).astype(np.float32)
    STRATS = ("lc", "kcg", "coreset", "badge")
    spilled = {"events": 0, "bytes": 0}
    picks = {}
    for replicas in (1, 3):
        for ram in (0, 2048):          # 0 = unlimited; 2048 B forces spill
            srv = ALServer(
                ALServiceConfig(batch_size=32, replicas=replicas,
                                shard_ram_bytes=ram),
                backend=MLPBackend(in_dim=d, feat_dim=32))
            sess = srv.session()
            stages = []
            keys = srv.push_data(list(X[:n // 2]))
            stages.append([srv.query(budget=8, strategy=s,
                                     rng_seed=3)["keys"] for s in STRATS])
            srv.label(keys[:24], [i % 4 for i in range(24)])
            srv.train_and_eval()
            stages.append([srv.query(budget=8, strategy=s,
                                     rng_seed=5)["keys"] for s in STRATS])
            srv.push_data(list(X[n // 2:]))
            stages.append([srv.query(budget=8, strategy=s,
                                     rng_seed=7)["keys"] for s in STRATS])
            picks[(replicas, ram)] = stages
            if ram:
                art = srv.stats()["artifacts"]
                assert art["spill_events"] > 0, \
                    "spill budget was set but no buffer ever spilled"
                spilled["events"] += art["spill_events"]
                spilled["bytes"] += art["spilled_bytes"]
            sess.close()
        assert picks[(replicas, 2048)] == picks[(replicas, 0)], (
            f"mmap-spilled shards diverged from RAM-resident at "
            f"replicas={replicas}")
    return [row(
        "table2/shard_spill", 0.0,
        f"replicas=1+3;strategies={'+'.join(STRATS)};stages=3;"
        f"spill_events={spilled['events']};"
        f"spilled_bytes={spilled['bytes']};bit_identical=True")]


def _standing_query(n: int = 4096, d: int = 192, budget: int = 32,
                    n_deltas: int = 6, delta_rows: int = 64) -> list:
    """9. standing queries: O(delta) streamed emits, asserted and
    op-accounted.

    Near-duplicate deltas (tiny perturbations of labeled rows — the
    steady-state stream of a deployed collector re-observing known
    regimes) can never displace a recorded per-slot winner, so every emit
    must ride the replay engine: extend the persisted min-dist state over
    the delta rows, fold the stored centers over JUST those rows, compare
    against the recorded winner scores. Emits are driven by sync pushes +
    polls on this thread because ``ops.track_ops`` is process-global.
    """
    from repro.kernels.pairwise import ops
    from repro.service.backends import MLPBackend

    rng = np.random.default_rng(29)
    X = rng.normal(size=(n, d)).astype(np.float32)
    n_lab = 96

    def build(**cfg):
        srv = ALServer(ALServiceConfig(batch_size=64, replicas=3, **cfg),
                       backend=MLPBackend(in_dim=d, feat_dim=32))
        keys = srv.push_data(list(X))
        srv.label(keys[:n_lab], [i % 4 for i in range(n_lab)])
        srv.train_and_eval()
        return srv

    deltas = [[X[(j * delta_rows + i) % n_lab]
               + rng.normal(scale=1e-4, size=(d,)).astype(np.float32)
               for i in range(delta_rows)] for j in range(n_deltas)]

    srv = build()
    reg = srv.standing_register(budget=budget, strategy="coreset",
                                rng_seed=7)
    seen, emit_rows, modes = reg["seq"], [], []
    for delta in deltas:
        srv.push_data(delta)               # sync: the POLL below emits
        ops.reset_op_stats()
        with ops.track_ops():
            r = srv.standing_poll(reg["query_id"], since=seen)
        emit_rows.append(ops.op_stats()["pool_rows"])
        modes += [e["mode"] for e in r["emits"]]
        seen = r["seq"]
    final = srv.standing_poll(reg["query_id"])
    sq_stats = srv.stats()["standing_queries"]
    assert modes == ["replay"] * n_deltas, modes
    assert sq_stats["replay_emits"] == n_deltas, sq_stats
    # O(delta) contract: an emit touches a small multiple of the delta
    # rows (state extend + budget-1 center folds), never the pool
    assert max(emit_rows) <= 3 * delta_rows * (budget + 1), emit_rows
    # reference cost: the same final emit with the replay engine OFF is a
    # full re-selection over the whole unlabeled pool
    ref = build(standing_replay=False)
    reg2 = ref.standing_register(budget=budget, strategy="coreset",
                                 rng_seed=7)
    for delta in deltas[:-1]:
        ref.push_data(delta)
        ref.standing_poll(reg2["query_id"])
    ref.push_data(deltas[-1])
    ops.reset_op_stats()
    with ops.track_ops():
        r2 = ref.standing_poll(reg2["query_id"])
    full_rows = ops.op_stats()["pool_rows"]
    assert r2["keys"] == final["keys"], \
        "replay emits diverged from the full-emit oracle"
    ratio = full_rows / max(max(emit_rows), 1)
    assert ratio >= 10.0, (
        f"replay emit touched {max(emit_rows)} pool rows vs {full_rows} "
        f"for the full emit (ratio {ratio:.1f}x, need >=10x)")
    # bit-identity oracle: one-shot over the final pool, fresh server,
    # every incremental engine off
    cold = ALServer(
        ALServiceConfig(batch_size=64, replicas=3, artifact_cache=False,
                        strategy_state_cache=False, standing_replay=False),
        backend=MLPBackend(in_dim=d, feat_dim=32))
    keys = cold.push_data(list(X))
    for delta in deltas:
        cold.push_data(delta)
    cold.label(keys[:n_lab], [i % 4 for i in range(n_lab)])
    cold.train_and_eval()
    one_shot = cold.query(budget=budget, strategy="coreset",
                          rng_seed=7)["keys"]
    assert final["keys"] == one_shot, \
        "streamed cumulative selection diverged from the one-shot query"
    return [row(
        "table2/standing_query", 0.0,
        f"pool={n};replicas=3;budget={budget};deltas={n_deltas}"
        f"x{delta_rows};replay_emits={sq_stats['replay_emits']};"
        f"rows_per_emit_max={max(emit_rows)};full_emit_rows={full_rows};"
        f"rows_ratio={ratio:.1f}x;streamed_equals_one_shot=True;"
        f"asserted_ge=10x")]


def _transformer_embed(n: int = 48, seq: int = 64,
                       budget: int = 8) -> list:
    """10. blockwise transformer embedding: bitwise chunk-invisibility,
    analytic memory flatness, and text-AL replica determinism.

    (a) the same text pool is embedded at block sizes {7 (non-dividing),
    16, 64 (=S), 96 (>S, the unchunked forward)} through one shared
    backend config — feature bytes must be identical;
    (b) ``activation_accounting`` at block=128/kv_chunk=128 must report
    the same per-block peak for S in {512, 2048, 8192} while the
    unchunked comparator (the (S,S) score matrix) grows quadratically;
    (c) a text-AL scenario (push, label, head train, coreset + lc
    queries, a standing query streaming a delta) must select
    bit-identically at replicas {1, 3}.
    """
    from repro.data.synthetic import text_pool
    from repro.models import blockwise
    from repro.service.backends import TransformerBackend

    toks, y = text_pool(n, num_classes=4, seq_len=seq, vocab=512, seed=13)

    # --- (a) chunked == unchunked bit-identity across block sizes
    blocks = (7, 16, seq, 96)
    feats, us = {}, 0.0
    for block in blocks:
        be = TransformerBackend(block_size=block, seq_len=seq,
                                kv_chunk=32)
        x = be.preprocess(toks)
        be.features(x[:1])                       # compile outside the timer
        t0 = time.perf_counter()
        feats[block] = be.features(x)
        us = max(us, (time.perf_counter() - t0) * 1e6)
    ref = feats[blocks[0]]
    for block, f in feats.items():
        assert np.array_equal(ref, f), \
            f"block={block} changed feature bytes vs block={blocks[0]}"

    # --- (b) analytic peak activation flat in sequence length
    cfg = blockwise.tiny_encoder_config()
    seq_lens = (512, 2048, 8192)
    accts = {S: blockwise.activation_accounting(cfg, 16, S, 128, 128)
             for S in seq_lens}
    peaks = [accts[S]["peak_activation_bytes"] for S in seq_lens]
    assert len(set(peaks)) == 1, f"peak activation not flat: {peaks}"
    unchunked = [accts[S]["unchunked_peak_bytes"] for S in seq_lens]
    assert unchunked[-1] > unchunked[0] * 100, unchunked
    growth = unchunked[-1] / unchunked[0]

    # --- (c) text-AL end to end, replicas {1,3} bit-identical
    picks = {}
    for reps in (1, 3):
        srv = ALServer(
            ALServiceConfig(model_name="transformer", batch_size=8,
                            replicas=reps, model_seq_len=seq,
                            model_block_size=16, strategy="coreset"))
        keys = srv.push_data(list(toks[:n - budget]))
        srv.label(keys[:12], [int(v) for v in y[:12]])
        srv.train_and_eval()
        reg = srv.standing_register(budget=budget, strategy="coreset",
                                    rng_seed=3)
        srv.push_data(list(toks[n - budget:]))
        streamed = srv.standing_poll(reg["query_id"])["keys"]
        one_shot = srv.query(budget=budget, strategy="coreset",
                             rng_seed=3)["keys"]
        assert streamed == one_shot, \
            f"replicas={reps}: streamed selection diverged from one-shot"
        picks[reps] = {s: srv.query(budget, s)["keys"]
                       for s in ("coreset", "lc")}
    assert picks[1] == picks[3], \
        "text-AL selections differ across replica counts"

    return [row(
        "table2/transformer_embed", us,
        f"pool={n};seq={seq};blocks={'+'.join(map(str, blocks))};"
        f"bit_identical=True;acct_block=128;"
        f"seq_lens={'+'.join(map(str, seq_lens))};"
        f"peak_act_bytes={'+'.join(map(str, peaks))};peak_act_flat=True;"
        f"unchunked_peak_bytes={'+'.join(map(str, unchunked))};"
        f"unchunked_growth={growth:.0f}x;replicas=1+3;"
        f"strategies=coreset+lc;replicas_identical=True;"
        f"streamed_equals_one_shot=True")]


def run() -> list:
    out = _pipeline_vs_serial()
    out += _concurrent_clients()
    out += _parallel_pshea()
    out += _artifact_cache_matrix()
    out += _replica_sharding()
    out += _incremental_artifacts()
    out += _prefilter_gated()
    out += _shard_spill()
    out += _standing_query()
    out += _transformer_embed()
    return out
