"""Paper Fig. 4a — one-round accuracy per AL strategy, with the paper's
lower bound (random) and upper bound (train on the full pool)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_pool, make_server, row, warm_start

STRATEGIES = ["random", "lc", "mc", "rc", "es", "kcg", "coreset", "dbal"]


SEEDS = (0, 7, 13)


def run() -> list:
    out = []
    init_accs = []
    accs = {s: [] for s in STRATEGIES}
    for seed in SEEDS:
        X, Y, EX, EY = make_pool(seed=seed)
        for strategy in STRATEGIES:
            srv, key2y = make_server(X, Y, EX, EY)
            init_accs.append(warm_start(srv, key2y, seed=seed + 99))
            res = srv.query(budget=100, strategy=strategy, rng_seed=seed)
            srv.label(res["keys"], [key2y[k] for k in res["keys"]])
            accs[strategy].append(srv.train_and_eval())
    for strategy in STRATEGIES:
        a = np.asarray(accs[strategy])
        out.append(row(f"fig4a/{strategy}", 0.0,
                       f"top1_acc={a.mean():.3f}+-{a.std():.3f}"))
    out.append(row("fig4a/initial_model", 0.0,
                   f"top1_acc={np.mean(init_accs):.3f}"))
    # upper bound: label everything (first seed)
    X, Y, EX, EY = make_pool(seed=SEEDS[0])
    srv, key2y = make_server(X, Y, EX, EY)
    all_keys = list(key2y)
    srv.label(all_keys, [key2y[k] for k in all_keys])
    acc = srv.train_and_eval()
    out.append(row("fig4a/full_data_upper_bound", 0.0, f"top1_acc={acc:.3f}"))
    return out
