"""Open-loop traffic harness: offered-load latency curve + failure drills.

Unlike the paired A/B sections of the table2 benchmark, this is an HONEST
heavy-traffic harness: a seeded open-loop generator (Poisson arrivals —
ops fire at their scheduled instants whether or not earlier ops finished,
so queueing delay counts against latency) drives a multi-tenant mix of
push / label / query / standing-poll against a replica-sharded server and
reports per-op p50/p99 latency plus achieved throughput AS A CURVE over
offered load, with the saturation point called out.

Four drills ride the same harness, asserted in-process and re-asserted
by CI from the uploaded JSON (scripts/assert_traffic.py):

  * graceful degradation — a deterministic op sequence runs on twin
    servers, one with shard workers killed mid-round (embed AND propose,
    via ``PhaseFailureInjector``); every query selection must stay
    BIT-IDENTICAL to the clean twin (kill -> detect -> reset shard ->
    re-embed from raw + content keys -> bounded retry), with worker
    restarts actually observed and p99 latency bounded vs the clean run;
  * kill-during-ingest — async pushes with a worker killed mid-drain must
    lose ZERO rows (retries re-run the idempotent content-addressed
    pipeline before rows append) — run UNDER the bounded-ingest cap;
  * overload — offered load >= 3x the measured saturation against the TCP
    server with admission control + a capped shed-policy ingest queue:
    queue memory stays bounded (ingest bytes high-water <= cap, scheduler
    inflight high-water <= bound), admitted-op p99 stays inside the
    envelope, per-tenant admitted throughput is fair (Jain >= JAIN_MIN),
    every shed op carries a positive retry_after_s, and zero acked rows
    are lost;
  * admission twin — the same deterministic serial sequence over TCP with
    admission OFF vs ON (tight bucket + client bounded retry): sheds and
    retries actually happen, yet selections stay BIT-IDENTICAL.

  PYTHONPATH=src python benchmarks/traffic.py --json BENCH_traffic.json --smoke
"""
from __future__ import annotations

import argparse
import concurrent.futures as cf
import json
import sys
import threading
import time

import numpy as np

from benchmarks.common import row
from repro.distributed.worker import PhaseFailureInjector
from repro.service.client import ALClient, serve_tcp
from repro.service.config import ALServiceConfig
from repro.service.errors import ServerOverloaded
from repro.service.server import ALServer

# p99 under injected worker death must stay within this factor of the
# clean run (the recovery path is a bounded rebuild, not a meltdown);
# scripts/assert_traffic.py re-asserts the same bound from the JSON
P99_DEGRADATION_BOUND = 50.0
# overload drill envelope: admitted ops (the ones admission let through)
# must finish within this p99 even at 3x saturation offered — admission
# keeps the dispatch queue short, so latency stays flat while excess
# load is shed with retry_after_s instead of queueing without bound
OVERLOAD_P99_BOUND_MS = 2000.0
# Jain's fairness index floor on per-tenant admitted throughput
JAIN_MIN = 0.9
# bounded-ingest cap for the overload drill (bytes outstanding per
# session; one 8x8x3 float32 row is 768B)
OVERLOAD_INGEST_CAP_BYTES = 64 << 10

OP_MIX = [("push", 0.45), ("label", 0.20), ("query", 0.25),
          ("poll", 0.10)]


def _rows(n, seed, shape=(8, 8, 3)):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n,) + shape).astype(np.float32)


def _make_server(replicas=2, injector=None, **cfg_kw):
    cfg = ALServiceConfig(replicas=replicas, batch_size=16,
                          worker_backoff_s=0.0, **cfg_kw)
    return ALServer(config=cfg, failure_injector=injector)


def _warm_tenant(srv, sid, seed, n=48):
    X = _rows(n, seed)
    keys = srv.push_data(list(X), session=sid)
    labels = [int(i % 2) for i in range(8)]
    srv.label(keys[:8], labels, session=sid)
    srv.train_and_eval(session=sid)
    qid = srv.standing_register(3, strategy="coreset",
                                session=sid)["query_id"]
    return keys, qid


def _schedule(n_ops, offered, tenants, seed):
    """Seeded open-loop schedule: exponential inter-arrivals at ``offered``
    ops/s, op type from the tenant mix, round-robin-free tenant draw."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / offered, size=n_ops)
    arrivals = np.cumsum(gaps)
    ops = rng.choice([op for op, _ in OP_MIX], size=n_ops,
                     p=[w for _, w in OP_MIX])
    ten = rng.integers(0, tenants, size=n_ops)
    return list(zip(arrivals.tolist(), ops.tolist(), ten.tolist()))


def _run_open_loop(srv, sids, warm, offered, n_ops, seed):
    """Fire the schedule open-loop; returns {op: [latency_s, ...]} and the
    wall seconds the burst took. Latency is completion minus SCHEDULED
    arrival — a stalled server pays for its queue."""
    sched = _schedule(n_ops, offered, len(sids), seed)
    fresh = _rows(n_ops, seed + 1)
    lat: dict = {op: [] for op, _ in OP_MIX}

    def execute(op, t, i, t_sched, t0):
        sid = sids[t]
        keys, qid = warm[t]
        rng = np.random.default_rng(seed + 7 * i)
        if op == "push":
            srv.push_data([fresh[i]], asynchronous=True, session=sid)
        elif op == "label":
            k = keys[int(rng.integers(0, len(keys)))]
            srv.label([k], [int(rng.integers(0, 2))], session=sid)
        elif op == "query":
            srv.query(4, strategy="mc", rng_seed=i, session=sid)
        else:
            srv.standing_poll(qid, session=sid)
        lat[op].append(time.perf_counter() - (t0 + t_sched))

    t0 = time.perf_counter()
    with cf.ThreadPoolExecutor(max_workers=32) as pool:
        futs = []
        for i, (t_arr, op, t) in enumerate(sched):
            now = time.perf_counter() - t0
            if t_arr > now:
                time.sleep(t_arr - now)
            futs.append(pool.submit(execute, op, t, i, t_arr, t0))
        for f in futs:
            f.result()
    for sid in sids:
        srv.flush(session=sid)       # ingest barrier: nothing in flight
    return lat, time.perf_counter() - t0


def _pcts(vals):
    if not vals:
        return 0.0, 0.0
    a = np.asarray(vals) * 1e3      # ms
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def _load_curve(loads, n_ops, tenants, seed):
    """One offered-load level per row: the p50/p99-vs-load CURVE the
    paired-ratio benchmarks cannot show, plus the saturation point."""
    out = []
    achieved = []
    for offered in loads:
        srv = _make_server(replicas=2)
        sids = [srv.create_session(f"t{i}") for i in range(tenants)]
        warm = [_warm_tenant(srv, sid, seed + 11 * i)
                for i, sid in enumerate(sids)]
        lat, wall = _run_open_loop(srv, sids, warm, offered, n_ops, seed)
        done = sum(len(v) for v in lat.values())
        assert done == n_ops, f"open loop dropped ops: {done}/{n_ops}"
        thr = done / wall
        achieved.append(thr)
        parts = [f"offered={offered:g}", f"achieved={thr:.1f}"]
        for op, _ in OP_MIX:
            p50, p99 = _pcts(lat[op])
            parts += [f"p50_{op}_ms={p50:.2f}", f"p99_{op}_ms={p99:.2f}"]
        mean_ms = 1e3 * float(np.mean([v for vs in lat.values()
                                       for v in vs]))
        out.append(row(f"traffic/load_{offered:g}", mean_ms * 1e3,
                       ";".join(parts)))
    assert len(loads) >= 2, "a curve needs >= 2 offered-load levels"
    sat = max(achieved)
    out.append(row(
        "traffic/saturation", 0.0,
        f"throughput_ops_s={sat:.1f};levels={len(loads)};"
        f"loads={'/'.join(f'{ld:g}' for ld in loads)}"))
    return out, sat


def _deterministic_ops(srv, sid, keys, seed, n_ops=18):
    """A fixed op sequence (sync pushes so both twins see identical pool
    states); returns (query selections, query latencies)."""
    fresh = _rows(n_ops, seed + 2)
    sels, qlat = [], []
    for i in range(n_ops):
        kind = i % 3
        if kind == 0:
            srv.push_data([fresh[i]], session=sid)
        elif kind == 1:
            srv.label([keys[i % len(keys)]], [i % 2], session=sid)
        else:
            t0 = time.perf_counter()
            res = srv.query(4, strategy="coreset", rng_seed=i, session=sid)
            qlat.append(time.perf_counter() - t0)
            sels.append(res["keys"])
    return sels, qlat


def _degradation(seed):
    """Twin deterministic runs; the killed twin must select identically."""
    runs = {}
    # the throwaway "warm" pass eats every process-wide jit compile the
    # sequence triggers; without it whichever timed twin runs FIRST pays
    # the compiles and the p99 ratio measures xla, not the recovery path
    for mode in ("warm", "clean", "killed"):
        srv = _make_server(replicas=3)
        sid = srv.create_session("t0")
        keys, _ = _warm_tenant(srv, sid, seed)
        if mode == "killed":
            # arm AFTER warmup so the kills land mid-workload: the next
            # embed round and the next propose round each lose a worker
            srv.shard_runtime().injector = PhaseFailureInjector(
                {"embed": [0], "propose": [0]})
        runs[mode] = (_deterministic_ops(srv, sid, keys, seed),
                      srv.stats(session=sid))
    (sel_w, _), _ = runs.pop("warm")
    (sel_c, lat_c), _ = runs["clean"]
    (sel_k, lat_k), st_k = runs["killed"]
    identical = sel_c == sel_k
    assert sel_w == sel_c, "deterministic sequence is not repeatable"
    p99_c = float(np.percentile(np.asarray(lat_c) * 1e3, 99))
    p99_k = float(np.percentile(np.asarray(lat_k) * 1e3, 99))
    ratio = p99_k / max(p99_c, 1e-9)
    recoveries = st_k["worker_recoveries"]
    restarts = st_k["workers"]["restarts"]
    assert identical, "killed-worker run diverged from the clean run"
    assert recoveries >= 1 and restarts >= 2, (
        f"kills did not exercise recovery (recoveries={recoveries}, "
        f"restarts={restarts})")
    assert ratio <= P99_DEGRADATION_BOUND, (
        f"p99 degradation {ratio:.1f}x exceeds "
        f"{P99_DEGRADATION_BOUND:.0f}x")
    return [row(
        "traffic/degradation", p99_k * 1e3,
        f"killed_equals_clean={identical};p99_clean_ms={p99_c:.2f};"
        f"p99_killed_ms={p99_k:.2f};p99_ratio={ratio:.2f}x;"
        f"recoveries={recoveries};restarts={restarts}")]


def _ingest_kill(seed, n_push=40, cap_rows=8):
    """Async pushes with a worker killed mid-drain AND the bounded-ingest
    cap active (block policy): zero lost rows, cap held throughout."""
    srv = _make_server(replicas=2, ingest_max_rows=cap_rows,
                       ingest_policy="block")
    sid = srv.create_session("t0")
    srv.shard_runtime().injector = PhaseFailureInjector({"ingest": [0]})
    X = _rows(n_push, seed + 3)
    tickets = [srv.push_data([x], asynchronous=True, session=sid)
               for x in X]
    srv.flush(session=sid)
    uniq = {k for t in tickets for k in t.keys}
    st = srv.stats(session=sid)
    lost = len(uniq) - st["pool"]
    restarts = st["workers"]["restarts"]
    rows_hw = st["ingest"]["rows_hw"]
    assert lost == 0, f"kill during ingest drain lost {lost} rows"
    assert restarts >= 1, "ingest kill never fired"
    assert rows_hw <= cap_rows, (
        f"ingest cap breached under kill: {rows_hw} > {cap_rows}")
    return [row("traffic/ingest_kill", 0.0,
                f"pushed={len(uniq)};pool={st['pool']};lost_rows={lost};"
                f"restarts={restarts};rows_hw={rows_hw};"
                f"cap_rows={cap_rows}")]


def _jain(xs):
    xs = [float(x) for x in xs]
    denom = len(xs) * sum(x * x for x in xs)
    return (sum(xs) ** 2 / denom) if denom else 0.0


def _overload(seed, sat, tenants, n_ops, clients_per_tenant=4):
    """Offered load >= 3x saturation against the TCP server with the full
    overload stack on: admission (per-tenant buckets + inflight bound) and
    a capped shed-policy ingest queue. Asserts the acceptance criteria
    in-process; scripts/assert_traffic.py re-asserts them from the JSON."""
    offered = 3.0 * max(sat, 1.0)
    rate = max(sat / tenants, 4.0)          # binding per-tenant bucket
    max_inflight = 16
    srv = _make_server(replicas=2, admission=True,
                       admission_max_inflight=max_inflight,
                       admission_tenant_rate=rate,
                       admission_tenant_burst=4.0,
                       ingest_max_bytes=OVERLOAD_INGEST_CAP_BYTES,
                       ingest_policy="shed")
    rpc = serve_tcp(srv)
    sids = [srv.create_session(f"t{i}") for i in range(tenants)]
    warm = [_warm_tenant(srv, sid, seed + 11 * i)
            for i, sid in enumerate(sids)]
    # retries=0: a shed surfaces as ServerOverloaded at the call site, so
    # the drill can observe every rejection's retry_after_s directly
    clis = [[ALClient(url=f"127.0.0.1:{rpc.port}", session=sid, retries=0)
             for _ in range(clients_per_tenant)] for sid in sids]
    sched = _schedule(n_ops, offered, tenants, seed + 17)
    fresh = _rows(n_ops, seed + 19)
    lock = threading.Lock()
    lat_admitted = []                        # completion - scheduled
    admitted_by_tenant = [0] * tenants
    shed_retry_after = []                    # one entry per shed op
    acked_keys = [set() for _ in range(tenants)]

    def execute(op, t, i, t_sched, t0):
        cli = clis[t][i % clients_per_tenant]
        keys, qid = warm[t]
        try:
            if op == "push":
                ticket = cli.push_data([fresh[i]], asynchronous=True)
                ticket.result(timeout=60)    # server acked the enqueue
                with lock:
                    acked_keys[t].update(ticket.keys)
            elif op == "label":
                k = keys[i % len(keys)]
                cli.label([k], [i % 2])
            elif op == "query":
                cli.query(4, strategy="mc", rng_seed=i)
            else:
                cli.standing_poll(qid)
        except ServerOverloaded as e:
            with lock:
                shed_retry_after.append(float(e.retry_after_s))
            return
        with lock:
            lat_admitted.append(time.perf_counter() - (t0 + t_sched))
            admitted_by_tenant[t] += 1

    t0 = time.perf_counter()
    with cf.ThreadPoolExecutor(max_workers=32) as pool:
        futs = []
        for i, (t_arr, op, t) in enumerate(sched):
            now = time.perf_counter() - t0
            if t_arr > now:
                time.sleep(t_arr - now)
            futs.append(pool.submit(execute, op, t, i, t_arr, t0))
        for f in futs:
            f.result()
    wall = time.perf_counter() - t0
    # drain: flush is itself subject to admission — retry until admitted
    for t, sid in enumerate(sids):
        deadline = time.time() + 60
        while True:
            try:
                clis[t][0].flush()
                break
            except ServerOverloaded as e:
                assert time.time() < deadline, "drain flush starved"
                time.sleep(e.retry_after_s)
    # ---- acceptance criteria, asserted in-process ----
    sheds = len(shed_retry_after)
    assert sheds > 0, "overload drill never shed (not actually overloaded)"
    retry_ok = all(r > 0 for r in shed_retry_after)
    assert retry_ok, "a shed op came back without a usable retry_after_s"
    jain = _jain(admitted_by_tenant)
    assert jain >= JAIN_MIN, (
        f"admitted throughput unfair: Jain {jain:.3f} < {JAIN_MIN}"
        f" (per-tenant {admitted_by_tenant})")
    p99 = float(np.percentile(np.asarray(lat_admitted) * 1e3, 99))
    assert p99 <= OVERLOAD_P99_BOUND_MS, (
        f"admitted-op p99 {p99:.0f}ms outside the "
        f"{OVERLOAD_P99_BOUND_MS:.0f}ms envelope")
    adm = rpc.stats()
    assert adm["inflight_hw"] <= max_inflight, (
        f"inflight high-water {adm['inflight_hw']} breached the bound")
    bytes_hw = 0
    lost = 0
    for t, sid in enumerate(sids):
        st = srv.stats(session=sid)
        bytes_hw = max(bytes_hw, st["ingest"]["bytes_hw"])
        pool_keys = set(srv.session(sid)._keys)
        lost += len(acked_keys[t] - pool_keys)
    assert bytes_hw <= OVERLOAD_INGEST_CAP_BYTES, (
        f"ingest queue memory unbounded: {bytes_hw} > cap")
    assert lost == 0, f"overload lost {lost} acked rows"
    for row_clients in clis:
        for cli in row_clients:
            cli.close()
    rpc.stop()
    return [row(
        "traffic/overload", p99 * 1e3,
        f"offered={offered:.1f};sat={sat:.1f};wall_s={wall:.2f};"
        f"admitted={sum(admitted_by_tenant)};sheds={sheds};"
        f"retry_after_all_positive={retry_ok};jain={jain:.4f};"
        f"jain_min={JAIN_MIN};p99_admitted_ms={p99:.2f};"
        f"p99_bound_ms={OVERLOAD_P99_BOUND_MS:.0f};"
        f"inflight_hw={adm['inflight_hw']};max_inflight={max_inflight};"
        f"ingest_bytes_hw={bytes_hw};"
        f"ingest_cap_bytes={OVERLOAD_INGEST_CAP_BYTES};"
        f"acked_rows={sum(len(s) for s in acked_keys)};lost_rows={lost};"
        f"expired={adm['expired']}")]


def _client_ops(cli, keys, seed, n_ops=12):
    """The deterministic serial sequence of _deterministic_ops, driven
    through an ALClient (sync pushes -> identical pool states)."""
    fresh = _rows(n_ops, seed + 2)
    sels = []
    for i in range(n_ops):
        kind = i % 3
        if kind == 0:
            cli.push_data([fresh[i]])
        elif kind == 1:
            cli.label([keys[i % len(keys)]], [i % 2])
        else:
            sels.append(cli.query(4, strategy="coreset",
                                  rng_seed=i)["keys"])
    return sels


def _admission_twin(seed):
    """Deterministic twin over TCP: admission OFF vs ON (tight per-tenant
    bucket, so real sheds happen and the client's bounded retry does real
    work) — selections must stay bit-identical. Admission decides WHEN an
    op runs, never WHAT it computes."""
    results = {}
    for mode in ("off", "on"):
        kw = {} if mode == "off" else dict(
            admission=True, admission_max_inflight=16,
            admission_tenant_rate=2.0, admission_tenant_burst=1.0)
        srv = _make_server(replicas=2, **kw)
        sid = srv.create_session("t0")
        keys, _ = _warm_tenant(srv, sid, seed)
        rpc = serve_tcp(srv)
        cli = ALClient(url=f"127.0.0.1:{rpc.port}", session=sid,
                       retries=10, retry_jitter_s=0.01)
        sels = _client_ops(cli, keys, seed)
        stats = rpc.stats()
        cli.close()
        rpc.stop()
        results[mode] = (sels, stats)
    sels_off, _ = results["off"]
    sels_on, st_on = results["on"]
    identical = sels_off == sels_on
    sheds, retries = st_on["shed"], st_on["retries"]
    assert identical, "admission control changed the selections"
    assert sheds >= 1, "admission-on twin never shed (bucket not binding)"
    assert retries >= 1, "client retry layer never exercised"
    return [row(
        "traffic/admission_twin", 0.0,
        f"identical={identical};sheds={sheds};retries={retries};"
        f"queries={len(sels_on)}")]


def run(loads=(10.0, 30.0, 60.0), n_ops=150, tenants=3, seed=0):
    curve_rows, sat = _load_curve(list(loads), n_ops, tenants, seed)
    yield from curve_rows
    yield from _degradation(seed)
    yield from _ingest_kill(seed)
    yield from _overload(seed, sat, tenants, n_ops)
    yield from _admission_twin(seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--loads", default=None,
                    help="comma-separated offered loads (ops/s)")
    ap.add_argument("--ops", type=int, default=None,
                    help="ops per load level")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI sizing (2 levels, fewer ops)")
    args = ap.parse_args()
    loads = ([float(x) for x in args.loads.split(",")] if args.loads
             else [5.0, 15.0] if args.smoke else [10.0, 30.0, 60.0])
    n_ops = args.ops if args.ops else (60 if args.smoke else 150)
    tenants = 2 if args.smoke and args.tenants == 3 else args.tenants

    print("name,us_per_call,derived")
    records, failures = [], 0

    def emit(line):
        print(line, flush=True)
        name, us, derived = line.split(",", 2)
        records.append({"name": name, "us_per_call": float(us),
                        "derived": derived})

    t0 = time.perf_counter()
    try:
        for line in run(loads=loads, n_ops=n_ops, tenants=tenants,
                        seed=args.seed):
            emit(line)
    except Exception as e:   # match benchmarks.run: record, don't crash
        failures += 1
        emit(f"traffic/ERROR,0.0,{type(e).__name__}: {e}")
    emit(f"traffic/_wall,{(time.perf_counter() - t0) * 1e6:.0f},done")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "rows": records,
                       "failures": failures}, f, indent=1)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
