"""Open-loop traffic harness: offered-load latency curve + failure drills.

Unlike the paired A/B sections of the table2 benchmark, this is an HONEST
heavy-traffic harness: a seeded open-loop generator (Poisson arrivals —
ops fire at their scheduled instants whether or not earlier ops finished,
so queueing delay counts against latency) drives a multi-tenant mix of
push / label / query / standing-poll against a replica-sharded server and
reports per-op p50/p99 latency plus achieved throughput AS A CURVE over
offered load, with the saturation point called out.

Two failure drills ride the same harness, asserted in-process and
re-asserted by CI from the uploaded JSON (scripts/assert_traffic.py):

  * graceful degradation — a deterministic op sequence runs on twin
    servers, one with shard workers killed mid-round (embed AND propose,
    via ``PhaseFailureInjector``); every query selection must stay
    BIT-IDENTICAL to the clean twin (kill -> detect -> reset shard ->
    re-embed from raw + content keys -> bounded retry), with worker
    restarts actually observed and p99 latency bounded vs the clean run;
  * kill-during-ingest — async pushes with a worker killed mid-drain must
    lose ZERO rows (retries re-run the idempotent content-addressed
    pipeline before rows append).

  PYTHONPATH=src python benchmarks/traffic.py --json BENCH_traffic.json --smoke
"""
from __future__ import annotations

import argparse
import concurrent.futures as cf
import json
import sys
import time

import numpy as np

from benchmarks.common import row
from repro.distributed.worker import PhaseFailureInjector
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer

# p99 under injected worker death must stay within this factor of the
# clean run (the recovery path is a bounded rebuild, not a meltdown);
# scripts/assert_traffic.py re-asserts the same bound from the JSON
P99_DEGRADATION_BOUND = 50.0

OP_MIX = [("push", 0.45), ("label", 0.20), ("query", 0.25),
          ("poll", 0.10)]


def _rows(n, seed, shape=(8, 8, 3)):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n,) + shape).astype(np.float32)


def _make_server(replicas=2, injector=None, **cfg_kw):
    cfg = ALServiceConfig(replicas=replicas, batch_size=16,
                          worker_backoff_s=0.0, **cfg_kw)
    return ALServer(config=cfg, failure_injector=injector)


def _warm_tenant(srv, sid, seed, n=48):
    X = _rows(n, seed)
    keys = srv.push_data(list(X), session=sid)
    labels = [int(i % 2) for i in range(8)]
    srv.label(keys[:8], labels, session=sid)
    srv.train_and_eval(session=sid)
    qid = srv.standing_register(3, strategy="coreset",
                                session=sid)["query_id"]
    return keys, qid


def _schedule(n_ops, offered, tenants, seed):
    """Seeded open-loop schedule: exponential inter-arrivals at ``offered``
    ops/s, op type from the tenant mix, round-robin-free tenant draw."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / offered, size=n_ops)
    arrivals = np.cumsum(gaps)
    ops = rng.choice([op for op, _ in OP_MIX], size=n_ops,
                     p=[w for _, w in OP_MIX])
    ten = rng.integers(0, tenants, size=n_ops)
    return list(zip(arrivals.tolist(), ops.tolist(), ten.tolist()))


def _run_open_loop(srv, sids, warm, offered, n_ops, seed):
    """Fire the schedule open-loop; returns {op: [latency_s, ...]} and the
    wall seconds the burst took. Latency is completion minus SCHEDULED
    arrival — a stalled server pays for its queue."""
    sched = _schedule(n_ops, offered, len(sids), seed)
    fresh = _rows(n_ops, seed + 1)
    lat: dict = {op: [] for op, _ in OP_MIX}

    def execute(op, t, i, t_sched, t0):
        sid = sids[t]
        keys, qid = warm[t]
        rng = np.random.default_rng(seed + 7 * i)
        if op == "push":
            srv.push_data([fresh[i]], asynchronous=True, session=sid)
        elif op == "label":
            k = keys[int(rng.integers(0, len(keys)))]
            srv.label([k], [int(rng.integers(0, 2))], session=sid)
        elif op == "query":
            srv.query(4, strategy="mc", rng_seed=i, session=sid)
        else:
            srv.standing_poll(qid, session=sid)
        lat[op].append(time.perf_counter() - (t0 + t_sched))

    t0 = time.perf_counter()
    with cf.ThreadPoolExecutor(max_workers=32) as pool:
        futs = []
        for i, (t_arr, op, t) in enumerate(sched):
            now = time.perf_counter() - t0
            if t_arr > now:
                time.sleep(t_arr - now)
            futs.append(pool.submit(execute, op, t, i, t_arr, t0))
        for f in futs:
            f.result()
    for sid in sids:
        srv.flush(session=sid)       # ingest barrier: nothing in flight
    return lat, time.perf_counter() - t0


def _pcts(vals):
    if not vals:
        return 0.0, 0.0
    a = np.asarray(vals) * 1e3      # ms
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def _load_curve(loads, n_ops, tenants, seed):
    """One offered-load level per row: the p50/p99-vs-load CURVE the
    paired-ratio benchmarks cannot show, plus the saturation point."""
    out = []
    achieved = []
    for offered in loads:
        srv = _make_server(replicas=2)
        sids = [srv.create_session(f"t{i}") for i in range(tenants)]
        warm = [_warm_tenant(srv, sid, seed + 11 * i)
                for i, sid in enumerate(sids)]
        lat, wall = _run_open_loop(srv, sids, warm, offered, n_ops, seed)
        done = sum(len(v) for v in lat.values())
        assert done == n_ops, f"open loop dropped ops: {done}/{n_ops}"
        thr = done / wall
        achieved.append(thr)
        parts = [f"offered={offered:g}", f"achieved={thr:.1f}"]
        for op, _ in OP_MIX:
            p50, p99 = _pcts(lat[op])
            parts += [f"p50_{op}_ms={p50:.2f}", f"p99_{op}_ms={p99:.2f}"]
        mean_ms = 1e3 * float(np.mean([v for vs in lat.values()
                                       for v in vs]))
        out.append(row(f"traffic/load_{offered:g}", mean_ms * 1e3,
                       ";".join(parts)))
    assert len(loads) >= 2, "a curve needs >= 2 offered-load levels"
    sat = max(achieved)
    out.append(row(
        "traffic/saturation", 0.0,
        f"throughput_ops_s={sat:.1f};levels={len(loads)};"
        f"loads={'/'.join(f'{ld:g}' for ld in loads)}"))
    return out


def _deterministic_ops(srv, sid, keys, seed, n_ops=18):
    """A fixed op sequence (sync pushes so both twins see identical pool
    states); returns (query selections, query latencies)."""
    fresh = _rows(n_ops, seed + 2)
    sels, qlat = [], []
    for i in range(n_ops):
        kind = i % 3
        if kind == 0:
            srv.push_data([fresh[i]], session=sid)
        elif kind == 1:
            srv.label([keys[i % len(keys)]], [i % 2], session=sid)
        else:
            t0 = time.perf_counter()
            res = srv.query(4, strategy="coreset", rng_seed=i, session=sid)
            qlat.append(time.perf_counter() - t0)
            sels.append(res["keys"])
    return sels, qlat


def _degradation(seed):
    """Twin deterministic runs; the killed twin must select identically."""
    runs = {}
    # the throwaway "warm" pass eats every process-wide jit compile the
    # sequence triggers; without it whichever timed twin runs FIRST pays
    # the compiles and the p99 ratio measures xla, not the recovery path
    for mode in ("warm", "clean", "killed"):
        srv = _make_server(replicas=3)
        sid = srv.create_session("t0")
        keys, _ = _warm_tenant(srv, sid, seed)
        if mode == "killed":
            # arm AFTER warmup so the kills land mid-workload: the next
            # embed round and the next propose round each lose a worker
            srv.shard_runtime().injector = PhaseFailureInjector(
                {"embed": [0], "propose": [0]})
        runs[mode] = (_deterministic_ops(srv, sid, keys, seed),
                      srv.stats(session=sid))
    (sel_w, _), _ = runs.pop("warm")
    (sel_c, lat_c), _ = runs["clean"]
    (sel_k, lat_k), st_k = runs["killed"]
    identical = sel_c == sel_k
    assert sel_w == sel_c, "deterministic sequence is not repeatable"
    p99_c = float(np.percentile(np.asarray(lat_c) * 1e3, 99))
    p99_k = float(np.percentile(np.asarray(lat_k) * 1e3, 99))
    ratio = p99_k / max(p99_c, 1e-9)
    recoveries = st_k["worker_recoveries"]
    restarts = st_k["workers"]["restarts"]
    assert identical, "killed-worker run diverged from the clean run"
    assert recoveries >= 1 and restarts >= 2, (
        f"kills did not exercise recovery (recoveries={recoveries}, "
        f"restarts={restarts})")
    assert ratio <= P99_DEGRADATION_BOUND, (
        f"p99 degradation {ratio:.1f}x exceeds "
        f"{P99_DEGRADATION_BOUND:.0f}x")
    return [row(
        "traffic/degradation", p99_k * 1e3,
        f"killed_equals_clean={identical};p99_clean_ms={p99_c:.2f};"
        f"p99_killed_ms={p99_k:.2f};p99_ratio={ratio:.2f}x;"
        f"recoveries={recoveries};restarts={restarts}")]


def _ingest_kill(seed, n_push=40):
    """Async pushes with a worker killed mid-drain: zero lost rows."""
    srv = _make_server(replicas=2)
    sid = srv.create_session("t0")
    srv.shard_runtime().injector = PhaseFailureInjector({"ingest": [0]})
    X = _rows(n_push, seed + 3)
    tickets = [srv.push_data([x], asynchronous=True, session=sid)
               for x in X]
    srv.flush(session=sid)
    uniq = {k for t in tickets for k in t.keys}
    st = srv.stats(session=sid)
    lost = len(uniq) - st["pool"]
    restarts = st["workers"]["restarts"]
    assert lost == 0, f"kill during ingest drain lost {lost} rows"
    assert restarts >= 1, "ingest kill never fired"
    return [row("traffic/ingest_kill", 0.0,
                f"pushed={len(uniq)};pool={st['pool']};lost_rows={lost};"
                f"restarts={restarts}")]


def run(loads=(10.0, 30.0, 60.0), n_ops=150, tenants=3, seed=0):
    yield from _load_curve(list(loads), n_ops, tenants, seed)
    yield from _degradation(seed)
    yield from _ingest_kill(seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--loads", default=None,
                    help="comma-separated offered loads (ops/s)")
    ap.add_argument("--ops", type=int, default=None,
                    help="ops per load level")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI sizing (2 levels, fewer ops)")
    args = ap.parse_args()
    loads = ([float(x) for x in args.loads.split(",")] if args.loads
             else [5.0, 15.0] if args.smoke else [10.0, 30.0, 60.0])
    n_ops = args.ops if args.ops else (60 if args.smoke else 150)
    tenants = 2 if args.smoke and args.tenants == 3 else args.tenants

    print("name,us_per_call,derived")
    records, failures = [], 0

    def emit(line):
        print(line, flush=True)
        name, us, derived = line.split(",", 2)
        records.append({"name": name, "us_per_call": float(us),
                        "derived": derived})

    t0 = time.perf_counter()
    try:
        for line in run(loads=loads, n_ops=n_ops, tenants=tenants,
                        seed=args.seed):
            emit(line)
    except Exception as e:   # match benchmarks.run: record, don't crash
        failures += 1
        emit(f"traffic/ERROR,0.0,{type(e).__name__}: {e}")
    emit(f"traffic/_wall,{(time.perf_counter() - t0) * 1e6:.0f},done")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "rows": records,
                       "failures": failures}, f, indent=1)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
