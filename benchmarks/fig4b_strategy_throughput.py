"""Paper Fig. 4b — selection throughput (images/s through the query path)
per strategy; uncertainty strategies are near-free while Core-Set's greedy
min-dist loop is the heavy one, matching the paper's ordering.

``run_micro`` is the fused-vs-unfused greedy-selection microbenchmark: it
drives k-center rounds from Python under ``ops.track_ops()`` so the HBM-pass
accounting can verify the fused round costs exactly ONE (N, d) pool read per
selected center, and that fused/unfused pick identical centers."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import make_pool, make_server, row

STRATEGIES = ["random", "lc", "mc", "rc", "es", "kcg", "coreset", "dbal",
              "badge", "margin_density", "weighted_kcenter"]

MICRO_N, MICRO_D, MICRO_B = 4096, 64, 64


def _greedy_select(x, budget, round_fn, weights=None):
    """Seed with row 0, then ``budget - 1`` greedy rounds driven from
    Python (so op accounting sees every round)."""
    import jax.numpy as jnp
    from repro.kernels.pairwise import ops
    mind = ops.sq_dist_to_center(x, x[0]).at[0].set(-1.0)
    sel = [0]
    score = (mind if weights is None
             else ops.masked_weighted_score(mind, weights))
    nxt = jnp.argmax(score).astype(jnp.int32)
    for _ in range(budget - 1):
        sel.append(int(nxt))
        mind, nxt, _ = round_fn(x, mind, nxt)
    return sel


def run_micro() -> list:
    import jax.numpy as jnp
    from repro.kernels.pairwise import ops

    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(MICRO_N, MICRO_D)), jnp.float32)

    def fused(x, mind, i):
        return ops.greedy_round(x, mind, x[i][None, :], i[None])

    def unfused(x, mind, i):
        return ops.greedy_round_unfused(x, mind, x[i], i)

    out = []
    sels, timings, reads = {}, {}, {}
    for name, fn in (("fused", fused), ("unfused", unfused)):
        _greedy_select(x, MICRO_B, fn)            # warm up jits
        with ops.track_ops() as stats:
            t0 = time.perf_counter()
            sels[name] = _greedy_select(x, MICRO_B, fn)
            timings[name] = time.perf_counter() - t0
        reads[name] = dict(stats)

    import jax
    on_tpu = jax.devices()[0].platform == "tpu"
    match = sum(a == b for a, b in zip(sels["fused"], sels["unfused"]))
    if not on_tpu and sels["fused"] != sels["unfused"]:
        # CPU ref paths share the exact distance formula -> bit parity
        raise AssertionError("fused selection diverged from unfused: "
                             f"{sels['fused'][:8]} vs {sels['unfused'][:8]}")
    if match < 0.95 * MICRO_B:
        # TPU: kernel uses the matmul identity, the unfused baseline the
        # broadcast diff — allow ulp-level argmax flips, not divergence
        raise AssertionError(f"fused/unfused selections diverged: "
                             f"{match}/{MICRO_B} match")
    rpc = reads["fused"]["embedding_reads"] / MICRO_B
    if rpc != 1.0:
        raise AssertionError(
            "fused greedy round must read the pool exactly once per center, "
            f"got {rpc:.2f}")

    for name in ("fused", "unfused"):
        st = reads[name]
        out.append(row(
            f"fig4b_micro/greedy_{name}", timings[name] * 1e6 / MICRO_B,
            f"emb_reads_per_center={st['embedding_reads'] / MICRO_B:.2f}"
            f"|vector_streams={st['vector_streams']}"
            f"|hbm_mb={st['hbm_bytes'] / 1e6:.1f}"))
    # wall-clock on the CPU ref impl is dispatch-bound; the HBM-pass ledger
    # above is the tracked metric (the fusion win is the TPU Pallas path)
    out.append(row("fig4b_micro/speedup", 0.0,
                   f"wall_x={timings['unfused'] / timings['fused']:.2f}"
                   f"|hbm_mb_saved="
                   f"{(reads['unfused']['hbm_bytes'] - reads['fused']['hbm_bytes']) / 1e6:.1f}"
                   f"|parity={match}/{MICRO_B}"))

    # Weighted hybrid round: the SAME fused pass with per-row uncertainty
    # weights (the margin_density / weighted_kcenter / BADGE substrate) —
    # must also cost exactly ONE pool read per selected center.
    w = jnp.asarray(rng.uniform(0.05, 1.0, size=(MICRO_N,)), jnp.float32)

    def weighted(x, mind, i):
        return ops.greedy_round(x, mind, x[i][None, :], i[None], weights=w)

    _greedy_select(x, MICRO_B, weighted, weights=w)        # warm up jits
    with ops.track_ops() as stats:
        t0 = time.perf_counter()
        sel_w = _greedy_select(x, MICRO_B, weighted, weights=w)
        dt_w = time.perf_counter() - t0
        st_w = dict(stats)
    wrpc = st_w["embedding_reads"] / MICRO_B
    if wrpc != 1.0:
        raise AssertionError(
            "weighted hybrid round must read the pool exactly once per "
            f"center, got {wrpc:.2f}")
    if len(set(sel_w)) != MICRO_B:
        raise AssertionError("weighted selections are not unique")
    out.append(row(
        f"fig4b_micro/greedy_weighted", dt_w * 1e6 / MICRO_B,
        f"emb_reads_per_center={wrpc:.2f}"
        f"|vector_streams={st_w['vector_streams']}"
        f"|hbm_mb={st_w['hbm_bytes'] / 1e6:.1f}"))

    # Autotuned launch blocks for this pool shape (what ops.greedy_round /
    # warm_start_min_dist use when n_block / r_block are left unset).
    ch = ops.autotuned_blocks(MICRO_N, MICRO_D, jnp.float32)
    out.append(row("fig4b_micro/autotune", ch.wall_s * 1e6,
                   f"n_block={ch.n_block}|r_block={ch.r_block}"
                   f"|round_hbm_mb={ch.hbm_bytes / 1e6:.2f}"
                   f"|source={ch.source}"))

    # Core-Set warm start: M centers fold into ceil(M / r_block) pool reads
    M, RB = 512, ch.r_block
    cen = jnp.asarray(rng.normal(size=(M, MICRO_D)), jnp.float32)
    ops.warm_start_min_dist(x, cen, r_block=RB)   # warm up
    with ops.track_ops() as stats:
        t0 = time.perf_counter()
        ops.warm_start_min_dist(x, cen, r_block=RB).block_until_ready()
        dt = time.perf_counter() - t0
        st = dict(stats)
    out.append(row("fig4b_micro/warm_start", dt * 1e6,
                   f"emb_reads={st['embedding_reads']}"
                   f"|centers={M}|r_block={RB}"))
    return out


def run() -> list:
    X, Y, EX, EY = make_pool()
    srv, key2y = make_server(X, Y, EX, EY)
    out = []
    for strategy in STRATEGIES:
        srv.query(budget=100, strategy=strategy)          # warm up jits
        t0 = time.perf_counter()
        reps = 3
        for r in range(reps):
            srv.query(budget=100, strategy=strategy, rng_seed=r)
        dt = (time.perf_counter() - t0) / reps
        thr = len(X) / dt
        out.append(row(f"fig4b/{strategy}", dt * 1e6,
                       f"throughput_img_s={thr:.0f}"))
    return out
