"""Paper Fig. 4b — selection throughput (images/s through the query path)
per strategy; uncertainty strategies are near-free while Core-Set's greedy
min-dist loop is the heavy one, matching the paper's ordering."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import make_pool, make_server, row

STRATEGIES = ["random", "lc", "mc", "rc", "es", "kcg", "coreset", "dbal"]


def run() -> list:
    X, Y, EX, EY = make_pool()
    srv, key2y = make_server(X, Y, EX, EY)
    out = []
    for strategy in STRATEGIES:
        srv.query(budget=100, strategy=strategy)          # warm up jits
        t0 = time.perf_counter()
        reps = 3
        for r in range(reps):
            srv.query(budget=100, strategy=strategy, rng_seed=r)
        dt = (time.perf_counter() - t0) / reps
        thr = len(X) / dt
        out.append(row(f"fig4b/{strategy}", dt * 1e6,
                       f"throughput_img_s={thr:.0f}"))
    return out
