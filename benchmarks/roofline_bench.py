"""Roofline rows from the dry-run artifacts (deliverable g) + live kernel
micro-bench of the fused uncertainty scoring vs its unfused reference."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row


def run() -> list:
    out = []
    for mesh_file in ("runs/dryrun_single.json", "runs/dryrun_multi.json"):
        if not os.path.exists(mesh_file):
            out.append(row(f"roofline/{os.path.basename(mesh_file)}", 0.0,
                           "missing (run repro.launch.dryrun first)"))
            continue
        with open(mesh_file) as f:
            recs = json.load(f)
        n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
        for key, r in sorted(recs.items()):
            if r["status"] != "ok":
                continue
            rf = r["roofline"]
            out.append(row(
                f"roofline/{key}", rf["step_time_bound"] * 1e6,
                f"bottleneck={rf['bottleneck']};"
                f"t_comp={rf['t_compute']:.3e};t_mem={rf['t_memory']:.3e};"
                f"t_coll={rf['t_collective']:.3e};"
                f"useful={rf['useful_flops_ratio']:.3f};"
                f"mfu_bound={rf['mfu_bound']:.4f}"))
        out.append(row(f"roofline/{os.path.basename(mesh_file)}_summary",
                       0.0, f"cells_ok={n_ok}"))

    # live micro-bench: fused uncertainty scoring vs unfused reference (CPU)
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2048, 32000)), jnp.float32)
    from repro.kernels.uncertainty import ops, ref

    fused = jax.jit(lambda x: ops.uncertainty_stats(x, impl="ref"))
    unfused = jax.jit(lambda x: {
        k: v for k, v in ref.uncertainty_stats_ref(x).items()})
    jax.block_until_ready(fused(logits))
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(fused(logits))
    dt = (time.perf_counter() - t0) / 3
    out.append(row("kernels/uncertainty_scoring_2048x32k", dt * 1e6,
                   f"rows_per_s={2048/dt:.0f}"))
    return out
