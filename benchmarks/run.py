"""Benchmark harness — one module per paper table/figure (deliverable d).

Prints ``name,us_per_call,derived`` CSV; ``--json PATH`` additionally
writes the parsed rows as JSON with a stable schema
(``{"schema": 1, "rows": [{"name", "us_per_call", "derived"}],
"failures": N}``). The repo commits a ``BENCH_table2.json`` snapshot of
``--only table2`` so the perf trajectory (prefilter rows-touched ratios,
delta-refresh speedups) is tracked across PRs, and CI regenerates +
uploads the same file as a workflow artifact, re-asserting the
incremental-artifact and prefilter sections from it
(scripts/assert_table2_*.py).

  PYTHONPATH=src python -m benchmarks.run [--only table2,fig4a,...]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import types

BENCHES = ["table2", "fig4a", "fig4b", "fig4b_micro", "fig4c", "fig5",
           "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as JSON to PATH")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(BENCHES)

    from benchmarks import (fig4a_strategy_accuracy, fig4b_strategy_throughput,
                            fig4c_batch_size, fig5_pshea, roofline_bench,
                            table2_pipeline)

    mods = {
        "table2": table2_pipeline,
        "fig4a": fig4a_strategy_accuracy,
        "fig4b": fig4b_strategy_throughput,
        # fused-vs-unfused greedy selection: asserts one pool read/center
        "fig4b_micro": types.SimpleNamespace(
            run=fig4b_strategy_throughput.run_micro),
        "fig4c": fig4c_batch_size,
        "fig5": fig5_pshea,
        "roofline": roofline_bench,
    }
    print("name,us_per_call,derived")
    failures = 0
    records = []

    def emit(line: str):
        print(line, flush=True)
        name, us, derived = line.split(",", 2)
        records.append({"name": name, "us_per_call": float(us),
                        "derived": derived})

    for name in BENCHES:
        if name not in only:
            continue
        t0 = time.perf_counter()
        try:
            for line in mods[name].run():
                emit(line)
        except Exception as e:  # keep the harness going
            failures += 1
            emit(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
        emit(f"{name}/_wall,{(time.perf_counter()-t0)*1e6:.0f},done")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "rows": records, "failures": failures},
                      f, indent=1)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
