"""Paper Fig. 5 — (a) negative-exponential predictor accuracy on a real AL
curve; (b) PSHEA multi-round elimination + cost saving vs brute force."""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_pool, make_server, row, warm_start
from repro.core.agent.predictor import predict_next


def run() -> list:
    out = []

    # ---- 5a: predictor foresees the next-round accuracy (LC curve) ------
    X, Y, EX, EY = make_pool()
    srv, key2y = make_server(X, Y, EX, EY)
    warm_start(srv, key2y)
    accs = []
    for rnd in range(6):
        res = srv.query(budget=60, strategy="lc", rng_seed=rnd)
        srv.label(res["keys"], [key2y[k] for k in res["keys"]])
        accs.append(srv.train_and_eval())
    errs = []
    for k in range(3, len(accs)):
        pred = predict_next(range(k), accs[:k], k)
        errs.append(abs(pred - accs[k]))
    out.append(row("fig5a/predictor", 0.0,
                   f"mean_abs_err={np.mean(errs):.4f};"
                   f"max_abs_err={np.max(errs):.4f};rounds={len(accs)}"))

    # ---- 5b: PSHEA elimination + budget saving --------------------------
    srv, key2y = make_server(X, Y, EX, EY)
    res = srv.query(budget=560, strategy="auto", target_accuracy=0.995)
    n_strats = 7
    rounds_run = max(len(h) - 1 for h in res["history"].values())
    brute = n_strats * rounds_run * (560 // (2 * n_strats))
    saving = 1.0 - res["budget_spent"] / max(brute, 1)
    out.append(row("fig5b/pshea", 0.0,
                   f"winner={res['strategy']};acc={res['accuracy']:.3f};"
                   f"eliminated={'>'.join(res['eliminated'])};"
                   f"budget_spent={res['budget_spent']};"
                   f"saving_vs_bruteforce={saving:.2%}"))
    return out
