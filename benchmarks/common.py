"""Shared benchmark helpers: synthetic pool + oracle-attached server."""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import image_pool
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer

POOL_N = 800
EVAL_N = 400
NOISE = 0.3     # calibrated so the warm-start model has real headroom


def make_pool(seed: int = 0, n: int = POOL_N, noise: float = NOISE):
    X, Y = image_pool(n, seed=seed, noise=noise)
    EX, EY = image_pool(EVAL_N, seed=seed + 1, noise=noise)
    return X, Y, EX, EY


def make_server(X, Y, EX, EY, *, batch_size: int = 32,
                fetch_latency_s: float = 0.0, push: bool = True,
                **config_kw):
    srv = ALServer(ALServiceConfig(batch_size=batch_size, **config_kw),
                   fetch_latency_s=fetch_latency_s)
    key2y = {}
    if push:
        keys = srv.push_data(list(X))
        key2y = dict(zip(keys, Y))
        srv.attach_oracle(lambda ks: [key2y[k] for k in ks], EX, EY)
    return srv, key2y


def warm_start(srv, key2y, n: int = 30, seed: int = 123):
    """Paper §4.2: the initial model is trained on randomly-selected labeled
    data BEFORE AL scores the pool (uncertainty from an untrained head is
    noise — the cold-start effect of the paper's own ref [18])."""
    rng = np.random.default_rng(seed)
    keys = list(key2y)
    sel = rng.choice(len(keys), size=min(n, len(keys)), replace=False)
    chosen = [keys[i] for i in sel]
    srv.label(chosen, [key2y[k] for k in chosen])
    return srv.train_and_eval()


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
