"""Pod-scale AL selection: score shards locally, merge globally.

Demonstrates the distributed selection layer (core/selection.py) on an
8-device mesh (forced host devices): every data shard computes fused
uncertainty scores for its slice of the pool, then

  * budget-B uncertainty selection = local top-B + all_gather merge,
  * diversity selection = distributed greedy k-center,

with per-round communication independent of pool size — the same program
runs on the (pod, data, model) production mesh.

Run: PYTHONPATH=src python examples/distributed_selection.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.selection import (distributed_k_center,  # noqa: E402
                                  distributed_top_k, sharded_scores)
from repro.launch.mesh import make_debug_mesh, set_mesh  # noqa: E402


def main():
    mesh = make_debug_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    N, C, D, BUDGET = 65536, 512, 64, 128

    # a pool of logits + embeddings, sharded over the data axis
    logits = jnp.asarray(rng.normal(size=(N, C)) * 2, jnp.float32)
    emb = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)

    with set_mesh(mesh):
        t0 = time.perf_counter()
        scores = sharded_scores(logits, "lc", mesh)        # stays sharded
        idx_u = distributed_top_k(scores, BUDGET, mesh)    # replicated result
        jax.block_until_ready(idx_u)
        t_unc = time.perf_counter() - t0

        t0 = time.perf_counter()
        idx_d = distributed_k_center(emb, BUDGET, mesh)
        jax.block_until_ready(idx_d)
        t_div = time.perf_counter() - t0

    # verify against the single-device reference
    ref = np.argsort(-np.asarray(scores))[:BUDGET]
    match = len(set(np.asarray(idx_u).tolist()) & set(ref.tolist()))
    print(f"pool={N} budget={BUDGET} devices={mesh.devices.size}")
    print(f"uncertainty top-k: {t_unc*1e3:.0f} ms, "
          f"{match}/{BUDGET} agree with the global reference")
    print(f"k-center greedy:   {t_div*1e3:.0f} ms, "
          f"{len(set(np.asarray(idx_d).tolist()))} unique centers")


if __name__ == "__main__":
    main()
