"""Quickstart — the paper's Fig. 2 flow, verbatim API.

1. configure the AL service from a YAML file (config-as-a-service)
2. start the server
3. push unlabeled data from a client
4. query a budget of samples to label

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.data.synthetic import image_pool
from repro.service.client import ALClient, serve_tcp
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer

EXAMPLE_YML = """
name: "IMG_CLASSIFICATION"
version: 0.1
active_learning:
  strategy:
    type: "lc"
  model:
    name: "synthetic_cnn"
    batch_size: 16
  device: CPU
al_worker:
  protocol: "tcp"
  host: "127.0.0.1"
  port: 0
  replicas: 1
"""


def main():
    # 1. configure
    config = ALServiceConfig.from_yaml(EXAMPLE_YML)
    print(f"service: {config.name} strategy={config.strategy} "
          f"model={config.model_name}")

    # 2. start server (+ TCP endpoint, the gRPC stand-in)
    al_server = ALServer(config)
    rpc = serve_tcp(al_server, config.host, config.port)
    print(f"server listening on {config.host}:{rpc.port}")

    # 3. client pushes the unlabeled pool
    al_client = ALClient(url=f"{config.host}:{rpc.port}")
    data_list, labels = image_pool(400, seed=3)
    keys = al_client.push_data(list(data_list))
    print(f"pushed {len(keys)} samples; "
          f"cache entries: {al_client.stats()['cache']['entries']}")

    # 4. query a labeling budget
    selected = al_client.query(budget=10)
    print(f"strategy {selected['strategy']} selected "
          f"{len(selected['keys'])} samples: indices {selected['indices']}")

    # 5. human-in-the-loop: label and update the model
    key2y = dict(zip(keys, labels))
    al_client.label(selected["keys"], [key2y[k] for k in selected["keys"]])
    acc = al_client.train_eval()
    print(f"model updated on labeled set; (train-set) accuracy proxy "
          f"= {acc}")

    al_client.close()
    rpc.stop()


if __name__ == "__main__":
    main()
