"""End-to-end driver: multi-round active learning over an LM token pool,
with real fine-tuning between rounds (the 'data-centric LLM' workflow this
framework scales to pods).

Each round: score the unlabeled pool with the current model (fused
uncertainty on last-token logits + pooled embeddings), select with a zoo
strategy, 'label' the selected sequences (synthetic oracle = their true
continuation), fine-tune the LM on the labeled set, evaluate held-out loss.
Compares an uncertainty strategy against random selection.

Run: PYTHONPATH=src python examples/al_train_loop.py  (CPU, ~2-4 min)
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.strategies.zoo import get_strategy
from repro.data.synthetic import lm_pool
from repro.kernels.uncertainty import ops as unc_ops
from repro.models.transformer import Model
from repro.optim.optimizer import make_optimizer

ARCH = "qwen1.5-4b"
POOL, SEQ, ROUNDS, BUDGET, FT_STEPS = 256, 48, 3, 32, 30


def main():
    cfg = get_smoke_config(ARCH)
    model = Model(cfg)
    opt = make_optimizer("adamw")
    tokens, _ = lm_pool(POOL, SEQ + 1, cfg.vocab, seed=0)
    eval_tokens, _ = lm_pool(64, SEQ + 1, cfg.vocab, seed=99)
    eval_batch = {"tokens": jnp.asarray(eval_tokens[:, :-1]),
                  "labels": jnp.asarray(eval_tokens[:, 1:])}

    loss_fn = jax.jit(model.loss)
    logits_fn = jax.jit(model.last_logits)
    embed_fn = jax.jit(model.embed_pool)

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        p, s, _ = opt.update(grads, opt_state, params)
        return p, s, loss

    def run(strategy_name: str):
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        strat = get_strategy(strategy_name)
        labeled = np.zeros(POOL, bool)
        evals = []
        for rnd in range(ROUNDS):
            pool_idx = np.where(~labeled)[0]
            pool_batch = {"tokens": jnp.asarray(tokens[pool_idx, :SEQ])}
            logits = logits_fn(params, pool_batch)
            probs = jax.nn.softmax(logits, axis=-1)
            emb = embed_fn(params, pool_batch) if "embeddings" in strat.needs \
                else None
            sel = strat.select(
                jax.random.PRNGKey(rnd), min(BUDGET, len(pool_idx)),
                probs=probs if "probs" in strat.needs else None,
                embeddings=emb,
                labeled_embeddings=None)
            labeled[pool_idx[np.asarray(sel)]] = True
            lab_idx = np.where(labeled)[0]
            for step in range(FT_STEPS):
                take = np.random.default_rng(rnd * 1000 + step).choice(
                    lab_idx, size=min(8, len(lab_idx)), replace=False)
                batch = {"tokens": jnp.asarray(tokens[take, :-1]),
                         "labels": jnp.asarray(tokens[take, 1:])}
                params, opt_state, _ = train_step(params, opt_state, batch)
            ev = float(loss_fn(params, eval_batch)[0])
            evals.append(ev)
            print(f"  [{strategy_name}] round {rnd}: labeled "
                  f"{labeled.sum():3d}/{POOL}, eval loss {ev:.4f}")
        return evals

    t0 = time.perf_counter()
    print("strategy: entropy sampling (es)")
    es = run("es")
    print("strategy: random")
    rnd = run("random")
    print(f"\nfinal eval loss  es={es[-1]:.4f}  random={rnd[-1]:.4f} "
          f" ({time.perf_counter()-t0:.0f}s total)")


if __name__ == "__main__":
    main()
