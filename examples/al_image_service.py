"""One-round AL over an image pool — the paper's §4.2 experiment shape.

Compares a few zoo strategies + the PSHEA auto agent on a synthetic
CIFAR-like pool (offline environment; see DESIGN.md): select a budget,
label, fine-tune the head, report eval accuracy — and show the cache +
pipeline stats that make ALaaS faster than serial tools.

Run: PYTHONPATH=src python examples/al_image_service.py
"""
import time

import numpy as np

from repro.data.synthetic import image_pool
from repro.service.config import ALServiceConfig
from repro.service.server import ALServer


def main():
    X, Y = image_pool(1200, seed=0)
    EX, EY = image_pool(600, seed=1)

    results = {}
    for strategy in ["random", "lc", "mc", "es", "coreset", "dbal"]:
        srv = ALServer(ALServiceConfig(batch_size=32))
        keys = srv.push_data(list(X))
        key2y = dict(zip(keys, Y))
        srv.attach_oracle(lambda ks: [key2y[k] for k in ks], EX, EY)
        t0 = time.perf_counter()
        res = srv.query(budget=120, strategy=strategy)
        srv.label(res["keys"], [key2y[k] for k in res["keys"]])
        acc = srv.train_and_eval()
        dt = time.perf_counter() - t0
        results[strategy] = (acc, dt)
        print(f"{strategy:10s} acc={acc:.3f}  select+train={dt:.2f}s")

    # PSHEA auto-selection (paper Alg. 1)
    srv = ALServer(ALServiceConfig(batch_size=32))
    keys = srv.push_data(list(X))
    key2y = dict(zip(keys, Y))
    srv.attach_oracle(lambda ks: [key2y[k] for k in ks], EX, EY)
    auto = srv.query(budget=600, strategy="auto", target_accuracy=0.97)
    print(f"\nPSHEA picked {auto['strategy']!r} "
          f"(acc {auto['accuracy']:.3f}, stop: {auto['stop_reason']}); "
          f"eliminated order: {auto['eliminated']}")
    best_fixed = max(results, key=lambda s: results[s][0])
    print(f"best fixed strategy was {best_fixed!r} "
          f"(acc {results[best_fixed][0]:.3f})")


if __name__ == "__main__":
    main()
